"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Usage:
    PYTHONPATH=src python -m benchmarks.run [--smoke] [--json FILE] [module ...]

``--smoke``: run every fig*/tab*/throughput_* benchmark plus kernel_bench at
minimum size and exit non-zero if any raises — the CI slow lane runs this so
benchmark scripts cannot bitrot silently.  Smoke numbers are meaningless.

``--json FILE``: additionally write the rows as a JSON document
``{"smoke": bool, "rows": [{"module", "name", "us_per_call", "derived"}]}``
— CI uploads this per main-commit (actions/upload-artifact) so the perf
trajectory, including the dense-vs-paged decode comparison in kernel_bench,
is recorded instead of discarded with the job log.
"""
from __future__ import annotations

import json
import sys
import time

MODULES = [
    "fig2_prefill_scaling",
    "fig4_cache_hit",
    "fig5_retrieval_pattern",
    "fig13_overall",
    "fig15_topk",
    "fig16_large_models",
    "fig17_policy",
    "fig18_reorder",
    "fig19_speculative",
    "fig_tiered_cache",
    "fig_cag",
    "fig_chunk_reuse",
    "fig_replica_routing",
    "fig_frontdoor",
    "fig_tp_scaling",
    "tab4_sched_time",
    "throughput_batching",
    "tpot_topk",
    "kernel_bench",
]


def main() -> None:
    import importlib
    args = list(sys.argv[1:])
    smoke = "--smoke" in args
    json_path = None
    if "--json" in args:
        i = args.index("--json")
        if i + 1 >= len(args) or args[i + 1].startswith("-"):
            print("benchmarks.run: --json requires a FILE argument\n"
                  "usage: python -m benchmarks.run [--smoke] [--json FILE] "
                  "[module ...]", file=sys.stderr)
            sys.exit(2)
        json_path = args[i + 1]
        del args[i:i + 2]
    if smoke:
        args.remove("--smoke")
        from benchmarks import common
        common.SMOKE = True
        default = [m for m in MODULES
                   if m.startswith(("fig", "tab", "throughput_"))
                   or m == "kernel_bench"]
    else:
        default = MODULES
    wanted = args or default
    print("name,us_per_call,derived")
    failures = 0
    records = []
    for name in wanted:
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            rows = mod.run()
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}")
            records.append({"module": name, "name": f"{name}/ERROR",
                            "us_per_call": 0.0,
                            "derived": f"{type(e).__name__}: {e}"})
            failures += 1
            continue
        for row_name, us, derived in rows:
            print(f"{row_name},{us:.1f},{str(derived).replace(',', ';')}")
            records.append({"module": name, "name": row_name,
                            "us_per_call": float(us),
                            "derived": str(derived)})
        wall = (time.time() - t0) * 1e6
        print(f"{name}/_total,{wall:.0f},bench wall time", flush=True)
        records.append({"module": name, "name": f"{name}/_total",
                        "us_per_call": wall, "derived": "bench wall time"})
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"smoke": smoke, "rows": records}, f, indent=1)
        print(f"wrote {len(records)} rows to {json_path}", file=sys.stderr)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
