"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Usage:
    PYTHONPATH=src python -m benchmarks.run [--smoke] [module ...]

``--smoke``: run every fig*/tab*/throughput_* benchmark at minimum size and
exit non-zero if any raises — the CI slow lane runs this so benchmark
scripts cannot bitrot silently.  Smoke numbers are meaningless.
"""
from __future__ import annotations

import sys
import time

MODULES = [
    "fig2_prefill_scaling",
    "fig4_cache_hit",
    "fig5_retrieval_pattern",
    "fig13_overall",
    "fig15_topk",
    "fig16_large_models",
    "fig17_policy",
    "fig18_reorder",
    "fig19_speculative",
    "fig_tiered_cache",
    "fig_replica_routing",
    "tab4_sched_time",
    "throughput_batching",
    "tpot_topk",
    "kernel_bench",
]


def main() -> None:
    import importlib
    args = list(sys.argv[1:])
    smoke = "--smoke" in args
    if smoke:
        args.remove("--smoke")
        from benchmarks import common
        common.SMOKE = True
        default = [m for m in MODULES
                   if m.startswith(("fig", "tab", "throughput_"))]
    else:
        default = MODULES
    wanted = args or default
    print("name,us_per_call,derived")
    failures = 0
    for name in wanted:
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            rows = mod.run()
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}")
            failures += 1
            continue
        for row_name, us, derived in rows:
            print(f"{row_name},{us:.1f},{str(derived).replace(',', ';')}")
        print(f"{name}/_total,{(time.time() - t0) * 1e6:.0f},bench wall time",
              flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
