"""Paper Fig. 4: prefill latency — full computation vs cached prefix vs
cached prefix + host->GPU transmission.

Paper claims: caching cuts prefill up to 11.5x; still 3.9x ahead after the
PCIe transfer.
"""
from __future__ import annotations

from benchmarks.common import PROFILES


def run() -> list:
    rows = []
    prof = PROFILES["llama2-7b"]   # 0.5 MiB/token: the transfer-heavy case
    req = 32                        # request tokens (paper setting)
    best_full_over_hit = 0.0
    best_full_over_hit_tx = 0.0
    for p in (128, 512, 1024, 2048, 4096):
        full = prof.prefill_time(0, p + req)
        hit = prof.prefill_time(p, req)
        tx = prof.transfer_time(p * prof.kv_bytes_per_token)
        rows.append((f"fig4/full_prefill_{p}", full * 1e6, f"s={full:.3f}"))
        rows.append((f"fig4/cached_prefix_{p}", hit * 1e6,
                     f"speedup={full / hit:.1f}x"))
        rows.append((f"fig4/cached_plus_tx_{p}", (hit + tx) * 1e6,
                     f"speedup={full / (hit + tx):.1f}x"))
        best_full_over_hit = max(best_full_over_hit, full / hit)
        best_full_over_hit_tx = max(best_full_over_hit_tx, full / (hit + tx))
    rows.append(("fig4/claim/max_speedup_no_tx", best_full_over_hit,
                 f"paper<=11.5x got={best_full_over_hit:.1f}x"))
    rows.append(("fig4/claim/max_speedup_with_tx", best_full_over_hit_tx,
                 f"paper<=3.9x got={best_full_over_hit_tx:.1f}x"))
    return rows
