"""Paper Fig. 2: inference (prefill) time vs input length.

Two sources: wall-clock on the tiny CPU model (same code path) and the
calibrated A10G analytic profile at paper scale (7B model).
Paper claim: prefill-dominated, ~1 s at 4k tokens on A10G/7B.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import PROFILES
from repro.configs import get_reduced
from repro.models import model as M


def run() -> list:
    rows = []
    prof = PROFILES["mistral-7b"]
    for n in (128, 512, 1024, 2048, 4096):
        t = prof.prefill_time(0, n)
        rows.append((f"fig2/a10g_7b/prefill_{n}tok", t * 1e6,
                     f"analytic_s={t:.3f}"))
    # measured on the tiny model (CPU wall clock, same code path)
    cfg = get_reduced("mistral-7b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    fn = jax.jit(lambda p, t: M.prefill(cfg, p, {"tokens": t})[0])
    for n in (64, 256, 512):
        toks = jnp.zeros((1, n), jnp.int32)
        fn(params, toks).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            fn(params, toks).block_until_ready()
        dt = (time.perf_counter() - t0) / 3
        rows.append((f"fig2/tiny_cpu/prefill_{n}tok", dt * 1e6,
                     f"measured_s={dt:.4f}"))
    claim = prof.prefill_time(0, 4096)
    rows.append(("fig2/claim/prefill_4k_near_1s", claim * 1e6,
                 f"paper~1.0s got={claim:.2f}s ok={0.5 < claim < 2.0}"))
    return rows
