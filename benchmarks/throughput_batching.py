"""Continuous batching vs sequential serving: throughput / TTFT sweep.

Two sweeps:

  * real execution (tiny reduced model, CPU): requests served through the
    continuous-batching runtime at several decode-batch sizes vs the
    sequential RAGServer — reports wall-clock throughput, mean TTFT and
    decode-batch occupancy.  Run directly:

        PYTHONPATH=src python benchmarks/throughput_batching.py --real

  * simulator (paper-scale hardware profile): request rate x max_batch grid,
    continuous iteration-level scheduling (the shared scheduler policy) —
    this is the shape of paper Fig. 13's x-axis.  Default mode, and the
    mode used by benchmarks/run.py (returns rows like the fig* modules).
"""
from __future__ import annotations

import argparse
import time
from typing import List

import numpy as np

from benchmarks.common import corpus_and_index, simulate, workload

Row = tuple


def run() -> List[Row]:
    """Simulator sweeps: requests/s x max_batch, then prefill-chunk size x
    prefill-batch budget (the chunked/batched-prefill TTFT/TPOT knob)."""
    corpus, idx = corpus_and_index()
    rows: List[Row] = []
    for rate in (0.5, 1.5, 3.0):
        wl = workload(corpus, n=200, rate=rate, zipf=1.0, out_len=6, seed=23)
        for max_batch in (1, 4, 8):
            m, _ = simulate(corpus, idx, wl, max_batch=max_batch)
            rows.append((
                f"throughput/rate{rate}/batch{max_batch}",
                m.avg_ttft * 1e6,
                f"ttft={m.avg_ttft:.2f}s tpot={m.avg_tpot * 1e3:.0f}ms "
                f"rps={m.throughput_rps:.2f}",
            ))
        base = [r for r in rows if f"rate{rate}/batch1" in r[0]][0]
        best = [r for r in rows if f"rate{rate}/batch8" in r[0]][0]
        rows.append((
            f"throughput/rate{rate}/batch8_vs_1_ttft_speedup",
            base[1] / max(best[1], 1e-9),
            "continuous batching vs one-at-a-time",
        ))
    rows.extend(run_chunk_sweep(corpus, idx))
    return rows


def run_chunk_sweep(corpus, idx) -> List[Row]:
    """Chunk-size x prefill-token-budget sweep at a fixed saturating rate:
    small chunks shorten the cancellation window (more speculative tokens
    saved) and let decode interleave (TPOT); a ragged prefill-token budget
    packs short prefills together (TTFT under load)."""
    rows: List[Row] = []
    wl = workload(corpus, n=150, rate=2.0, zipf=1.0, out_len=6, seed=31)
    for chunk in (128, 512, 2048, 0):
        for budget in (0, 2048):
            m, _ = simulate(corpus, idx, wl, max_batch=4,
                            prefill_chunk=chunk, max_prefill_tokens=budget)
            rows.append((
                f"throughput/chunk{chunk or 'off'}/budget{budget or 'off'}",
                m.avg_ttft * 1e6,
                f"ttft={m.avg_ttft:.2f}s tpot={m.avg_tpot * 1e3:.0f}ms "
                f"iters={m.prefill_iterations} "
                f"packed={m.avg_prefill_batch:.2f} "
                f"saved_tok={m.chunk_tokens_saved}",
            ))
    return rows


def run_real(requests: int = 10, max_new: int = 4) -> None:
    """Real-execution A/B on the reduced qwen2 model (slow: jit compiles)."""
    import jax
    from repro.configs import get_reduced
    from repro.models import model as M
    from repro.retrieval.corpus import make_corpus, make_workload
    from repro.retrieval.vectordb import IVFIndex
    from repro.serving.config import EngineConfig
    from repro.serving.engine import RAGServer
    from repro.serving.runtime import ContinuousRuntime

    cfg = get_reduced("qwen2-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    corpus = make_corpus(40, mean_doc_tokens=32, vocab=cfg.vocab_size, seed=0)
    idx = IVFIndex(corpus.doc_vectors, n_clusters=8, nprobe=4)
    wl = make_workload(corpus, n_requests=requests, rate=100.0,
                       question_tokens=8, vocab=cfg.vocab_size,
                       zipf_s=1.2, seed=1)

    print(f"{'mode':>14} {'wall_s':>7} {'req/s':>6} {'ttft_ms':>8} "
          f"{'occupancy':>9}")
    t0 = time.time()
    srv = RAGServer(cfg, params, corpus, idx, config=EngineConfig(top_k=2))
    seq = srv.serve(wl, max_new_tokens=max_new)
    wall = time.time() - t0
    ttft = float(np.mean([r.ttft for r in seq]))
    print(f"{'sequential':>14} {wall:>7.1f} {len(seq) / wall:>6.2f} "
          f"{ttft * 1e3:>8.1f} {'1.00':>9}")

    for max_batch, chunk, budget in ((2, 0, 0), (4, 0, 0), (4, 16, 48)):
        rt = ContinuousRuntime(cfg, params, corpus, idx,
                               config=EngineConfig(
                                   top_k=2, max_batch=max_batch,
                                   prefill_chunk=chunk,
                                   max_prefill_tokens=budget))
        t0 = time.time()
        res = rt.serve(wl, max_new_tokens=max_new)
        wall = time.time() - t0
        s = rt.metrics.summary()
        tag = f"b={max_batch}" + (f",c={chunk}" if chunk else "")
        print(f"{f'cont({tag})':>14} {wall:>7.1f} "
              f"{len(res) / wall:>6.2f} {s['ttft']['mean'] * 1e3:>8.1f} "
              f"{s['mean_decode_batch']:>9.2f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--real", action="store_true",
                    help="real-execution A/B instead of the simulator sweep")
    ap.add_argument("--requests", type=int, default=10)
    args = ap.parse_args()
    if args.real:
        run_real(requests=args.requests)
    else:
        for name, val, info in run():
            print(f"{name:<45} {val:>12.1f}  {info}")
