"""Paper Fig. 15: different top-k values (1, 3, 5). RAGCache keeps its edge
because the tree caches shared prefixes even as permutations grow."""
from __future__ import annotations

from benchmarks.common import BASELINES, corpus_and_index, simulate, workload


def run() -> list:
    corpus, idx = corpus_and_index()
    rows = []
    for k in (1, 3, 5):
        wl = workload(corpus, n=200, rate=0.6, zipf=1.0, seed=11)
        t = {}
        for name in ("ragcache", "vllm"):
            m, _ = simulate(corpus, idx, wl, top_k=k, **BASELINES[name])
            t[name] = m.avg_ttft
            rows.append((f"fig15/top{k}/{name}", m.avg_ttft * 1e6,
                         f"hit={m.doc_hit_rate:.2f}"))
        rows.append((f"fig15/top{k}/claim", t["vllm"] / t["ragcache"],
                     f"paper 1.7-3.1x got={t['vllm'] / t['ragcache']:.2f}x"))
    return rows
