"""Paper Fig. 17 + Table 2: replacement-policy ablation — PGDSF vs GDSF vs
LRU vs LFU hit rate and TTFT across host-memory sizes.

Paper claims: PGDSF 1.02-1.32x over GDSF, 1.06-1.62x over LRU,
1.06-1.75x over LFU (hit rate); 1.05-1.29x lower TTFT.
"""
from __future__ import annotations

from benchmarks.common import corpus_and_index, simulate, workload

# host sizes scaled to the synthetic corpus (paper: 8-128 GiB on Wikipedia)
HOST_GIB = (0.5, 1, 2, 4)


def run() -> list:
    corpus, idx = corpus_and_index()
    rows = []
    worst_best = {}
    for hg in HOST_GIB:
        # mild popularity drift: real QA traffic is non-stationary, which is
        # where recency-aware policies (PGDSF clock) separate from pure LFU
        wl = workload(corpus, n=250, rate=0.8, zipf=1.0, seed=17, drift=0.15)
        hits = {}
        for pol in ("pgdsf", "gdsf", "lru", "lfu"):
            m, _ = simulate(corpus, idx, wl, policy=pol,
                            gpu_cache_bytes=int(0.25 * 2**30),
                            host_cache_bytes=int(hg * 2**30),
                            reorder=False, speculative=False)
            hits[pol] = m.doc_hit_rate
            rows.append((f"fig17/host{hg}GiB/{pol}", m.doc_hit_rate * 100,
                         f"hit={m.doc_hit_rate:.3f} ttft={m.avg_ttft:.3f}s"))
        for other in ("gdsf", "lru", "lfu"):
            r = hits["pgdsf"] / max(hits[other], 1e-9)
            worst_best.setdefault(other, []).append(r)
    for other, ratios in worst_best.items():
        rows.append((f"fig17/claim/pgdsf_vs_{other}", max(ratios),
                     f"hit-ratio range {min(ratios):.2f}-{max(ratios):.2f}x "
                     f"(paper 1.02-1.75x, >=1 expected)"))
    return rows
