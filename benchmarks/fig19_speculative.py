"""Paper Fig. 19 + Table 3: dynamic speculative pipelining vs No-DSP across
vector-search ratios.

Paper claims: up to 1.6x TTFT reduction; 1.5-4.3x less non-overlapping
vector search time.  The search-ratio sweep trades accuracy for latency by
probing a fraction of IVF clusters.
"""
from __future__ import annotations

from benchmarks.common import corpus_and_index, simulate, workload
from repro.retrieval.vectordb import IVFIndex


def run() -> list:
    # high-accuracy search regime: nprobe=32 of 64 clusters, scan bandwidth
    # calibrated so the full search costs ~0.4 s (paper Table 3: 78-446 ms —
    # their corpus is 0.3M Wikipedia docs at 768-dim; ours is scaled down, so
    # the analytic bandwidth is scaled to match the paper's search times)
    corpus, _ = corpus_and_index()
    idx = IVFIndex(corpus.doc_vectors, n_clusters=64, nprobe=32,
                   scan_bytes_per_s=3.2e5)
    rows = []
    best_ttft, best_ovl = 0.0, 0.0
    for frac in (0.125, 0.25, 0.5, 1.0):
        wl = workload(corpus, n=120, rate=0.1, zipf=1.0, seed=23)
        m = {}
        for dsp in (True, False):
            m[dsp], _ = simulate(corpus, idx, wl, speculative=dsp,
                                 search_fraction=frac, reorder=False)
            rows.append((f"fig19/ratio{frac}/{'dsp' if dsp else 'nodsp'}",
                         m[dsp].avg_non_overlap_search * 1e6,
                         f"nonovl={m[dsp].avg_non_overlap_search * 1000:.1f}ms "
                         f"ttft={m[dsp].avg_ttft:.3f}s "
                         f"wasted={m[dsp].wasted_prefills}"))
        best_ttft = max(best_ttft, m[False].avg_ttft / max(m[True].avg_ttft, 1e-9))
        best_ovl = max(best_ovl, m[False].avg_non_overlap_search
                       / max(m[True].avg_non_overlap_search, 1e-9))
    rows.append(("fig19/claim/ttft_reduction", best_ttft,
                 f"paper<=1.6x got={best_ttft:.2f}x"))
    rows.append(("tab3/claim/non_overlap_reduction", best_ovl,
                 f"paper 1.5-4.3x got={best_ovl:.2f}x"))
    return rows
