"""Paper Fig. 18: cache-aware reordering at saturating request rates.
Paper claim: 1.2-2.1x lower TTFT with reordering when the queue saturates."""
from __future__ import annotations

from benchmarks.common import corpus_and_index, simulate, workload


def run() -> list:
    corpus, idx = corpus_and_index()
    rows = []
    best = 0.0
    for host_gib in (1, 4):
        wl = workload(corpus, n=250, rate=2.5, zipf=1.0, seed=19)  # saturated
        t = {}
        for on in (True, False):
            m, _ = simulate(corpus, idx, wl, reorder=on, reorder_window=32,
                            speculative=False,
                            gpu_cache_bytes=int(0.25 * 2**30),
                            host_cache_bytes=int(host_gib * 2**30))
            t[on] = m.avg_ttft
            rows.append((f"fig18/host{host_gib}GiB/"
                         f"{'reorder' if on else 'fifo'}",
                         m.avg_ttft * 1e6,
                         f"ttft={m.avg_ttft:.2f}s hit={m.doc_hit_rate:.2f}"))
        best = max(best, t[False] / t[True])
    rows.append(("fig18/claim/reorder_speedup", best,
                 f"paper 1.2-2.1x got={best:.2f}x"))
    return rows
