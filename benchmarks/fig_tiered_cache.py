"""Three-tier cache hierarchy sweep: GPU:host:disk capacity ratios.

RAGCache's multilevel claim (§5.1) extended one tier down (Cache-Craft,
arXiv 2502.15734; systems-tradeoffs study, arXiv 2412.11854): when the
retained working set exceeds GPU+host memory, an mmap'd disk tier keeps
document KV reusable at NVMe bandwidth instead of recomputing it.  The
sweep holds the GPU budget fixed at roughly one request path and scales
host and disk by ratio; the headline row checks that the mean TTFT of
requests whose prefix hit came (at least partly) from DISK stays strictly
below the full-recompute baseline — the disk tier only earns its place
while fetch beats recompute.

Long-document regime on purpose: per-token disk+PCIe transfer beats
per-token attention recompute only past a few thousand cached tokens
(the crossover is ~2*flops*(1/bw_disk + 1/bw_pcie) tokens, independent of
KV width), so docs are thousands of tokens even in smoke mode — token
counts are analytic inputs and cost the simulator nothing.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import PROFILES, simulate, smoke_clamp, workload
from repro.retrieval.corpus import make_corpus
from repro.retrieval.vectordb import IVFIndex

# A10G with a local NVMe RAID for the disk tier (PCIe4 x4 striped pair) —
# the storage-heavy deployment the disk tier targets.
PROFILE = dataclasses.replace(PROFILES["mistral-7b"],
                              name="a10g-mistral-7b-nvme",
                              disk_bytes_per_s=12e9)

TOP_K = 4
# host:disk capacity multiples of the fixed GPU budget
RATIOS = [(1, 0, 0), (1, 1, 0), (1, 1, 4), (1, 1, 16), (1, 2, 16)]


def _setup():
    n_docs = smoke_clamp(48, 24)
    mean_doc = 6000                     # alpha ~24k on a full hit (see above)
    corpus = make_corpus(n_docs, mean_doc_tokens=mean_doc, seed=0)
    idx = IVFIndex(corpus.doc_vectors, n_clusters=max(4, n_docs // 8),
                   nprobe=8, seed=0)
    wl = workload(corpus, n=smoke_clamp(64, 20), rate=0.5, zipf=1.6,
                  out_len=2, seed=1)
    path_bytes = TOP_K * mean_doc * PROFILE.kv_bytes_per_token
    return corpus, idx, wl, path_bytes


def run() -> list:
    corpus, idx, wl, path_bytes = _setup()
    gpu = int(1.25 * path_bytes)        # ~one pinned path + slack
    rows = []

    base, _ = simulate(corpus, idx, wl, profile=PROFILE, top_k=TOP_K,
                       gpu_cache_bytes=0, host_cache_bytes=0,
                       disk_cache_bytes=0)
    rows.append(("fig_tiered/recompute", base.avg_ttft * 1e6,
                 f"ttft_s={base.avg_ttft:.3f}"))

    disk_hit_ttfts = []
    for g, h, d in RATIOS:
        m, _ = simulate(corpus, idx, wl, profile=PROFILE, top_k=TOP_K,
                        gpu_cache_bytes=g * gpu, host_cache_bytes=h * gpu,
                        disk_cache_bytes=d * gpu)
        name = f"fig_tiered/gpu{g}_host{h}_disk{d}"
        hits = (f"hit_tok g={m.hit_tokens_gpu} h={m.hit_tokens_host} "
                f"d={m.hit_tokens_disk}")
        rows.append((name, m.avg_ttft * 1e6,
                     f"hit={m.doc_hit_rate:.2f} {hits} "
                     f"spill={m.spill_bytes / 2**30:.1f}GiB "
                     f"fetch={m.fetch_bytes / 2**30:.1f}GiB "
                     f"disk_ev={m.disk_evictions}"))
        if d > 0:
            disk_hit_ttfts += m.disk_hit_ttfts

    # headline: disk-tier hits must beat full recompute, else the tier is
    # pure overhead — asserted (deterministic analytic sim; CI smoke runs it)
    assert disk_hit_ttfts, "no request ever hit the disk tier — sweep broken"
    disk_ttft = float(np.mean(disk_hit_ttfts))
    assert disk_ttft < base.avg_ttft, (
        f"disk-tier hit TTFT {disk_ttft:.3f}s >= recompute "
        f"{base.avg_ttft:.3f}s — fetch no longer beats recompute")
    rows.append(("fig_tiered/claim/disk_hit_vs_recompute",
                 disk_ttft * 1e6,
                 f"disk_hit_ttft={disk_ttft:.3f}s < "
                 f"recompute={base.avg_ttft:.3f}s "
                 f"({base.avg_ttft / disk_ttft:.2f}x) n={len(disk_hit_ttfts)}"))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
