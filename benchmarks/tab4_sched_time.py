"""Paper Table 4: scheduling time (tree lookup/update, reorder decisions,
DSP decisions). Paper claim: < 1 ms per decision at 0.5-2 req/s."""
from __future__ import annotations

import numpy as np

from benchmarks.common import corpus_and_index, simulate, workload


def run() -> list:
    corpus, idx = corpus_and_index()
    rows = []
    for rate in (0.5, 1.0, 2.0):
        wl = workload(corpus, n=200, rate=rate, zipf=1.0, seed=29)
        m, sim = simulate(corpus, idx, wl)
        st = np.asarray(sim.sched_times)
        mean_us = float(st.mean() * 1e6) if len(st) else 0.0
        rows.append((f"tab4/rate{rate}/sched_decision", mean_us,
                     f"mean={mean_us:.0f}us p99="
                     f"{float(np.percentile(st, 99) * 1e6):.0f}us "
                     f"paper<1ms ok={mean_us < 1000}"))
    return rows
