"""Front-door sweep: query-level cache + SLO admission + autoscaler A/B.

At the "millions of users" scale many requests should never reach an
engine: QA traffic repeats itself, and a query-level cache of retrieval
results + finished answers absorbs the repeats (SNIPPETS.md §1;
serving/frontdoor.py).  This sweep drives the SAME ``FrontDoor`` policy
stack the real driver uses (``launch/serve.py --frontdoor``) over
simulated replica fleets on the multi-tenant traffic model
(retrieval/traffic.py) and asserts the headline claims:

  * on a repeat-heavy workload (small canonical query pools, drift off),
    front-door-on mean TTFT is STRICTLY below front-door-off;
  * the autoscaler's active replica count stays within its configured
    [min, max] bounds under a Markov-modulated bursty trace;
  * TTL expiry bounds staleness: with TTL shorter than the trace, entries
    expire and the hit rate drops below the no-TTL ceiling.
"""
from __future__ import annotations

from benchmarks.common import PROFILES, smoke_clamp
from repro.retrieval.corpus import make_corpus
from repro.retrieval.traffic import (TrafficConfig, default_tenants,
                                     make_tenant_workload, repeat_rate)
from repro.retrieval.vectordb import IVFIndex
from repro.serving.frontdoor import TenantSLO, make_frontdoor
from repro.serving.simulator import (SimConfig, simulate_frontdoor,
                                     simulate_replicas)

PROFILE = PROFILES["mistral-7b"]


def _setup(n_requests: int, *, n_queries: int = 8, burst_mult: float = 1.0,
           rate: float = 20.0, seed: int = 1):
    n_docs = smoke_clamp(400, 60)
    corpus = make_corpus(n_docs, mean_doc_tokens=smoke_clamp(600, 120),
                         seed=0)
    idx = IVFIndex(corpus.doc_vectors, n_clusters=max(4, n_docs // 12),
                   nprobe=8, seed=0)
    tenants = default_tenants(2, zipf_s=1.3, n_queries=n_queries)
    cfg = TrafficConfig(n_requests=n_requests, base_rate=rate, seed=seed,
                        burst_rate_mult=burst_mult,
                        diurnal_amplitude=0.3 if burst_mult > 1.0 else 0.0)
    wl = make_tenant_workload(corpus, tenants, cfg)
    return corpus, idx, tenants, wl


def _slos(tenants):
    return {t.name: TenantSLO(ttft_target=t.slo_ttft_ms / 1e3,
                              min_top_k=t.min_top_k) for t in tenants}


def run() -> list:
    rows = []
    n_req = smoke_clamp(200, 80)

    # ---- headline: front door on vs off, repeat-heavy trace --------------
    corpus, idx, tenants, wl = _setup(n_req, n_queries=8)
    rr = repeat_rate(wl)
    sim_kw = dict(profile=PROFILE, top_k=2, gpu_cache_bytes=4 * 2**30,
                  host_cache_bytes=32 * 2**30)
    off = simulate_replicas(SimConfig(**sim_kw), corpus, idx, wl,
                            n_replicas=2)
    # generous SLOs for the headline A/B: nothing sheds or degrades, so
    # the TTFT delta is the query cache alone
    fd = make_frontdoor(capacity=256, ttl=1e9, sim_threshold=0.98,
                        slos={t.name: TenantSLO(ttft_target=1e9)
                              for t in tenants},
                        top_k=2, init_service=1e-6)
    on = simulate_frontdoor(SimConfig(**sim_kw), corpus, idx, wl, fd,
                            n_replicas=2)
    assert not on.partition.shed, "headline A/B must not shed"
    hit_rate = on.frontdoor_stats["hit_rate"]
    rows.append(("fig_frontdoor/off", off.metrics.avg_ttft * 1e6,
                 f"mean_ttft={off.metrics.avg_ttft:.4f}s "
                 f"p99={off.metrics.p99_ttft:.3f}s repeat_rate={rr:.2f}"))
    rows.append(("fig_frontdoor/on", on.metrics.avg_ttft * 1e6,
                 f"mean_ttft={on.metrics.avg_ttft:.4f}s "
                 f"p99={on.metrics.p99_ttft:.3f}s hit_rate={hit_rate:.2f} "
                 f"hits={len(on.partition.hits)} "
                 f"misses={len(on.partition.misses)}"))
    assert on.metrics.avg_ttft < off.metrics.avg_ttft, (
        f"front door stopped paying for itself: on "
        f"{on.metrics.avg_ttft:.4f}s >= off {off.metrics.avg_ttft:.4f}s "
        f"at repeat rate {rr:.2f}")
    rows.append(("fig_frontdoor/claim/on_beats_off",
                 (off.metrics.avg_ttft - on.metrics.avg_ttft) * 1e6,
                 f"on={on.metrics.avg_ttft:.4f}s < "
                 f"off={off.metrics.avg_ttft:.4f}s "
                 f"({off.metrics.avg_ttft / max(on.metrics.avg_ttft, 1e-12):.2f}x)"))

    # ---- TTL sweep: staleness bound costs hit rate -----------------------
    prev_hits = None
    for ttl in (1e9, 2.0, 0.2):
        corpus2, idx2, tenants2, wl2 = _setup(n_req, n_queries=8)
        fd = make_frontdoor(capacity=256, ttl=ttl, sim_threshold=0.98,
                            slos={t.name: TenantSLO(ttft_target=1e9)
                                  for t in tenants2},
                            top_k=2, init_service=1e-6)
        res = simulate_frontdoor(SimConfig(**sim_kw), corpus2, idx2, wl2,
                                 fd, n_replicas=2)
        cs = res.frontdoor_stats["cache"]
        hits = cs["hits_exact"] + cs["hits_similar"]
        rows.append((f"fig_frontdoor/ttl_{ttl:g}",
                     res.metrics.avg_ttft * 1e6,
                     f"hits={hits} expired={cs['expired']} "
                     f"hit_rate={res.frontdoor_stats['hit_rate']:.2f}"))
        if prev_hits is not None:
            assert hits <= prev_hits, (
                f"shorter TTL {ttl} produced MORE hits ({hits} > "
                f"{prev_hits}) — expiry is not expiring")
        prev_hits = hits

    # ---- autoscaler under bursts: bounds + SLO admission -----------------
    corpus3, idx3, tenants3, wl3 = _setup(n_req, n_queries=8,
                                          burst_mult=6.0, rate=40.0,
                                          seed=2)
    lo, hi = 1, 3
    fd = make_frontdoor(capacity=256, ttl=1e9, sim_threshold=0.98,
                        slos=_slos(tenants3), top_k=2,
                        min_replicas=lo, max_replicas=hi, autoscale=True,
                        scale_up_backlog=2.0, scale_down_backlog=0.5,
                        cooldown=0.05)
    res = simulate_frontdoor(SimConfig(**sim_kw), corpus3, idx3, wl3, fd,
                             n_replicas=hi)
    scale = res.frontdoor_stats["autoscale"]
    assert lo <= scale["min_seen"] and scale["max_seen"] <= hi, (
        f"autoscaler left its bounds: saw "
        f"[{scale['min_seen']}, {scale['max_seen']}] outside [{lo}, {hi}]")
    att = res.frontdoor_stats["slo_attainment"]
    att_s = " ".join(f"{t}={v['fraction']:.2f}" for t, v in att.items())
    rows.append(("fig_frontdoor/autoscale_burst",
                 res.metrics.avg_ttft * 1e6,
                 f"active_range=[{scale['min_seen']},{scale['max_seen']}] "
                 f"bounds=[{lo},{hi}] events={len(scale['events'])} "
                 f"shed={res.frontdoor_stats['shed_total']} "
                 f"degraded={res.frontdoor_stats['degraded']} "
                 f"slo_attainment: {att_s}"))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
