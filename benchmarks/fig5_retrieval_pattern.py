"""Paper Fig. 5/6: skewed retrieval pattern — top docs dominate accesses,
robust across ANN indexes (FlatL2 vs IVF)."""
from __future__ import annotations


from benchmarks.common import corpus_and_index, workload
from repro.retrieval.corpus import access_cdf
from repro.retrieval.vectordb import FlatIndex


def run() -> list:
    corpus, ivf = corpus_and_index()
    wl = workload(corpus, n=2000, rate=10, zipf=1.0, seed=5)
    rows = []
    n_docs = len(corpus.doc_lengths)
    for name, index in (("ivf", ivf), ("flat", FlatIndex(corpus.doc_vectors))):
        accessed = [index.search(r.query_vec, 1)[0] for r in wl[:600]]
        frac, cdf = access_cdf(accessed, n_docs)
        top3 = float(cdf[max(int(0.03 * n_docs) - 1, 0)])
        rows.append((f"fig5/{name}/top3pct_share", top3 * 100,
                     f"paper~60% got={top3:.0%} skew_ok={top3 > 0.3}"))
    # ground-truth zipf target distribution
    frac, cdf = access_cdf([r.target_doc for r in wl], n_docs)
    rows.append(("fig5/zipf_target/top3pct_share",
                 float(cdf[int(0.03 * n_docs)]) * 100,
                 f"uniform_would_be=3%"))
    return rows
