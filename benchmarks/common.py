"""Shared benchmark fixtures: corpus, workloads, simulator harness."""
from __future__ import annotations

import functools
from typing import Dict, Tuple

from repro.core.profiler import (A10G_LLAMA2_7B, A10G_MISTRAL_7B,
                                 H800_LLAMA2_70B, H800_MIXTRAL)
from repro.retrieval.corpus import make_corpus, make_workload
from repro.retrieval.vectordb import IVFIndex
from repro.serving.simulator import RAGSimulator, SimConfig

Row = Tuple[str, float, str]   # (name, us_per_call, derived)

# benchmarks.run --smoke sets this: clamp every corpus/workload to minimum
# size so the whole benchmark suite runs in CI as a bitrot check (numbers
# are meaningless in smoke mode — only "completes without exceptions" is
# asserted).
SMOKE = False


def smoke_clamp(n: int, cap: int) -> int:
    return min(n, cap) if SMOKE else n


PROFILES = {
    "mistral-7b": A10G_MISTRAL_7B,
    "llama2-7b": A10G_LLAMA2_7B,
    "mixtral-8x7b": H800_MIXTRAL,
    "llama2-70b": H800_LLAMA2_70B,
}


@functools.lru_cache(maxsize=4)
def corpus_and_index(n_docs: int = 2000, mean_doc: int = 1000, seed: int = 0):
    n_docs = smoke_clamp(n_docs, 150)
    mean_doc = smoke_clamp(mean_doc, 120)
    corpus = make_corpus(n_docs, mean_doc_tokens=mean_doc, seed=seed)
    idx = IVFIndex(corpus.doc_vectors,
                   n_clusters=min(64, max(4, n_docs // 8)), nprobe=8,
                   seed=seed)
    return corpus, idx


def workload(corpus, n=300, rate=1.0, zipf=1.0, out_len=1, seed=1, **kw):
    return make_workload(corpus, n_requests=smoke_clamp(n, 25), rate=rate,
                         zipf_s=zipf, output_len_mean=out_len, seed=seed,
                         **kw)


def simulate(corpus, idx, wl, **cfg_kw):
    cfg = SimConfig(profile=cfg_kw.pop("profile", A10G_MISTRAL_7B), **cfg_kw)
    sim = RAGSimulator(cfg, corpus, idx, wl)
    m = sim.run()
    return m, sim


BASELINES: Dict[str, dict] = {
    "ragcache": {},
    "vllm": dict(gpu_cache_bytes=0, host_cache_bytes=0,
                 reorder=False, speculative=False),
    "sglang": dict(host_cache_bytes=0, policy="lru",
                   reorder=False, speculative=False),
}
