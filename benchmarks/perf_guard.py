"""Perf-regression guard over ``benchmarks.run --json`` artifacts.

The CI slow lane uploads ``bench_smoke.json`` per main commit; this module
compares the current run against the previous main artifact (when one can
be downloaded) and fails on a >``max_ratio`` regression of the tracked
smoke-TTFT rows.  Tolerant by design:

  * no baseline (first run, expired artifact, download failed) -> pass,
    but LOUDLY: a ``::warning::`` annotation + step-summary line name the
    missing baseline so a broken artifact upload can't mute the gate
    silently;
  * rows missing from either side (benchmarks added/removed) -> ignored;
  * error/system rows (``*/ERROR``, ``*/_total`` wall times) -> ignored —
    wall time on a shared runner is noise, the analytic simulator TTFTs
    are not.

Only rows whose names match ``TRACKED`` prefixes guard: these are
simulator-computed TTFT figures (deterministic given the config), so a 2x
jump is a real policy/cost-model regression, not runner jitter.

Usage (what ci.yml runs):
    python -m benchmarks.perf_guard baseline.json current.json
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Tuple

# analytic (simulator) TTFT rows — deterministic, safe to gate on
TRACKED = (
    "fig_cag/",
    "fig_frontdoor/",
    "fig_replica/",
    "fig_tp/",
    "fig13_",
    "kernel/prefill_paged/",
)
MAX_RATIO = 2.0
# smoke rows below this are dominated by fixed overheads; a ratio on a
# near-zero denominator is meaningless
MIN_BASELINE_US = 100.0


def _rows(doc: dict) -> Dict[str, float]:
    out: Dict[str, float] = {}
    for row in doc.get("rows", []):
        name = row.get("name", "")
        if name.endswith(("/_total", "/ERROR")):
            continue
        if not name.startswith(TRACKED):
            continue
        try:
            us = float(row.get("us_per_call", 0.0))
        except (TypeError, ValueError):
            continue
        if us > 0.0:
            out[name] = us
    return out


def compare(baseline: dict, current: dict, *,
            max_ratio: float = MAX_RATIO
            ) -> Tuple[List[str], List[str]]:
    """(regressions, notes).  Empty regressions list = pass."""
    base = _rows(baseline)
    cur = _rows(current)
    if baseline.get("smoke") != current.get("smoke"):
        return [], ["baseline and current ran at different sizes "
                    "(smoke flag differs); skipping comparison"]
    regressions, notes = [], []
    for name in sorted(set(base) & set(cur)):
        b, c = base[name], cur[name]
        if b < MIN_BASELINE_US:
            continue
        ratio = c / b
        line = f"{name}: {b:.1f} -> {c:.1f} us ({ratio:.2f}x)"
        if ratio > max_ratio:
            regressions.append(line)
        else:
            notes.append(line)
    only = sorted(set(cur) - set(base))
    if only:
        notes.append(f"new rows (no baseline): {', '.join(only)}")
    if not set(base) & set(cur):
        notes.append("no comparable rows between baseline and current")
    return regressions, notes


def main(argv: List[str]) -> int:
    if len(argv) != 2:
        print("usage: python -m benchmarks.perf_guard "
              "baseline.json current.json", file=sys.stderr)
        return 2
    base_path, cur_path = argv
    try:
        with open(base_path) as f:
            baseline = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        # missing/unreadable baseline is NOT a failure: the first main run
        # after this lands has nothing to compare against.  But pass LOUDLY
        # — a broken artifact upload would otherwise disable this gate
        # invisibly on every subsequent run.
        print(f"perf_guard: no usable baseline ({e}); passing")
        msg = (f"perf_guard: baseline '{base_path}' missing/unreadable "
               f"({e}); regression gate SKIPPED this run")
        print(f"::warning title=perf_guard baseline missing::{msg}")
        summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
        if summary_path:
            try:
                with open(summary_path, "a") as f:
                    f.write(f":warning: {msg}\n")
            except OSError:
                pass    # a broken summary sink must not flip the verdict
        return 0
    with open(cur_path) as f:
        current = json.load(f)
    regressions, notes = compare(baseline, current)
    for line in notes:
        print(f"perf_guard: {line}")
    if regressions:
        print(f"perf_guard: FAIL — >{MAX_RATIO}x smoke-TTFT regression:")
        for line in regressions:
            print(f"perf_guard:   {line}")
        return 1
    print("perf_guard: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
