"""Paper Fig. 16: large models (Mixtral-8x7B, LLaMA2-70B) on 2x H800.
Paper claim: 1.4-2.1x lower TTFT vs vLLM at low rates; SLO holds longer."""
from __future__ import annotations

from benchmarks.common import BASELINES, PROFILES, corpus_and_index, \
    simulate, workload


def run() -> list:
    corpus, idx = corpus_and_index()
    rows = []
    for model, max_bs, rates in (("mixtral-8x7b", 8, (0.5, 1.0, 2.0)),
                                 ("llama2-70b", 4, (0.5, 1.0, 1.5))):
        prof = PROFILES[model]
        best = 0.0
        for rate in rates:
            wl = workload(corpus, n=150, rate=rate, zipf=1.0, seed=13)
            t = {}
            for name in ("ragcache", "vllm"):
                kw = dict(BASELINES[name])
                kw.update(max_batch=max_bs,
                          host_cache_bytes=(384 * 2**30 if name == "ragcache"
                                            else 0))
                m, _ = simulate(corpus, idx, wl, profile=prof, **kw)
                t[name] = m.avg_ttft
                rows.append((f"fig16/{model}/{name}/rate{rate}",
                             m.avg_ttft * 1e6, f"hit={m.doc_hit_rate:.2f}"))
            best = max(best, t["vllm"] / t["ragcache"])
        rows.append((f"fig16/{model}/claim", best,
                     f"paper 1.4-2.1x got={best:.2f}x"))
    return rows
