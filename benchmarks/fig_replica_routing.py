"""Multi-replica routing sweep: N replicas × routing policy.

The knowledge tree only pays off when a request lands where its document
prefix is already resident; scattering a Zipf-skewed workload across
replicas (round-robin) recomputes every popular document once PER replica,
while doc-affinity routing keeps each document's tree path hot on exactly
one replica (Cache-Craft, arXiv 2502.15734; placement trade-offs, arXiv
2412.11854).  This sweep drives the SAME ``ReplicaRouter`` policy object
the real driver uses over N simulated replicas and asserts the headline
claims:

  * affinity routing beats round-robin on GPU-tier cache-hit tokens at
    every N > 1 (the escape hatch may cede a little to pure affinity, but
    never below scatter);
  * the escape hatch keeps the observed per-replica queue skew within the
    configured bound.
"""
from __future__ import annotations

from benchmarks.common import PROFILES, smoke_clamp
from repro.retrieval.corpus import make_corpus, make_workload
from repro.retrieval.vectordb import IVFIndex
from repro.serving.simulator import SimConfig, simulate_replicas

PROFILE = PROFILES["mistral-7b"]
POLICIES = ("affinity", "round_robin", "least_loaded")
MAX_QUEUE_SKEW = 4


def _setup():
    # the smoke trace must stay long enough for affinity's grouping to
    # amortize the escape hatch's one-time doc replications (the sim is
    # analytic, so 100 requests cost CI nothing)
    n_docs = smoke_clamp(600, 80)
    corpus = make_corpus(n_docs, mean_doc_tokens=smoke_clamp(800, 120),
                         seed=0)
    idx = IVFIndex(corpus.doc_vectors, n_clusters=max(4, n_docs // 12),
                   nprobe=8, seed=0)
    wl = make_workload(corpus, n_requests=smoke_clamp(240, 100), rate=1.0,
                       zipf_s=1.3, output_len_mean=2, seed=1)
    return corpus, idx, wl


def run() -> list:
    corpus, idx, wl = _setup()
    cfg_kw = dict(profile=PROFILE, top_k=2,
                  gpu_cache_bytes=4 * 2**30, host_cache_bytes=32 * 2**30)
    rows = []
    gpu_hits = {}                   # (n, policy) -> gpu-tier hit tokens
    for n in (1, 2, 4):
        for pol in POLICIES:
            if n == 1 and pol != "affinity":
                continue            # one replica: every policy is identical
            fleet = simulate_replicas(
                SimConfig(**cfg_kw), corpus, idx, wl,
                n_replicas=n, routing=pol, max_queue_skew=MAX_QUEUE_SKEW)
            m = fleet.metrics
            rs = fleet.router_stats
            gpu_hits[(n, pol)] = m.hit_tokens_gpu
            rows.append((
                f"fig_replica/n{n}_{pol}", m.avg_ttft * 1e6,
                f"gpu_hit_tok={m.hit_tokens_gpu} hit={m.doc_hit_rate:.2f} "
                f"p99={m.p99_ttft:.3f}s routed={rs['routed']} "
                f"escaped={rs['escaped']} skew={rs['max_skew_observed']}"))
            # the escape hatch's contract, asserted on every swept point
            assert rs["max_skew_observed"] <= MAX_QUEUE_SKEW, (
                f"n={n} {pol}: observed queue skew "
                f"{rs['max_skew_observed']} > bound {MAX_QUEUE_SKEW}")

    # headline: affinity >= round-robin on GPU-tier cache-hit tokens
    for n in (2, 4):
        aff, rr = gpu_hits[(n, "affinity")], gpu_hits[(n, "round_robin")]
        assert aff >= rr, (
            f"N={n}: affinity routing hit {aff} GPU-tier tokens < "
            f"round-robin {rr} — doc affinity stopped paying for itself")
        rows.append((f"fig_replica/claim/n{n}_affinity_vs_rr",
                     float(aff), f"affinity={aff} >= round_robin={rr} "
                     f"({aff / max(rr, 1):.2f}x)"))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
