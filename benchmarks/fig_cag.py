"""CAG vs RAG workload-mode A/B: TTFT and throughput across corpus sizes
and hit skews.

Cache-augmented generation ("Don't Do RAG", arXiv 2412.15605) preloads the
FULL corpus KV and answers with no retrieval stage at all.  RAGCache's
knowledge tree already holds per-doc KV states, so CAG is a residency
policy, not a new engine: ``mode="cag"`` pre-inserts every doc into the
disk tier at startup and each request's docs resolve as tier hits promoted
through the same PGDSF cascade (docs/ARCHITECTURE.md §12).

The sweep compares, per (corpus size, zipf skew):
  - full recompute (no cache at all) — the floor every tier must beat,
  - RAG with a tiered budget (staged retrieval + speculative overlap),
  - CAG with the disk tier sized to the whole corpus.

Headline (asserted): disk-resident CAG TTFT stays strictly below full
recompute — pre-inserted KV only earns its disk residency while NVMe fetch
beats per-token attention recompute.  Long-document regime on purpose: the
fetch-vs-recompute crossover needs thousands of cached tokens per path
(token counts are analytic inputs and cost the simulator nothing).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks import common
from benchmarks.common import PROFILES, simulate, smoke_clamp, workload
from repro.retrieval.corpus import make_corpus
from repro.retrieval.vectordb import IVFIndex

# A10G + local NVMe RAID (same storage-heavy deployment fig_tiered targets)
PROFILE = dataclasses.replace(PROFILES["mistral-7b"],
                              name="a10g-mistral-7b-nvme",
                              disk_bytes_per_s=12e9)

TOP_K = 4
MEAN_DOC = 6000
CORPUS_SIZES = [24, 48, 96]       # docs (smoke clamps to the first)
ZIPFS = [1.1, 1.6]                # flat-ish vs heavily skewed popularity


def _setup(n_docs: int, zipf: float):
    corpus = make_corpus(n_docs, mean_doc_tokens=MEAN_DOC, seed=0)
    idx = IVFIndex(corpus.doc_vectors, n_clusters=max(4, n_docs // 8),
                   nprobe=8, seed=0)
    wl = workload(corpus, n=smoke_clamp(64, 20), rate=0.5, zipf=zipf,
                  out_len=2, seed=1)
    corpus_bytes = int(corpus.doc_lengths.sum()
                       * PROFILE.kv_bytes_per_token)
    path_bytes = TOP_K * MEAN_DOC * PROFILE.kv_bytes_per_token
    return corpus, idx, wl, corpus_bytes, path_bytes


def run() -> list:
    rows = []
    cag_ttfts, recompute_ttfts = [], []
    sizes = CORPUS_SIZES[:1] if common.SMOKE else CORPUS_SIZES
    for n_docs in sizes:
        for zipf in ZIPFS:
            corpus, idx, wl, corpus_bytes, path_bytes = _setup(n_docs, zipf)
            gpu = int(1.25 * path_bytes)     # ~one pinned path + slack
            tag = f"docs{n_docs}_zipf{zipf:g}"

            base, _ = simulate(corpus, idx, wl, profile=PROFILE,
                               top_k=TOP_K, gpu_cache_bytes=0,
                               host_cache_bytes=0, disk_cache_bytes=0)
            rows.append((f"fig_cag/recompute/{tag}", base.avg_ttft * 1e6,
                         f"ttft_s={base.avg_ttft:.3f}"))

            rag, _ = simulate(corpus, idx, wl, profile=PROFILE,
                              top_k=TOP_K, gpu_cache_bytes=gpu,
                              host_cache_bytes=gpu,
                              disk_cache_bytes=4 * gpu)
            rows.append((f"fig_cag/rag/{tag}", rag.avg_ttft * 1e6,
                         f"hit={rag.doc_hit_rate:.2f} "
                         f"stages={rag.retrieval_stages} "
                         f"ttft_s={rag.avg_ttft:.3f}"))

            cag, sim = simulate(corpus, idx, wl, profile=PROFILE,
                                mode="cag", top_k=TOP_K,
                                gpu_cache_bytes=gpu, host_cache_bytes=gpu,
                                disk_cache_bytes=corpus_bytes)
            assert cag.retrieval_stages == 0, (
                "CAG ran retrieval stages — the degenerate-overlap "
                "invariant is broken")
            assert sim.preload_stats["docs"] == n_docs
            rows.append((f"fig_cag/cag/{tag}", cag.avg_ttft * 1e6,
                         f"hit={cag.doc_hit_rate:.2f} stages=0 "
                         f"preload_B={sim.preload_stats['bytes']} "
                         f"ttft_s={cag.avg_ttft:.3f} "
                         f"tput={cag.throughput_rps:.2f}rps"))
            cag_ttfts.append(cag.avg_ttft)
            recompute_ttfts.append(base.avg_ttft)

    # headline: disk-resident CAG must beat computing every context cold,
    # else preloading the corpus is pure overhead — asserted (deterministic
    # analytic sim; CI smoke runs it)
    cag_ttft = float(np.mean(cag_ttfts))
    recompute_ttft = float(np.mean(recompute_ttfts))
    assert cag_ttft < recompute_ttft, (
        f"CAG TTFT {cag_ttft:.3f}s >= full recompute "
        f"{recompute_ttft:.3f}s — preloaded fetch no longer beats "
        f"recompute")
    rows.append(("fig_cag/claim/cag_vs_recompute", cag_ttft * 1e6,
                 f"cag_ttft={cag_ttft:.3f}s < "
                 f"recompute={recompute_ttft:.3f}s "
                 f"({recompute_ttft / cag_ttft:.2f}x)"))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
