"""Tensor-parallel scaling sweep: one replica at tp = 1 / 2 / 4.

Drives the analytic simulator with ``SimConfig.tp`` (service times from
``HardwareProfile.with_tp``: compute and HBM/PCIe bandwidth scale by tp,
every forward pays a ring all-reduce term that GROWS with tp) over the
same Zipf workload and asserts the headline shape of TP serving:

  * TTFT strictly improves with tp (prefill is compute-bound, decode and
    promote/demote copies are bandwidth-bound — all shard);
  * per-request SERVICE time scales SUB-linearly (the collective term
    does not shard), so tp4 gains less per device than tp2 — while e2e
    TTFT may beat linear because queueing delay drains on top;
  * the cache keeps paying at every tp (exact hit counts shift with tp
    here because PGDSF priorities rescale with service times; the real
    engines prove bit-exact tp-invariance in tests/test_tp_serving.py).

Rows are deterministic simulator TTFTs -> tracked by perf_guard.
"""
from __future__ import annotations

from benchmarks.common import PROFILES, simulate, smoke_clamp
from repro.retrieval.corpus import make_corpus, make_workload
from repro.retrieval.vectordb import IVFIndex

PROFILE = PROFILES["mistral-7b"]
TPS = (1, 2, 4)


def _setup():
    n_docs = smoke_clamp(600, 80)
    corpus = make_corpus(n_docs, mean_doc_tokens=smoke_clamp(800, 120),
                         seed=0)
    idx = IVFIndex(corpus.doc_vectors, n_clusters=max(4, n_docs // 12),
                   nprobe=8, seed=0)
    wl = make_workload(corpus, n_requests=smoke_clamp(240, 100), rate=2.0,
                       zipf_s=1.2, output_len_mean=4, seed=1)
    return corpus, idx, wl


def run() -> list:
    corpus, idx, wl = _setup()
    rows, ttft, hits = [], {}, {}
    for tp in TPS:
        m, _ = simulate(corpus, idx, wl, profile=PROFILE, tp=tp, top_k=2,
                        gpu_cache_bytes=4 * 2**30,
                        host_cache_bytes=32 * 2**30)
        ttft[tp], hits[tp] = m.avg_ttft, m.hit_tokens_gpu
        rows.append((
            f"fig_tp/tp{tp}", m.avg_ttft * 1e6,
            f"p99={m.p99_ttft:.3f}s tpot={m.avg_tpot * 1e3:.1f}ms "
            f"hit={m.doc_hit_rate:.2f} gpu_hit_tok={m.hit_tokens_gpu}"))

    # headline 1: TTFT strictly improves with tp
    assert ttft[1] > ttft[2] > ttft[4], (
        f"TP stopped paying: ttft {ttft}")
    # headline 2: SERVICE-time scaling is sub-linear — the all-reduce term
    # does not shard.  (End-to-end TTFT can scale SUPER-linearly: halving
    # service time also drains queueing delay, so the TTFT ratio routinely
    # beats 2x under load and is the wrong quantity to bound.)
    svc = {tp: PROFILE.with_tp(tp).prefill_time(1024, 1024) for tp in TPS}
    s2, s4 = svc[1] / svc[2], svc[1] / svc[4]
    assert s2 < 2.0 and s4 < 4.0 and s4 < 2 * s2, (
        f"service speedups {s2:.2f}x/{s4:.2f}x exceed the collective-bounded"
        f" model: {svc}")
    rows.append(("fig_tp/claim/sublinear_speedup", float(s4 * 1e3),
                 f"service tp2={s2:.2f}x tp4={s4:.2f}x (linear: 2x/4x); "
                 f"e2e ttft tp2={ttft[1] / ttft[2]:.2f}x "
                 f"tp4={ttft[1] / ttft[4]:.2f}x (queueing drains on top)"))
    # headline 3: the cache keeps paying at every tp.  (Exact hit counts
    # are NOT tp-invariant in the analytic simulator: PGDSF priorities are
    # computed from measured service times, which with_tp rescales, so
    # eviction order shifts with tp.  The bit-exact claim — sharding never
    # changes what the knowledge tree hits — belongs to the real engines
    # and is asserted per-request in tests/test_tp_serving.py.)
    assert min(hits.values()) > 0, f"cache stopped hitting: {hits}"
    return rows
