"""Kernel-layer bench: Pallas prefix-attention grid/VMEM accounting + CPU
oracle agreement, and the jnp flash path wall-clock (the actual CPU compute
path; interpret-mode kernel timing is not meaningful).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.models import layers as L


def run() -> list:
    rows = []
    # VMEM footprint per grid cell for production tile sizes
    for (bq, bk, hd) in ((128, 128, 128), (256, 512, 128), (128, 128, 256)):
        vmem = (bq * hd + 2 * bk * hd) * 2 + (bq * hd + 2 * bq) * 4 \
            + bq * bk * 4
        rows.append((f"kernel/prefix_attn/tile_q{bq}_k{bk}_hd{hd}",
                     vmem / 1024,
                     f"vmem_kib={vmem / 1024:.0f} fits_16MiB="
                     f"{vmem < 16 * 2**20}"))
    # correctness spot check (interpret mode)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    B, H, KV, Sq, P, hd = 1, 4, 2, 32, 32, 64
    q = jax.random.normal(k1, (B, H, Sq, hd), jnp.float32)
    k = jax.random.normal(k2, (B, KV, P + Sq, hd), jnp.float32)
    v = jax.random.normal(k3, (B, KV, P + Sq, hd), jnp.float32)
    t0 = time.perf_counter()
    out = ops.prefix_attention(q, k, v, prefix_len=P, block_q=16, block_k=16,
                               interpret=True)
    dt = time.perf_counter() - t0
    err = float(jnp.abs(
        out - ref.reference_prefix_attention(q, k, v, prefix_len=P)).max())
    rows.append(("kernel/prefix_attn/interpret_allclose", dt * 1e6,
                 f"max_err={err:.1e} ok={err < 1e-4}"))
    # jnp flash wall clock (CPU execution path used by the tiny engine)
    qf = q.transpose(0, 2, 1, 3)
    kf = k.transpose(0, 2, 1, 3)
    vf = v.transpose(0, 2, 1, 3)
    fn = jax.jit(lambda q, k, v: L.flash_attention(q, k, v, q_offset=P))
    fn(qf, kf, vf).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        fn(qf, kf, vf).block_until_ready()
    rows.append(("kernel/flash_jnp/cpu_wallclock",
                 (time.perf_counter() - t0) / 10 * 1e6, "jit path"))
    return rows
