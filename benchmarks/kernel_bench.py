"""Kernel-layer bench: Pallas prefix-attention grid/VMEM accounting + CPU
oracle agreement, the jnp flash path wall-clock (the actual CPU compute
path; interpret-mode kernel timing is not meaningful), and the serving-shape
decode comparison: dense-gather ``decode_step`` vs kernel-backed
``paged_decode_step`` straight from the pool (the `--attn dense|paged` A/B
that PR 5 wired into the runtime).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import smoke_clamp
from repro.configs import get_reduced
from repro.kernels import ops, ref
from repro.models import layers as L
from repro.models import model as M


def _paged_decode_rows() -> list:
    """Dense-gather vs paged decode at serving shapes: one decode iteration
    of the reduced model, B requests of ctx tokens in a 16-token-block pool
    (the continuous runtime's exact layout), steady-state (post-jit)."""
    rows = []
    cfg = get_reduced("qwen2-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    bs = 16
    B = 4
    ctx = smoke_clamp(512, 64)
    reps = smoke_clamp(10, 2)
    nb_req = -(-ctx // bs)
    n_blocks = B * nb_req + 1                       # block 0 = scratch
    S = nb_req * bs
    key = jax.random.PRNGKey(1)
    kp = jax.random.normal(key, (cfg.n_layers, n_blocks, bs, cfg.n_kv_heads,
                                 cfg.hd))
    vp = kp * 0.5
    toks = jnp.ones((B, 1), jnp.int32)
    pos = jnp.full((B,), ctx, jnp.int32)            # ctx incl. the new token
    # request b owns blocks [1 + b*nb_req, ...) — contiguous runs
    tables = np.asarray([[1 + b * nb_req + j for j in range(nb_req)]
                         for b in range(B)], np.int32)
    blk_map = np.repeat(tables, bs, axis=1)         # (B, S) token-level maps
    slot_map = np.tile(np.arange(S, dtype=np.int32) % bs, (B, 1))
    counts = np.full((B, nb_req), bs, np.int32)
    counts[:, -1] = ctx - (nb_req - 1) * bs
    starts = np.asarray([[j * bs for j in range(nb_req)]] * B, np.int32)
    wblk = tables[:, (ctx - 1) // bs]
    wslot = np.full((B,), (ctx - 1) % bs, np.int32)

    def dense_step(params, toks, blk_map, slot_map, lengths, kp, vp):
        k = kp[:, blk_map, slot_map]                # (L, B, S, KV, hd)
        v = vp[:, blk_map, slot_map]
        logits, _ = M.decode_step(cfg, params, toks, {"k": k, "v": v},
                                  lengths + 1)
        return jnp.argmax(logits[:, -1], axis=-1)

    def paged_step(params, toks, tables, counts, starts, pos, wblk, wslot,
                   kp, vp):
        logits, kp, vp = M.paged_decode_step(
            cfg, params, toks, kp, vp, tables, counts, starts, wblk, wslot,
            pos, attn_impl="jnp")
        return jnp.argmax(logits[:, -1], axis=-1), kp, vp

    dense = jax.jit(dense_step)
    paged = jax.jit(paged_step, donate_argnums=(8, 9))
    lengths = pos - 1
    args_d = (jnp.asarray(toks), jnp.asarray(blk_map), jnp.asarray(slot_map),
              jnp.asarray(lengths))
    args_p = (jnp.asarray(toks), jnp.asarray(tables), jnp.asarray(counts),
              jnp.asarray(starts), jnp.asarray(pos), jnp.asarray(wblk),
              jnp.asarray(wslot))
    dense(params, *args_d, kp, vp).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out_d = dense(params, *args_d, kp, vp)
    out_d.block_until_ready()
    dt_d = (time.perf_counter() - t0) / reps
    _, kp, vp = paged(params, *args_p, kp, vp)      # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out_p, kp, vp = paged(params, *args_p, kp, vp)
    out_p.block_until_ready()
    dt_p = (time.perf_counter() - t0) / reps
    if not bool((np.asarray(out_d) == np.asarray(out_p)).all()):
        # hard-fail the smoke lane: paged vs dense greedy-token divergence
        # is the regression this bench exists to catch, not a number to log
        raise RuntimeError(
            f"paged decode diverged from dense decode at bench shapes: "
            f"dense={np.asarray(out_d).tolist()} "
            f"paged={np.asarray(out_p).tolist()}")
    gathered = cfg.n_layers * B * S * cfg.n_kv_heads * cfg.hd
    rows.append((f"kernel/decode_dense_gather/B{B}_ctx{ctx}", dt_d * 1e6,
                 f"dense_elems={gathered} per_iter"))
    rows.append((f"kernel/decode_paged/B{B}_ctx{ctx}", dt_p * 1e6,
                 f"speedup_vs_dense={dt_d / max(dt_p, 1e-12):.2f}x "
                 f"tokens_match=True"))
    return rows


def _paged_prefill_rows() -> list:
    """Dense re-materialization vs paged ragged prefill at serving shapes:
    one chunked-prefill iteration of B requests, each with a cached prefix
    resident in the pool.  The dense baseline is the retired steady-state
    path — gather the prefix pages into a dense (L, 1, pref, KV, hd) cache
    and run a concat prefill per request (the dense engine runs one request
    per iteration); the paged path is ONE batched ``paged_prefill_step``
    reading the prefix pages in place and scattering the chunk KV into its
    own pages."""
    rows = []
    cfg = get_reduced("qwen2-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    bs = 16
    B = 4
    pref = smoke_clamp(256, 48)     # cached prefix tokens per request
    n = smoke_clamp(64, 16)         # chunk tokens per request
    reps = smoke_clamp(10, 2)
    total = pref + n
    nb_req = -(-total // bs)
    n_blocks = B * nb_req + 1                       # block 0 = scratch
    key = jax.random.PRNGKey(3)
    kp = jax.random.normal(key, (cfg.n_layers, n_blocks, bs, cfg.n_kv_heads,
                                 cfg.hd), cfg.jdtype)
    vp = kp * 0.5
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, size=(B, n)).astype(np.int32)
    tables = np.asarray([[1 + b * nb_req + j for j in range(nb_req)]
                         for b in range(B)], np.int32)
    counts = np.full((B, nb_req), bs, np.int32)
    counts[:, -1] = total - (nb_req - 1) * bs
    starts = np.asarray([[j * bs for j in range(nb_req)]] * B, np.int32)
    pos = np.arange(pref, total)
    wblk = tables[:, pos // bs]
    wslot = np.tile((pos % bs).astype(np.int32), (B, 1))
    blk_map = np.repeat(tables, bs, axis=1)[:, :pref]
    slot_map = np.tile(np.arange(nb_req * bs, dtype=np.int32) % bs,
                       (B, 1))[:, :pref]

    def dense_one(params, toks_b, blk_b, slot_b, kp, vp):
        pc = {"k": kp[:, blk_b, slot_b], "v": vp[:, blk_b, slot_b]}
        logits, _ = M.prefill(cfg, params, {"tokens": toks_b},
                              prefix_cache=pc, prefix_len=pref)
        return jnp.argmax(logits[:, -1], axis=-1)

    def paged_step(params, toks, tables, counts, starts, qs, ql, wblk, wslot,
                   kp, vp):
        logits, kp, vp = M.paged_prefill_step(
            cfg, params, toks, kp, vp, tables, counts, starts, qs, ql,
            wblk, wslot, attn_impl="jnp")
        return jnp.argmax(logits[:, 0], axis=-1), kp, vp

    dense = jax.jit(dense_one)
    paged = jax.jit(paged_step, donate_argnums=(9, 10))
    args_d = [(jnp.asarray(toks[b:b + 1]), jnp.asarray(blk_map[b:b + 1]),
               jnp.asarray(slot_map[b:b + 1])) for b in range(B)]
    args_p = (jnp.asarray(toks), jnp.asarray(tables), jnp.asarray(counts),
              jnp.asarray(starts), jnp.full((B,), pref, jnp.int32),
              jnp.full((B,), n, jnp.int32), jnp.asarray(wblk),
              jnp.asarray(wslot))
    out_d = jnp.concatenate([dense(params, *a, kp, vp) for a in args_d])
    out_d.block_until_ready()                       # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out_d = jnp.concatenate([dense(params, *a, kp, vp) for a in args_d])
    out_d.block_until_ready()
    dt_d = (time.perf_counter() - t0) / reps
    _, kp, vp = paged(params, *args_p, kp, vp)      # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out_p, kp, vp = paged(params, *args_p, kp, vp)
    out_p.block_until_ready()
    dt_p = (time.perf_counter() - t0) / reps
    if not bool((np.asarray(out_d) == np.asarray(out_p)).all()):
        # hard-fail the smoke lane, exactly like the decode A/B above
        raise RuntimeError(
            f"paged prefill diverged from dense prefill at bench shapes: "
            f"dense={np.asarray(out_d).tolist()} "
            f"paged={np.asarray(out_p).tolist()}")
    gathered = cfg.n_layers * B * pref * cfg.n_kv_heads * cfg.hd
    rows.append((f"kernel/prefill_dense_gather/B{B}_pref{pref}_n{n}",
                 dt_d * 1e6, f"dense_elems={gathered} per_iter"))
    rows.append((f"kernel/prefill_paged/B{B}_pref{pref}_n{n}", dt_p * 1e6,
                 f"speedup_vs_dense={dt_d / max(dt_p, 1e-12):.2f}x "
                 f"tokens_match=True"))
    return rows


def run() -> list:
    rows = []
    # VMEM footprint per grid cell for production tile sizes
    for (bq, bk, hd) in ((128, 128, 128), (256, 512, 128), (128, 128, 256)):
        vmem = (bq * hd + 2 * bk * hd) * 2 + (bq * hd + 2 * bq) * 4 \
            + bq * bk * 4
        rows.append((f"kernel/prefix_attn/tile_q{bq}_k{bk}_hd{hd}",
                     vmem / 1024,
                     f"vmem_kib={vmem / 1024:.0f} fits_16MiB="
                     f"{vmem < 16 * 2**20}"))
    # correctness spot check (interpret mode)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    B, H, KV, Sq, P, hd = 1, 4, 2, 32, 32, 64
    q = jax.random.normal(k1, (B, H, Sq, hd), jnp.float32)
    k = jax.random.normal(k2, (B, KV, P + Sq, hd), jnp.float32)
    v = jax.random.normal(k3, (B, KV, P + Sq, hd), jnp.float32)
    t0 = time.perf_counter()
    out = ops.prefix_attention(q, k, v, prefix_len=P, block_q=16, block_k=16,
                               interpret=True)
    dt = time.perf_counter() - t0
    err = float(jnp.abs(
        out - ref.reference_prefix_attention(q, k, v, prefix_len=P)).max())
    rows.append(("kernel/prefix_attn/interpret_allclose", dt * 1e6,
                 f"max_err={err:.1e} ok={err < 1e-4}"))
    # jnp flash wall clock (CPU execution path used by the tiny engine)
    qf = q.transpose(0, 2, 1, 3)
    kf = k.transpose(0, 2, 1, 3)
    vf = v.transpose(0, 2, 1, 3)
    fn = jax.jit(lambda q, k, v: L.flash_attention(q, k, v, q_offset=P))
    fn(qf, kf, vf).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        fn(qf, kf, vf).block_until_ready()
    rows.append(("kernel/flash_jnp/cpu_wallclock",
                 (time.perf_counter() - t0) / 10 * 1e6, "jit path"))
    rows.extend(_paged_decode_rows())
    rows.extend(_paged_prefill_rows())
    return rows
