"""Chunk-cache vs prefix-cache hit rate under top-k order churn.

RAGCache's knowledge tree reuses *prefix* paths: a cached doc that
reappears at a different position in the retrieved sequence recomputes
from scratch.  This sweep builds the adversarial-but-common workload —
the same hot documents retrieved in a per-request SHUFFLED order (vector
stores tie-break and re-rank; multi-doc queries churn) — and A/Bs
``reuse="prefix"`` against ``reuse="chunk"`` (docs/ARCHITECTURE.md §11:
per-doc chunk cache, reused at any position, first ``recompute_tokens``
boundary rows recomputed per relocated chunk).

The affinity router cannot save prefix mode here: routing keys on doc
*sets*, so all permutations of a hot set land on the same replica and
still miss the prefix tree.  The ``prefix_affinity2`` row demonstrates
exactly that.

Headline claim (asserted, CI smoke runs it): chunk mode strictly
increases cached-hit tokens over prefix mode on the shuffled workload.
Token-level correctness of the approximation is covered by
tests/test_chunk_reuse.py (--check-tokens tol:<eps> on the real engine).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import PROFILES, simulate, smoke_clamp, workload
from repro.retrieval.corpus import make_corpus
from repro.retrieval.vectordb import IVFIndex
from repro.serving.router import AFFINITY
from repro.serving.simulator import SimConfig, simulate_replicas

PROFILE = PROFILES["mistral-7b"]
TOP_K = 4
RECOMPUTE_TOKENS = 64
BLOCK_SIZE = 16


class ShuffledIndex:
    """Wraps a vector index, permuting each query's top-k deterministically
    (seeded by the query vector), so repeated retrievals of the same hot doc
    set arrive in churned order — prefix reuse dies, chunk reuse doesn't.
    ``search`` and ``staged_search`` apply the SAME permutation, so final
    docs agree across both entry points (router partition vs simulator)."""

    def __init__(self, base):
        self.base = base
        self.scan_bytes_per_s = base.scan_bytes_per_s

    def _perm(self, q: np.ndarray, k: int) -> np.ndarray:
        seed = int(np.abs(np.asarray(q, np.float32)).sum() * 1e4) % (2**31)
        return np.random.default_rng(seed).permutation(k)

    def search(self, q, k, fraction: float = 1.0):
        out = self.base.search(q, k, fraction)
        return [out[i] for i in self._perm(q, len(out))]

    def staged_search(self, q, k, fraction: float = 1.0):
        import dataclasses
        for st in self.base.staged_search(q, k, fraction):
            p = self._perm(q, len(st.topk))
            yield dataclasses.replace(
                st, topk=tuple(st.topk[i] for i in p))


def _setup():
    n_docs = smoke_clamp(80, 40)
    corpus = make_corpus(n_docs, mean_doc_tokens=1200, seed=0)
    base = IVFIndex(corpus.doc_vectors, n_clusters=max(4, n_docs // 8),
                    nprobe=8, seed=0)
    idx = ShuffledIndex(base)
    wl = workload(corpus, n=smoke_clamp(120, 25), rate=2.0, zipf=1.3,
                  out_len=2, seed=1)
    return corpus, idx, wl


def _hit_tokens(m) -> int:
    return m.hit_tokens_gpu + m.hit_tokens_host + m.hit_tokens_disk


def run() -> list:
    corpus, idx, wl = _setup()
    rows = []
    common = dict(profile=PROFILE, top_k=TOP_K, gpu_cache_bytes=8 * 2**30,
                  host_cache_bytes=64 * 2**30)

    prefix, _ = simulate(corpus, idx, wl, reuse="prefix", **common)
    chunk, _ = simulate(corpus, idx, wl, reuse="chunk",
                        recompute_tokens=RECOMPUTE_TOKENS,
                        block_size=BLOCK_SIZE, **common)
    for name, m in (("prefix", prefix), ("chunk", chunk)):
        rows.append((f"fig_chunk_reuse/{name}", m.avg_ttft * 1e6,
                     f"hit={m.doc_hit_rate:.3f} "
                     f"hit_tokens={_hit_tokens(m)} "
                     f"ttft_s={m.avg_ttft:.3f}"))

    # the affinity router cannot rescue prefix mode: permutations of one hot
    # doc set share an affinity key, land on one replica, and still miss
    fleet = simulate_replicas(
        SimConfig(**common, reuse="prefix"), corpus, idx, wl,
        n_replicas=2, routing=AFFINITY)
    pa = fleet.metrics
    rows.append(("fig_chunk_reuse/prefix_affinity2", pa.avg_ttft * 1e6,
                 f"hit={pa.doc_hit_rate:.3f} hit_tokens={_hit_tokens(pa)}"))

    # headline: chunk mode must strictly increase cached-hit tokens on the
    # shuffled workload — the whole point of position-independent reuse
    ht_p, ht_c = _hit_tokens(prefix), _hit_tokens(chunk)
    assert ht_c > ht_p, (
        f"chunk-cache hit tokens {ht_c} <= prefix {ht_p} on shuffled "
        f"top-k — position-independent reuse is broken")
    mult = ht_c / max(ht_p, 1)
    rows.append(("fig_chunk_reuse/claim/hit_token_multiplier", mult * 1e6,
                 f"chunk={ht_c} prefix={ht_p} ({mult:.1f}x) "
                 f"doc_hit {prefix.doc_hit_rate:.3f}->"
                 f"{chunk.doc_hit_rate:.3f} "
                 f"ttft {prefix.avg_ttft:.3f}s->{chunk.avg_ttft:.3f}s"))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
