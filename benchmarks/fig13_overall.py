"""Paper Fig. 13/14: overall TTFT & throughput — RAGCache vs vLLM vs SGLang
on MMLU-like (1 output token) and NQ-like (~6 output tokens) workloads,
Mistral-7B and LLaMA2-7B A10G profiles.

Paper claims: 1.2-4x lower TTFT vs vLLM, 1.1-3.5x vs SGLang;
1.3-2.1x / 1.2-1.8x higher throughput.
"""
from __future__ import annotations


from benchmarks.common import (BASELINES, PROFILES, corpus_and_index,
                               simulate, workload)

RATES = (0.4, 0.8, 1.2)


def _sweep(model: str, out_len: int, tag: str):
    corpus, idx = corpus_and_index()
    prof = PROFILES[model]
    rows = []
    best_vs = {"vllm": 0.0, "sglang": 0.0}
    for rate in RATES:
        wl = workload(corpus, n=250, rate=rate, zipf=1.0, out_len=out_len,
                      seed=7)
        ttfts = {}
        for name, kw in BASELINES.items():
            m, _ = simulate(corpus, idx, wl, profile=prof, **kw)
            ttfts[name] = m.avg_ttft
            rows.append((f"{tag}/{model}/{name}/rate{rate}",
                         m.avg_ttft * 1e6,
                         f"ttft={m.avg_ttft:.3f}s hit={m.doc_hit_rate:.2f} "
                         f"thr={m.throughput_rps:.2f}rps"))
        for b in ("vllm", "sglang"):
            best_vs[b] = max(best_vs[b], ttfts[b] / ttfts["ragcache"])
    rows.append((f"{tag}/{model}/claim/ttft_vs_vllm", best_vs["vllm"],
                 f"paper 1.2-4x got={best_vs['vllm']:.2f}x"))
    rows.append((f"{tag}/{model}/claim/ttft_vs_sglang", best_vs["sglang"],
                 f"paper 1.1-3.5x got={best_vs['sglang']:.2f}x"))
    return rows


def run() -> list:
    rows = []
    rows += _sweep("mistral-7b", 1, "fig13_mmlu")
    rows += _sweep("llama2-7b", 1, "fig13_mmlu")
    rows += _sweep("mistral-7b", 6, "fig14_nq")
    return rows
