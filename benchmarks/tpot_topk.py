"""Paper §8 (Discussion): TPOT and the large-top-k truncation trade-off.

* TPOT — RAGCache also lowers time-per-output-token by accelerating the
  prefill iterations that interleave with decode in iteration-level
  scheduling.
* Large top-k — caching only the leading ``cache_top_k`` documents of each
  sequence ("e.g. caching the top-3 documents for requests with top-5")
  balances hit rate against cache-space consumption as permutations explode.
"""
from __future__ import annotations

from benchmarks.common import BASELINES, corpus_and_index, simulate, workload


def run() -> list:
    corpus, idx = corpus_and_index()
    rows = []
    # TPOT: NQ-like output (6 tokens) so decode actually runs
    wl = workload(corpus, n=200, rate=1.0, zipf=1.0, out_len=6, seed=31)
    t = {}
    for name in ("ragcache", "vllm"):
        m, _ = simulate(corpus, idx, wl, **BASELINES[name])
        t[name] = m
        rows.append((f"tpot/{name}", m.avg_tpot * 1e6,
                     f"tpot={m.avg_tpot * 1000:.1f}ms "
                     f"ttft={m.avg_ttft:.3f}s"))
    rows.append(("tpot/claim", t["vllm"].avg_tpot / max(t["ragcache"].avg_tpot,
                                                        1e-9),
                 f"paper: RAGCache also lowers TPOT; got="
                 f"{t['vllm'].avg_tpot / max(t['ragcache'].avg_tpot, 1e-9):.2f}x"))

    # large top-k: cache all 5 vs only leading 3 under a tight cache
    wl5 = workload(corpus, n=250, rate=0.6, zipf=1.0, seed=33)
    for cache_k, label in ((0, "cache_all5"), (3, "cache_top3")):
        m, _ = simulate(corpus, idx, wl5, top_k=5, cache_top_k=cache_k,
                        gpu_cache_bytes=int(0.5 * 2**30),
                        host_cache_bytes=int(2 * 2**30),
                        reorder=False, speculative=False)
        rows.append((f"topk_trunc/{label}", m.doc_hit_rate * 100,
                     f"hit={m.doc_hit_rate:.3f} ttft={m.avg_ttft:.3f}s "
                     f"evictions={m.gpu_evictions}"))
    return rows
