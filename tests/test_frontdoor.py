"""Front-door request layer: query-cache TTL/similarity/LRU contracts
(hypothesis-verified), SLO admission, autoscaler bounds, the shared
``frontdoor_partition`` trace walk, and the simulator e2e.

Contracts (serving/frontdoor.py):
  * an expired entry is NEVER served (TTL anchors at insertion; hits
    refresh LRU recency, never freshness);
  * similarity hits fire only at/above the cosine threshold;
  * the LRU capacity bound is never exceeded;
  * the autoscaler's active count stays within [min, max] under bursts;
  * simulator and real driver consume the same policy objects through the
    same partition walk (PR 1/PR 4 shared-policy pattern).
"""
import dataclasses

import numpy as np
import pytest

from repro.retrieval.corpus import Request, make_corpus
from repro.retrieval.traffic import default_tenants, make_default_workload
from repro.serving.frontdoor import (ADMIT, DEGRADE, HIT_EXACT, HIT_SIMILAR,
                                     MISS, SHED, AutoscaleConfig,
                                     FleetAutoscaler, FrontDoor, QueryCache,
                                     SloAdmission, TenantSLO,
                                     frontdoor_partition, make_frontdoor,
                                     query_key, warm_from_disk)
from repro.serving.metrics import FleetMetrics, ServingMetrics
from repro.serving.router import ReplicaRouter


def _vec(seed, d=8):
    rng = np.random.default_rng(seed)
    v = rng.normal(size=d).astype(np.float32)
    return v / np.linalg.norm(v)


def _toks(seed, n=4):
    return np.random.default_rng(seed).integers(0, 1000, n).astype(np.int32)


def _req(i, *, arrival=0.0, seed=None, tenant="", out=1):
    s = i if seed is None else seed
    return Request(req_id=i, arrival=arrival, query_vec=_vec(s),
                   question_tokens=_toks(s), target_doc=0, output_len=out,
                   tenant=tenant)


# ---------------------------------------------------------------------------
# QueryCache: deterministic unit tests (run even without hypothesis)
# ---------------------------------------------------------------------------

def test_query_key_is_deterministic_and_order_sensitive():
    assert query_key([1, 2]) == query_key(np.asarray([1, 2]))
    assert query_key([1, 2]) != query_key([2, 1])
    assert query_key([]) == 0xcbf29ce484222325


def test_exact_hit_and_miss():
    c = QueryCache(capacity=4, ttl=10.0, sim_threshold=1.0)
    v, t = _vec(0), _toks(0)
    assert c.lookup(v, t, 0.0) == (MISS, None)
    c.insert(v, t, docs=(3, 1), answer=[7, 8], source_req_id=0, now=0.0,
             top_k=2)
    kind, e = c.lookup(v, t, 1.0)
    assert kind == HIT_EXACT
    assert e.docs == (3, 1) and e.answer == [7, 8] and e.source_req_id == 0
    # different tokens, same vector direction: exact misses (threshold 1.0
    # disables the similarity probe entirely)
    assert c.lookup(v, _toks(1), 1.0) == (MISS, None)
    assert c.stats()["hits_exact"] == 1 and c.stats()["misses"] == 2


def test_similarity_hit_at_threshold_only():
    c = QueryCache(capacity=4, ttl=10.0, sim_threshold=0.95)
    v = _vec(0)
    c.insert(v, _toks(0), docs=(1,), answer=[5], source_req_id=0, now=0.0,
             top_k=1)
    # near-duplicate: same direction, tiny perturbation, different tokens
    near = v + 0.01 * _vec(1)
    kind, e = c.lookup(near, _toks(1), 1.0)
    assert kind == HIT_SIMILAR and e.docs == (1,)
    # orthogonal-ish probe: below threshold -> miss
    far = _vec(2) - float(np.dot(_vec(2), v)) * v
    assert c.lookup(far, _toks(2), 1.0)[0] == MISS


def test_ttl_expiry_never_serves_expired():
    c = QueryCache(capacity=4, ttl=5.0, sim_threshold=0.9)
    v, t = _vec(0), _toks(0)
    c.insert(v, t, docs=(1,), answer=[], source_req_id=0, now=0.0, top_k=1)
    assert c.lookup(v, t, 4.999)[0] == HIT_EXACT
    # ... the hit did NOT refresh freshness: expiry still anchors at t=0
    assert c.lookup(v, t, 5.0) == (MISS, None)
    assert c.stats()["expired"] == 1 and len(c) == 0
    # an expired entry is invisible to the similarity probe too
    c.insert(v, t, docs=(1,), answer=[], source_req_id=0, now=10.0, top_k=1)
    assert c.lookup(v + 0.01 * _vec(1), _toks(1), 100.0) == (MISS, None)


def test_reinsert_refreshes_freshness():
    c = QueryCache(capacity=4, ttl=5.0, sim_threshold=1.0)
    v, t = _vec(0), _toks(0)
    c.insert(v, t, docs=(1,), answer=[], source_req_id=0, now=0.0, top_k=1)
    c.insert(v, t, docs=(2,), answer=[9], source_req_id=7, now=4.0, top_k=1)
    kind, e = c.lookup(v, t, 8.0)   # 8 < 4 + 5: alive, with the new payload
    assert kind == HIT_EXACT and e.docs == (2,) and e.source_req_id == 7


def test_lru_capacity_bound_evicts_least_recently_hit():
    c = QueryCache(capacity=3, ttl=100.0, sim_threshold=1.0)
    for i in range(3):
        c.insert(_vec(i), _toks(i), (i,), [], i, now=0.0, top_k=1)
    # touch entry 0 so it is most-recently used
    assert c.lookup(_vec(0), _toks(0), 1.0)[0] == HIT_EXACT
    c.insert(_vec(3), _toks(3), (3,), [], 3, now=1.0, top_k=1)
    assert len(c) == 3 and c.stats()["evicted"] == 1
    assert c.lookup(_vec(0), _toks(0), 2.0)[0] == HIT_EXACT   # survived
    assert c.lookup(_vec(1), _toks(1), 2.0)[0] == MISS        # evicted


def test_cache_rejects_bad_config():
    with pytest.raises(ValueError):
        QueryCache(capacity=0)
    with pytest.raises(ValueError):
        QueryCache(ttl=0.0)


def test_cache_records_top_k_and_filters_shallow_exact_hits():
    c = QueryCache(capacity=4, ttl=10.0, sim_threshold=1.0)
    v, t = _vec(0), _toks(0)
    c.insert(v, t, docs=(1,), answer=[5], source_req_id=0, now=0.0, top_k=1)
    # a shallow entry serves an equally-shallow (or depth-agnostic) lookup
    assert c.lookup(v, t, 1.0, min_top_k=1)[0] == HIT_EXACT
    assert c.lookup(v, t, 1.0)[0] == HIT_EXACT
    # ... but never a deeper one
    kind, e = c.lookup(v, t, 2.0, min_top_k=2)
    assert kind == MISS and e is None
    assert c.stats()["depth_filtered"] == 1
    # a full-depth reinsert upgrades the entry
    c.insert(v, t, docs=(1, 2), answer=[5], source_req_id=1, now=3.0,
             top_k=2)
    kind, e = c.lookup(v, t, 4.0, min_top_k=2)
    assert kind == HIT_EXACT and e.top_k == 2


def test_cache_filters_shallow_similarity_hits():
    c = QueryCache(capacity=4, ttl=1e9, sim_threshold=0.9)
    v = _vec(0)
    c.insert(v, _toks(0), (1,), [5], 0, now=0.0, top_k=1)
    near = v + 0.01 * _vec(1)
    assert c.lookup(near, _toks(1), 1.0, min_top_k=1)[0] == HIT_SIMILAR
    # the only candidate is too shallow for the required depth
    assert c.lookup(near, _toks(2), 2.0, min_top_k=2)[0] == MISS
    # a deeper entry elsewhere in the cache still serves the probe
    c.insert(v, _toks(3), (1, 2), [7], 1, now=3.0, top_k=2)
    kind, e = c.lookup(near, _toks(4), 4.0, min_top_k=2)
    assert kind == HIT_SIMILAR and e.top_k == 2


# ---------------------------------------------------------------------------
# SLO admission
# ---------------------------------------------------------------------------

def test_admission_admits_under_target():
    adm = SloAdmission({"a": TenantSLO(ttft_target=1.0)}, top_k=4,
                       init_service=0.01)
    d = adm.decide("a", backlog=0, active=1)
    assert d.action == ADMIT and d.top_k == 4
    assert adm.decisions[ADMIT] == 1


def test_admission_degrades_then_sheds():
    # service estimate 1s vs 0.5s target: over target but within the
    # 2x shed band at the floor -> degrade to min_top_k
    adm = SloAdmission({"a": TenantSLO(ttft_target=0.5, min_top_k=2)},
                       top_k=4, init_service=1.0, shed_factor=2.0)
    d = adm.decide("a", backlog=0, active=1)
    assert d.action == DEGRADE and d.top_k == 2
    # deep backlog: even the floor predicts > shed_factor x target -> shed
    d2 = adm.decide("a", backlog=50, active=1)
    assert d2.action == SHED and d2.top_k == 0
    assert adm.decisions[DEGRADE] == 1 and adm.decisions[SHED] == 1


def test_admission_unknown_tenant_uses_default_and_ewma_learns():
    adm = SloAdmission({}, default=TenantSLO(ttft_target=0.2), top_k=2,
                       init_service=1.0, ewma_alpha=0.5)
    assert adm.decide("nobody", 0, 1).predicted_ttft == pytest.approx(1.0)
    for _ in range(20):
        adm.observe_ttft(0.01)
    assert adm.decide("nobody", 0, 1).action == ADMIT


def test_more_active_replicas_lower_prediction():
    adm = SloAdmission({}, top_k=2, init_service=0.1)
    assert adm.predicted_ttft(8, 4) < adm.predicted_ttft(8, 1)


def test_backlog_dominated_predictions_shed_not_degrade():
    # queueing term 2.0s vs 0.5s target: no top_k shrinks OTHER requests'
    # work, so the request must SHED.  (The old code scaled the WHOLE
    # prediction by k'/k: 2.4s * 1/4 = 0.6s <= 2 x 0.5s "fit" on paper
    # while the real queue stayed 2.0s.)
    adm = SloAdmission({"a": TenantSLO(ttft_target=0.5, min_top_k=1)},
                       top_k=4, init_service=0.4, shed_factor=2.0)
    d = adm.decide("a", backlog=5, active=1)   # queue = 5 * 0.4s = 2.0s
    assert d.action == SHED and d.top_k == 0


def test_service_dominated_predictions_still_degrade():
    # zero backlog, service 1.6s: the floor k=1 scales it to 0.4s — under
    # target, so degrade (the fix must not turn every overload into a shed)
    adm = SloAdmission({"a": TenantSLO(ttft_target=0.5, min_top_k=1)},
                       top_k=4, init_service=1.6, shed_factor=2.0)
    d = adm.decide("a", backlog=0, active=1)
    assert d.action == DEGRADE and d.top_k == 1


def test_mixed_prediction_degrades_only_within_shed_band():
    # queue 0.6s + floor service 0.2s = 0.8s: above target but inside the
    # 2x shed band -> the degraded floor is still admitted
    adm = SloAdmission({"a": TenantSLO(ttft_target=0.5, min_top_k=1)},
                       top_k=4, init_service=0.8, shed_factor=2.0)
    d = adm.decide("a", backlog=3, active=4)   # queue = 3/4 * 0.8s = 0.6s
    assert d.action == DEGRADE and d.top_k == 1


# ---------------------------------------------------------------------------
# autoscaler
# ---------------------------------------------------------------------------

def test_autoscaler_bounds_under_bursty_trace():
    cfg = AutoscaleConfig(min_replicas=1, max_replicas=3,
                          scale_up_backlog=2.0, scale_down_backlog=0.5,
                          cooldown=0.1)
    sc = FleetAutoscaler(cfg)
    rng = np.random.default_rng(0)
    t = 0.0
    for _ in range(500):
        t += float(rng.exponential(0.05))
        # Markov-ish bursts: deep backlog spikes then idle troughs
        backlog = int(rng.choice([0, 1, 30], p=[0.4, 0.3, 0.3]))
        n = sc.observe(t, backlog)
        assert cfg.min_replicas <= n <= cfg.max_replicas
    assert sc.min_seen >= 1 and sc.max_seen <= 3
    assert sc.max_seen == 3 and sc.min_seen == 1     # both directions fired
    assert sc.events                                 # ... and were recorded
    kinds = {e.reason.split(":")[0] for e in sc.events}
    assert kinds == {"up", "down"}


def test_autoscaler_cooldown_spaces_events():
    sc = FleetAutoscaler(AutoscaleConfig(min_replicas=1, max_replicas=4,
                                         scale_up_backlog=1.0,
                                         scale_down_backlog=0.5,
                                         cooldown=10.0))
    assert sc.observe(0.0, 100) == 2
    assert sc.observe(5.0, 100) == 2      # inside cooldown: no change
    assert sc.observe(10.1, 100) == 3
    assert [e.active for e in sc.events] == [2, 3]


def test_autoscale_config_validation():
    with pytest.raises(ValueError):
        AutoscaleConfig(min_replicas=2, max_replicas=1)
    with pytest.raises(ValueError):
        AutoscaleConfig(scale_up_backlog=1.0, scale_down_backlog=2.0)


# ---------------------------------------------------------------------------
# router active set
# ---------------------------------------------------------------------------

class _Bare:
    pass


def test_router_set_active_restricts_routing():
    r = ReplicaRouter([_Bare(), _Bare(), _Bare()])
    r.set_active(1)
    for i in range(20):
        assert r.route((i,), (1,)).index == 0
    # a fresh router at full active set spreads distinct cold docs
    r2 = ReplicaRouter([_Bare(), _Bare(), _Bare()])
    assert {r2.route((i,), (1,)).index for i in range(30)} == {0, 1, 2}
    with pytest.raises(ValueError):
        r.set_active(0)
    with pytest.raises(ValueError):
        r.set_active(4)


# ---------------------------------------------------------------------------
# warm-from-disk
# ---------------------------------------------------------------------------

def test_warm_from_disk_stages_disk_nodes():
    from repro.core.knowledge_tree import KnowledgeTree
    tree = KnowledgeTree(100, 100, 100, bytes_per_token=1)
    node, _ = tree.insert(tree.root, 1, 10, None)
    tree.evict_gpu(100)     # GPU -> host
    tree.evict_host(100)    # host -> disk
    assert node.in_disk and not node.in_host and not node.in_gpu

    class _Replica:
        pass

    rep = _Replica()
    rep.tree = tree
    staged = warm_from_disk(rep)
    assert staged == 10      # node's bytes fetched disk -> host
    assert node.in_host      # staged, ready for a host->GPU promote
    # idempotent: nothing left disk-only to stage
    assert warm_from_disk(rep) == 0
    # a replica with no tree warms for free
    assert warm_from_disk(_Bare()) == 0


# ---------------------------------------------------------------------------
# FrontDoor composition + the shared partition walk
# ---------------------------------------------------------------------------

def _mk_fd(**kw):
    kw.setdefault("capacity", 32)
    kw.setdefault("ttl", 1e9)
    kw.setdefault("sim_threshold", 0.98)
    kw.setdefault("top_k", 2)
    kw.setdefault("init_service", 1e-6)
    return make_frontdoor(**kw)


def test_frontdoor_handle_flow_and_slo_attainment():
    fd = _mk_fd(slos={"a": TenantSLO(ttft_target=0.5)})
    r0 = _req(0, tenant="a")
    d0 = fd.handle(r0, 0.0)
    assert d0.kind == MISS and fd.backlog == 1
    fd.note_complete(r0, docs=(1, 2), answer=[9], ttft=0.1, now=0.1)
    assert fd.backlog == 0
    # the repeat (same query payload) hits, with the original's answer
    d1 = fd.handle(_req(1, seed=0, tenant="a"), 0.2)
    assert d1.kind == HIT_EXACT and d1.entry.answer == [9]
    att = fd.slo_attainment()
    assert att["a"][0] == 2 and att["a"][1] == 2    # miss + hit both in SLO
    s = fd.stats()
    assert s["hit_rate"] == pytest.approx(0.5)
    assert s["slo_attainment"]["a"]["fraction"] == 1.0


def test_frontdoor_partition_hits_shed_and_misses():
    # window=1: each miss completes (and populates the cache) as soon as
    # the next miss dispatches, so the repeat of request 0 can hit
    fd = _mk_fd(slos={"slow": TenantSLO(ttft_target=1e-9, min_top_k=1)},
                default_slo_ttft=1e9, init_service=1.0)
    router = ReplicaRouter([_Bare(), _Bare()])
    reqs = [
        _req(0, arrival=0.0, seed=0),
        _req(1, arrival=1.0, seed=1),
        _req(2, arrival=2.0, seed=0),              # repeat of 0 -> exact hit
        _req(3, arrival=3.0, seed=3, tenant="slow"),   # impossible SLO
    ]
    part = frontdoor_partition(fd, router, reqs,
                               docs_of=lambda r: (int(r.req_id) % 2,),
                               window=1)
    assert [r.req_id for r, _ in part.hits] == [2]
    assert part.hits[0][1].kind == HIT_EXACT
    assert [r.req_id for r in part.shed] == [3]
    assert sorted(r.req_id for r in part.misses) == [0, 1]
    assert sum(len(s) for s in part.shares) == 2
    assert router.depth == [0, 0]                  # fully drained
    assert fd.stats()["shed"] == {"slow": 1}


def test_frontdoor_partition_degrades_top_k_via_request_rewrite():
    # service estimate 1s vs 0.55s target at ZERO backlog: the service
    # term alone over-runs the target, the floor k=1 fits the shed band
    # (0 + 1/3 s <= 2 x 0.55s), and the rewritten Request carries the
    # lowered top_k.  Backlogged arrivals shed instead — the queueing
    # term can't be degraded away (see the SloAdmission unit tests).
    fd = _mk_fd(slos={"a": TenantSLO(ttft_target=0.55, min_top_k=1)},
                top_k=3, init_service=1.0)
    router = ReplicaRouter([_Bare()])
    reqs = [_req(0, arrival=0.0, seed=0, tenant="a")]
    part = frontdoor_partition(fd, router, reqs,
                               docs_of=lambda r: (0,), window=0)
    assert part.misses and all(r.top_k == 1 for r in part.misses)
    assert all(r.top_k == 0 for r in reqs)     # originals untouched
    assert fd.degraded == 1


def test_frontdoor_never_serves_degraded_answer_at_full_depth():
    # a degraded tenant's cached answer must not serve a request admitted
    # at full depth — for EITHER hit kind
    fd = _mk_fd(slos={"slow": TenantSLO(ttft_target=0.55, min_top_k=1),
                      "fast": TenantSLO(ttft_target=1e9)},
                top_k=3, init_service=1.0, sim_threshold=0.9)
    r0 = _req(0, tenant="slow")
    d0 = fd.handle(r0, 0.0)
    assert d0.kind == MISS and d0.degraded and d0.top_k == 1
    degraded = dataclasses.replace(r0, top_k=d0.top_k)
    fd.note_complete(degraded, docs=(1,), answer=[9], ttft=0.1, now=0.1)
    assert fd.cache.stats()["size"] == 1
    # exact repeat from the full-depth tenant: MISS, not a shallow hit
    d1 = fd.handle(_req(1, seed=0, tenant="fast"), 0.2)
    assert d1.kind == MISS
    assert fd.cache.stats()["depth_filtered"] == 1
    # near-duplicate (similarity probe) must miss too
    near = dataclasses.replace(
        _req(2, seed=0, tenant="fast"),
        query_vec=r0.query_vec + 0.01 * _vec(1),
        question_tokens=_toks(5))
    assert fd.handle(near, 0.3).kind == MISS
    # once a FULL-depth completion lands, both hit kinds serve again
    fd.note_complete(_req(1, seed=0, tenant="fast"),
                     docs=(1, 2, 3), answer=[7], ttft=0.1, now=0.4)
    d3 = fd.handle(_req(3, seed=0, tenant="fast"), 0.5)
    assert d3.kind == HIT_EXACT and d3.entry.top_k == 3
    d4 = fd.handle(near, 0.6)
    assert d4.kind == HIT_SIMILAR and d4.entry.top_k == 3


def test_frontdoor_partition_autoscales_and_warms():
    fd = _mk_fd(min_replicas=1, max_replicas=3, autoscale=True,
                scale_up_backlog=1.0, scale_down_backlog=0.1,
                cooldown=0.0, init_service=1e-6)
    router = ReplicaRouter([_Bare(), _Bare(), _Bare()])
    warmed_handles = []
    # all-distinct queries arriving with zero drain (window=0): backlog
    # climbs monotonically, forcing scale-ups
    reqs = [_req(i, arrival=float(i) * 0.01, seed=i) for i in range(12)]
    part = frontdoor_partition(
        fd, router, reqs, docs_of=lambda r: (r.req_id,), window=0,
        warm_replica=lambda rep: warmed_handles.append(rep) or 0)
    assert fd.autoscaler.max_seen == 3
    assert 1 <= fd.autoscaler.active <= 3
    # replicas 1 and 2 joined the active set exactly once each
    assert warmed_handles == [router.replicas[1], router.replicas[2]]
    assert set(part.warmed) == {1, 2}
    assert all(len(s) > 0 for s in part.shares)    # load actually spread


# ---------------------------------------------------------------------------
# simulator e2e: the same policy objects, end to end
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sim_setup():
    from repro.retrieval.vectordb import IVFIndex
    corpus = make_corpus(40, mean_doc_tokens=60, seed=0)
    idx = IVFIndex(corpus.doc_vectors, n_clusters=8, nprobe=8, seed=0)
    tenants, wl = make_default_workload(corpus, n_tenants=2, n_requests=80,
                                        rate=50.0, n_queries=6, seed=3)
    return corpus, idx, tenants, wl


def _sim_cfg():
    from repro.core.profiler import A10G_MISTRAL_7B
    from repro.serving.simulator import SimConfig
    return SimConfig(profile=A10G_MISTRAL_7B, top_k=2,
                     gpu_cache_bytes=2 * 2**30, host_cache_bytes=16 * 2**30)


def test_simulate_frontdoor_on_beats_off(sim_setup):
    from repro.serving.simulator import simulate_frontdoor, simulate_replicas
    corpus, idx, tenants, wl = sim_setup
    off = simulate_replicas(_sim_cfg(), corpus, idx, wl, n_replicas=2)
    fd = _mk_fd(slos={t.name: TenantSLO(ttft_target=1e9) for t in tenants})
    on = simulate_frontdoor(_sim_cfg(), corpus, idx, wl, fd, n_replicas=2)
    assert not on.partition.shed
    assert on.partition.hits                      # repeats actually hit
    assert on.metrics.completed == len(wl)
    assert on.metrics.avg_ttft < off.metrics.avg_ttft
    # miss-only metrics exclude the hits
    assert on.miss_metrics.completed == len(on.partition.misses)


def test_simulate_frontdoor_autoscaler_stays_bounded(sim_setup):
    from repro.serving.simulator import simulate_frontdoor
    corpus, idx, tenants, wl = sim_setup
    fd = _mk_fd(slos={t.name: TenantSLO(ttft_target=t.slo_ttft_ms / 1e3)
                      for t in tenants},
                min_replicas=1, max_replicas=3, autoscale=True,
                scale_up_backlog=2.0, scale_down_backlog=0.5, cooldown=0.05,
                init_service=0.05)
    res = simulate_frontdoor(_sim_cfg(), corpus, idx, wl, fd, n_replicas=3)
    scale = res.frontdoor_stats["autoscale"]
    assert 1 <= scale["min_seen"] and scale["max_seen"] <= 3
    assert scale["events"]


def test_fleet_metrics_reports_frontdoor_and_slo(sim_setup):
    from repro.serving.simulator import simulate_frontdoor
    corpus, idx, tenants, wl = sim_setup
    fd = _mk_fd(slos={t.name: TenantSLO(ttft_target=t.slo_ttft_ms / 1e3)
                      for t in tenants})
    simulate_frontdoor(_sim_cfg(), corpus, idx, wl, fd, n_replicas=2)
    fleet = FleetMetrics(router_stats={}, frontdoor_stats=fd.stats())
    fleet.add_replica("replica0", ServingMetrics())
    rep = fleet.format_report()
    assert "front door" in rep and "hit rate" in rep
    for t in tenants:
        assert f"SLO {t.name}" in rep             # per-tenant attainment
    assert fleet.summary()["frontdoor"]["hit_rate"] > 0.0


def test_shared_policy_objects_between_drivers():
    """The real driver and the simulator import the SAME partition walk
    and policy constructor — front-door behavior cannot drift (the PR 1
    scheduler / PR 4 router shared-policy discipline)."""
    pytest.importorskip("jax")
    import repro.launch.serve as serve_mod
    from repro.serving import frontdoor as fd_mod
    from repro.serving import simulator as sim_mod
    assert serve_mod.frontdoor_partition is fd_mod.frontdoor_partition
    assert serve_mod.make_frontdoor is fd_mod.make_frontdoor
    # simulate_frontdoor resolves the identical partition function
    import inspect
    src = inspect.getsource(sim_mod.simulate_frontdoor)
    assert "frontdoor_partition(" in src
    # ... and a FrontDoor built by the CLI path is drivable by the sim
    args = serve_mod.build_parser().parse_args(
        ["--frontdoor", "--slo-ttft-ms", "250"])
    fd = serve_mod.build_frontdoor(args, default_tenants(2))
    assert isinstance(fd, FrontDoor)
    # per-tenant targets come from the TenantSpecs (tenant0: 500ms default,
    # head tenants tighter); --slo-ttft-ms sets the unknown-tenant fallback
    assert fd.admission.slo_of("tenant0").ttft_target == pytest.approx(0.5)
    assert fd.admission.slo_of("stranger").ttft_target == pytest.approx(0.25)


# ---------------------------------------------------------------------------
# hypothesis property tests (CI installs hypothesis; local runs skip)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                              # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    # (key, insert-gap, lookup-gap) triples: time only moves forward
    ops = st.lists(st.tuples(st.integers(0, 5),
                             st.floats(0.0, 3.0, allow_nan=False),
                             st.floats(0.0, 3.0, allow_nan=False)),
                   min_size=1, max_size=40)

    @settings(max_examples=100, deadline=None)
    @given(trace=ops, ttl=st.floats(0.5, 4.0, allow_nan=False))
    def test_expired_entries_never_served(trace, ttl):
        """Whatever the interleaving of inserts and lookups, a served
        entry is strictly younger than the TTL."""
        c = QueryCache(capacity=64, ttl=ttl, sim_threshold=1.0)
        created = {}
        now = 0.0
        for key, gap_i, gap_l in trace:
            now += gap_i
            c.insert(_vec(key), _toks(key), (key,), [], key, now=now,
                     top_k=1)
            created[key] = now
            now += gap_l
            probe = key % 3
            kind, e = c.lookup(_vec(probe), _toks(probe), now)
            if kind == HIT_EXACT:
                assert now - created[probe] < ttl
            elif probe in created:
                # a miss on a known key is only legal past its TTL or
                # after an LRU eviction (capacity 64 > trace: never here)
                assert now - created[probe] >= ttl

    @settings(max_examples=100, deadline=None)
    @given(seeds=st.lists(st.integers(0, 50), min_size=1, max_size=16,
                          unique=True),
           probe_seed=st.integers(51, 99),
           threshold=st.floats(0.2, 0.999, allow_nan=False))
    def test_similarity_hits_only_at_or_above_threshold(seeds, probe_seed,
                                                        threshold):
        c = QueryCache(capacity=64, ttl=1e9, sim_threshold=threshold)
        for s in seeds:
            c.insert(_vec(s), _toks(s), (s,), [], s, now=0.0, top_k=1)
        q = _vec(probe_seed)
        kind, e = c.lookup(q, _toks(probe_seed), 1.0)
        best = max(float(np.dot(_vec(s), q)) for s in seeds)
        if kind == HIT_SIMILAR:
            assert float(np.dot(e.vec, q)) >= threshold - 1e-6
            assert float(np.dot(e.vec, q)) == pytest.approx(best, abs=1e-6)
        else:
            assert kind == MISS and best < threshold + 1e-6

    @settings(max_examples=100, deadline=None)
    @given(keys=st.lists(st.integers(0, 30), min_size=1, max_size=60),
           capacity=st.integers(1, 8))
    def test_lru_bound_never_exceeded(keys, capacity):
        c = QueryCache(capacity=capacity, ttl=1e9, sim_threshold=1.0)
        for i, k in enumerate(keys):
            c.insert(_vec(k), _toks(k), (k,), [], i, now=float(i), top_k=1)
            assert len(c) <= capacity
        st_ = c.stats()
        assert st_["size"] <= capacity
        # conservation: every insert either lives, was evicted, or was an
        # overwrite of a live key
        assert st_["evicted"] <= len(keys)

    backlogs = st.lists(st.integers(0, 50), min_size=1, max_size=200)

    @settings(max_examples=100, deadline=None)
    @given(trace=backlogs, lo=st.integers(1, 3), span=st.integers(0, 3),
           up=st.floats(1.0, 8.0), down_frac=st.floats(0.1, 1.0))
    def test_autoscaler_always_within_bounds(trace, lo, span, up,
                                             down_frac):
        cfg = AutoscaleConfig(min_replicas=lo, max_replicas=lo + span,
                              scale_up_backlog=up,
                              scale_down_backlog=up * down_frac,
                              cooldown=0.0)
        sc = FleetAutoscaler(cfg)
        for i, b in enumerate(trace):
            n = sc.observe(float(i), b)
            assert lo <= n <= lo + span
        assert lo <= sc.min_seen <= sc.max_seen <= lo + span
