"""Knowledge tree + PGDSF unit & property tests (paper §5.1, Alg. 1)."""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.knowledge_tree import KnowledgeTree
from repro.core.profiler import A10G_MISTRAL_7B, CostProfiler


def make_tree(gpu=1000, host=4000, policy="pgdsf"):
    prof = CostProfiler.from_profile(A10G_MISTRAL_7B)
    return KnowledgeTree(gpu, host, policy=policy, profiler=prof,
                         bytes_per_token=1)


def test_prefix_match_order_sensitivity():
    t = make_tree()
    n1, _ = t.insert(t.root, 1, 100)
    n2, _ = t.insert(n1, 2, 100)
    assert [n.doc_id for n in t.match_prefix([1, 2])] == [1, 2]
    assert [n.doc_id for n in t.match_prefix([2, 1])] == []
    assert [n.doc_id for n in t.match_prefix([1, 3])] == [1]
    # same doc 2 under a different prefix is a distinct node
    n3, _ = t.insert(t.root, 3, 100)
    n4, _ = t.insert(n3, 2, 100)
    assert n4 is not n2


def test_swap_out_only_once():
    t = make_tree(gpu=100, host=1000)   # GPU holds exactly one node
    n1, _ = t.insert(t.root, 1, 100)
    t.update_on_access(n1, False, 0, 100)
    n2, _ = t.insert(t.root, 2, 100)    # evicts n1 -> host copy
    t.update_on_access(n2, False, 0, 100)
    assert t.stats["swap_out_bytes"] == 100
    assert n1.in_host and n1.swapped_once and not n1.in_gpu
    t.ensure_in_gpu([n1])               # promote n1 back (evicts n2, copy)
    assert t.stats["swap_out_bytes"] == 200
    t.evict_gpu(100, pinned=set())      # n1 evicted again: zero-copy free
    assert t.stats["swap_out_skipped"] == 1
    assert t.stats["swap_out_bytes"] == 200
    assert n1.in_host and not n1.in_gpu


def test_eviction_is_leaf_first():
    """Paper §7.2: 'the knowledge tree always evicts the node furthest from
    the root' — parents must outlive children in GPU."""
    t = make_tree(gpu=300, host=0)
    n1, _ = t.insert(t.root, 1, 100)
    n2, _ = t.insert(n1, 2, 100)
    n3, _ = t.insert(n2, 3, 100)
    for n, beta in ((n1, 100), (n2, 100), (n3, 100)):
        t.update_on_access(n, False, 0, beta)
    t.insert(t.root, 9, 100)   # forces one eviction
    assert not n3.in_gpu and n2.in_gpu and n1.in_gpu
    t.check_invariants()


def test_pgdsf_prefers_frequent_and_costly():
    t = make_tree(gpu=200, host=0)
    n1, _ = t.insert(t.root, 1, 100)
    n2, _ = t.insert(t.root, 2, 100)
    for _ in range(5):
        t.update_on_access(n1, True, 100, 32)     # hot doc
    t.update_on_access(n2, False, 0, 100)          # cold doc
    t.insert(t.root, 3, 100)
    assert n1.in_gpu and not n2.in_gpu


def test_lru_policy_differs_from_lfu():
    for policy, evicted_doc in (("lru", 1), ("lfu", 2)):
        t = make_tree(gpu=200, host=0, policy=policy)
        n1, _ = t.insert(t.root, 1, 100)
        n2, _ = t.insert(t.root, 2, 100)
        # doc1: frequent but stale; doc2: recent but rare
        for _ in range(5):
            t.update_on_access(n1, True, 100, 1)
        t.update_on_access(n2, True, 100, 1)
        t.insert(t.root, 3, 100)
        victim = {1: n1, 2: n2}[evicted_doc]
        assert not victim.in_gpu, policy


def test_bilinear_interpolation():
    prof = CostProfiler(
        alphas=[0, 100], betas=[0, 100],
        table={(0, 0): 0.0, (0, 100): 10.0, (100, 0): 0.0, (100, 100): 6.0})
    assert prof.estimate(0, 50) == pytest.approx(5.0)
    assert prof.estimate(100, 100) == pytest.approx(6.0)
    assert prof.estimate(50, 100) == pytest.approx(8.0)
    assert prof.estimate(50, 50) == pytest.approx(4.0)


@settings(max_examples=60, deadline=None)
@given(st.lists(
    st.tuples(st.lists(st.integers(0, 6), min_size=1, max_size=4),
              st.integers(10, 120)),
    min_size=1, max_size=60))
def test_tree_invariants_under_random_workload(ops):
    """Property: random plan/promote/insert sequences never violate tier
    invariants or byte accounting."""
    from repro.core.controller import RAGController
    t = make_tree(gpu=500, host=800)
    c = RAGController(t)
    for doc_ids, tok in ops:
        doc_ids = list(dict.fromkeys(doc_ids))  # dedupe, keep order
        plan = c.plan(doc_ids, [tok] * len(doc_ids), 16)
        c.promote(plan)
        c.commit(plan)
        t.check_invariants()
    assert 0.0 <= c.doc_hit_rate <= 1.0
