"""Chunk-cache (--reuse chunk) unit + e2e coverage (docs/ARCHITECTURE.md §11).

Unit layer (no model): ``match_chunks`` position independence,
``effective_recompute`` page alignment/clamping (plus a hypothesis
property: ``recompute_tokens >= chunk_len`` degenerates to exact full
recompute), ``plan_chunks`` classification, ``commit_chunks``
src_prefix/exact_ctx recording and incumbent protection, and the
``--check-tokens`` mode parser / tolerance comparator.

E2e layer (tiny real model): exact chunk hits on unchanged doc order are
bit-identical; RELOCATED hits (same docs, reversed order) are flagged
``exact=False`` and their first-token logit divergence vs the sequential
oracle is bounded by the tolerance comparator; a huge recompute budget
degenerates back to bit-exact; block accounting still balances; dense
attention rejects chunk mode.  Exact-mode prefix-reuse parity at
N=1/N=3/tp=2 stays covered by test_serve_main.py / test_tp_serving.py.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.controller import RAGController, effective_recompute
from repro.core.knowledge_tree import KnowledgeTree
from repro.core.profiler import A10G_MISTRAL_7B, CostProfiler
from repro.launch.serve import parse_check_mode, token_mismatches
from repro.serving.config import EngineConfig


def make_tree(gpu=10_000, host=40_000, policy="pgdsf"):
    prof = CostProfiler.from_profile(A10G_MISTRAL_7B)
    return KnowledgeTree(gpu, host, policy=policy, profiler=prof,
                         bytes_per_token=1)


# ---------------------------------------------------------------------------
# tree: flat per-position probing
# ---------------------------------------------------------------------------

def test_match_chunks_hits_any_position():
    t = make_tree()
    t.insert(t.root, 7, 100)
    assert [n.doc_id if n else None for n in t.match_chunks([7, 8])] \
        == [7, None]
    # the SAME cached doc hits relocated to position 1 — where
    # match_prefix, by construction, sees nothing
    assert [n.doc_id if n else None for n in t.match_chunks([8, 7])] \
        == [None, 7]
    assert t.match_prefix([8, 7]) == []


def test_match_chunks_requires_residency():
    t = make_tree()
    n, _ = t.insert(t.root, 3, 100)
    n.in_gpu = False                       # fully evicted, node lingers
    assert t.match_chunks([3]) == [None]


# ---------------------------------------------------------------------------
# effective_recompute: page alignment + degenerate clamp
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("r,n,bs,want", [
    (16, 100, 16, 16),      # already aligned
    (17, 100, 16, 32),      # rounds UP to the next page
    (1, 100, 16, 16),
    (0, 100, 16, 0),        # zero boundary stays zero
    (99, 100, 16, 100),     # aligned past the end: clamps to chunk length
    (100, 100, 16, 100),    # degenerate: full recompute
    (500, 100, 16, 100),
    (5, 100, 1, 5),         # block_size 1: no alignment
])
def test_effective_recompute_table(r, n, bs, want):
    assert effective_recompute(r, n, bs) == want


def test_effective_recompute_degenerate_is_exact_plan():
    """recompute_tokens >= chunk_len must reclassify the hit as a plain
    miss (full recompute) — the plan is then exact end-to-end."""
    t = make_tree()
    t.insert(t.root, 1, 50)
    ctl = RAGController(t)
    plan = ctl.plan_chunks([2, 1], [50, 50], 10, recompute_tokens=50,
                           block_size=16)
    assert [it.kind for it in plan.chunks] == ["miss", "miss"]
    assert plan.exact and plan.alpha == 0
    assert plan.beta == 110


@pytest.mark.filterwarnings("ignore::DeprecationWarning")
def test_effective_recompute_properties_hypothesis():
    pytest.importorskip("hypothesis")
    import hypothesis.strategies as st
    from hypothesis import given, settings

    @settings(max_examples=200, deadline=None)
    @given(st.integers(0, 4096), st.integers(1, 4096), st.integers(1, 128))
    def prop(r, n, bs):
        eff = effective_recompute(r, n, bs)
        assert 0 <= eff <= n
        assert eff >= min(r, n)               # never recompute less than asked
        if r >= n:
            assert eff == n                   # degenerate: exact recompute
        elif eff < n:
            assert eff % bs == 0              # reused tail starts on a page

    prop()


# ---------------------------------------------------------------------------
# controller: plan/commit classification and metadata
# ---------------------------------------------------------------------------

def _commit_all_miss(ctl, docs, toks, q=10):
    plan = ctl.plan_chunks(docs, toks, q, recompute_tokens=16, block_size=16)
    ctl.promote(plan)
    return plan, ctl.commit_chunks(plan)


def test_plan_chunks_classification_and_alpha_beta():
    t = make_tree()
    ctl = RAGController(t)
    # seed the chunk cache: [1, 2] both commit as root children
    _commit_all_miss(ctl, [1, 2], [64, 64])
    # same docs, reversed: doc 2 relocated (src_prefix was (1,)), doc 1
    # relocated (src_prefix was ()), both reuse tails minus 16 boundary rows
    plan = ctl.plan_chunks([2, 1], [64, 64], 10, recompute_tokens=16,
                           block_size=16)
    assert [it.kind for it in plan.chunks] == ["reloc", "reloc"]
    assert not plan.exact
    assert plan.alpha == 2 * (64 - 16)
    assert plan.beta == 2 * 16 + 10
    assert plan.alpha + plan.beta == plan.full_len == 64 + 64 + 10
    # unchanged order: doc 1 at position 0 has src_prefix () and exact_ctx
    # -> exact; doc 2 at position 1 behind doc 1 -> exact too
    plan2 = ctl.plan_chunks([1, 2], [64, 64], 10, recompute_tokens=16,
                            block_size=16)
    assert [it.kind for it in plan2.chunks] == ["exact", "exact"]
    assert plan2.exact and plan2.alpha == 128
    for n in t.nodes():
        assert not n.pinned


def test_commit_chunks_records_context_and_skips_reloc():
    t = make_tree()
    ctl = RAGController(t)
    plan, new = _commit_all_miss(ctl, [1, 2], [64, 64])
    assert sorted(n.doc_id for n in new) == [1, 2]
    by_doc = {n.doc_id: n for n in new}
    assert by_doc[1].src_prefix == () and by_doc[1].exact_ctx
    assert by_doc[2].src_prefix == (1,) and by_doc[2].exact_ctx
    assert all(n.parent is t.root for n in new)   # flat chunk cache
    # request [2, 3]: 2 relocates (never re-commits), 3 misses and commits
    # with exact_ctx=False — everything after a relocated chunk is
    # approximate context
    plan = ctl.plan_chunks([2, 3], [64, 64], 10, recompute_tokens=16,
                           block_size=16)
    ctl.promote(plan)
    new = ctl.commit_chunks(plan)
    assert [n.doc_id for n in new] == [3]
    assert new[0].src_prefix == (2,) and not new[0].exact_ctx
    assert t.root.children[2] is by_doc[2]        # incumbent untouched


def test_commit_chunks_never_replaces_incumbent():
    """If a concurrent prefill commits a doc between our plan and commit,
    the incumbent node (with ITS src_prefix) stays canonical — our payload
    is declined, not spliced under the incumbent's metadata."""
    t = make_tree()
    ctl = RAGController(t)
    plan = ctl.plan_chunks([5], [64], 10, recompute_tokens=16, block_size=16)
    ctl.promote(plan)
    # concurrent commit wins the race
    _commit_all_miss(ctl, [9, 5], [64, 64])
    incumbent = t.root.children[5]
    assert incumbent.src_prefix == (9,)
    new = ctl.commit_chunks(plan, payloads=["ours"])
    assert new == []                              # declined -> caller reclaims
    assert t.root.children[5] is incumbent
    assert incumbent.src_prefix == (9,)


# ---------------------------------------------------------------------------
# --check-tokens mode parsing + tolerance comparator (launch/serve.py)
# ---------------------------------------------------------------------------

def test_parse_check_mode():
    assert parse_check_mode(None) == ("exact", 0.0)
    assert parse_check_mode("exact") == ("exact", 0.0)
    assert parse_check_mode("tol:0.5") == ("tol", 0.5)
    assert parse_check_mode("tol:1e-3") == ("tol", 1e-3)
    for bad in ("tol:", "tol:x", "tol:-1", "tol:inf", "fuzzy"):
        with pytest.raises(SystemExit):
            parse_check_mode(bad)


@dataclasses.dataclass
class _Res:
    req_id: int
    tokens: list
    first_logits: object = None


def test_token_mismatches_tolerance_semantics():
    logit = np.array([0.0, 1.0, 2.0])
    same = (_Res(0, [1, 2], logit), _Res(0, [1, 2], logit + 0.4))
    close = (_Res(1, [1, 2], logit), _Res(1, [1, 3], logit + 0.4))
    far = (_Res(2, [1, 2], logit), _Res(2, [1, 3], logit + 2.0))
    # exact mode: only token equality counts
    assert [m[0] for m in token_mismatches([same, close, far], "exact", 0.0)] \
        == [1, 2]
    # tol mode: differing tokens pass iff first-token logits are within eps
    bad = token_mismatches([same, close, far], "tol", 0.5)
    assert [m[0] for m in bad] == [2]
    assert bad[0][3] == pytest.approx(2.0)        # reported L-inf
    # missing logits can never pass on divergent tokens
    nolog = (_Res(3, [1], None), _Res(3, [2], None))
    assert [m[0] for m in token_mismatches([nolog], "tol", 100.0)] == [3]


def test_engine_config_reuse_roundtrip():
    cfg = EngineConfig(reuse="chunk", recompute_tokens=32)
    cli = cfg.to_cli()
    assert "--reuse" in cli and "chunk" in cli
    i = cli.index("--recompute-tokens")
    assert cli[i + 1] == "32"
    with pytest.raises(ValueError):
        EngineConfig(reuse="suffix")
    with pytest.raises(ValueError):
        EngineConfig(recompute_tokens=-1)


# ---------------------------------------------------------------------------
# e2e: tiny real model through the continuous runtime
# ---------------------------------------------------------------------------

jax = pytest.importorskip("jax")

from repro.configs import get_reduced                      # noqa: E402
from repro.models import model as M                        # noqa: E402
from repro.retrieval.corpus import make_corpus, make_workload  # noqa: E402
from repro.retrieval.vectordb import IVFIndex              # noqa: E402
from repro.serving.engine import RAGServer                 # noqa: E402
from repro.serving.runtime import ContinuousRuntime        # noqa: E402


class FlippableIndex:
    """Wraps an index; with ``reverse=True`` every retrieval returns the
    same doc set in reversed order — cached docs then reappear at the
    wrong positions, which is exactly the relocated-chunk case."""

    def __init__(self, base):
        self.base = base
        self.reverse = False

    def search(self, q, k, fraction=1.0):
        out = self.base.search(q, k, fraction)
        return out[::-1] if self.reverse else out

    def staged_search(self, q, k, fraction=1.0):
        for st in self.base.staged_search(q, k, fraction):
            yield (dataclasses.replace(st, topk=tuple(reversed(st.topk)))
                   if self.reverse else st)


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("qwen2-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    corpus = make_corpus(16, mean_doc_tokens=24, vocab=cfg.vocab_size, seed=0)
    idx = IVFIndex(corpus.doc_vectors, n_clusters=4, nprobe=4)
    wl = make_workload(corpus, n_requests=6, rate=100.0, question_tokens=8,
                       vocab=cfg.vocab_size, zipf_s=1.2, seed=1)
    return cfg, params, corpus, idx, wl


def _chunk_runtime(cfg, params, corpus, idx, **kw):
    kw.setdefault("recompute_tokens", 8)
    econf = EngineConfig(top_k=2, attn="paged", reuse="chunk", block_size=8,
                         **kw)
    return ContinuousRuntime(cfg, params, corpus, idx, config=econf)


def test_chunk_mode_exact_hits_bit_identical(setup):
    """Repeating ONE request keeps its doc order unchanged, so pass 2
    reuses both chunks exactly: alpha > 0, still flagged exact, tokens
    bit-identical."""
    cfg, params, corpus, idx, wl = setup
    rt = _chunk_runtime(cfg, params, corpus, idx)
    one = rt.serve([wl[0]], max_new_tokens=3)
    two = rt.serve([wl[0]], max_new_tokens=3)
    assert rt.metrics.exact_chunk_hits > 0
    assert rt.metrics.reloc_chunk_hits == 0
    assert one[0].exact and two[0].exact
    assert one[0].alpha == 0 and two[0].alpha > 0
    assert two[0].beta < one[0].beta
    assert one[0].tokens == two[0].tokens


def test_chunk_mode_exact_results_match_oracle(setup):
    """Full zipf workload served twice: doc order churns ACROSS requests,
    so exact, relocated, and miss placements all occur — but every result
    still flagged exact must match the sequential oracle bit-for-bit."""
    cfg, params, corpus, idx, wl = setup
    rt = _chunk_runtime(cfg, params, corpus, idx)
    rt.serve(wl, max_new_tokens=3)
    res = sorted(rt.serve(wl, max_new_tokens=3), key=lambda r: r.req_id)
    srv = RAGServer(cfg, params, corpus, idx, config=EngineConfig(top_k=2))
    seq = sorted(srv.serve(wl, max_new_tokens=3), key=lambda r: r.req_id)
    assert any(a.exact for a in res)
    for a, b in zip(res, seq):
        if a.exact:
            assert a.tokens == b.tokens, (a.req_id, a.tokens, b.tokens)


def test_relocated_chunks_tolerance_bounded(setup):
    """Same docs, reversed order: relocated reuse is flagged exact=False and
    its first-token logit divergence vs the sequential oracle is finite,
    nonzero for at least one request (the approximation is real), and
    accepted by the tolerance comparator at a bound it reports itself."""
    cfg, params, corpus, base_idx, wl = setup
    idx = FlippableIndex(base_idx)
    rt = _chunk_runtime(cfg, params, corpus, idx)
    rt.serve(wl, max_new_tokens=3)                # seed the chunk cache
    idx.reverse = True
    res = sorted(rt.serve(wl, max_new_tokens=3), key=lambda r: r.req_id)
    assert rt.metrics.reloc_chunk_hits > 0
    assert rt.metrics.reloc_recompute_tokens > 0
    assert any(not r.exact for r in res)
    # oracle: full recompute over the SAME reversed doc order
    srv = RAGServer(cfg, params, corpus, idx, config=EngineConfig(top_k=2))
    seq = sorted(srv.serve(wl, max_new_tokens=3), key=lambda r: r.req_id)
    linfs = []
    for a, b in zip(res, seq):
        assert a.first_logits is not None and b.first_logits is not None
        d = float(np.max(np.abs(np.asarray(a.first_logits, np.float64)
                                - np.asarray(b.first_logits, np.float64))))
        assert np.isfinite(d)
        linfs.append(d)
    assert max(linfs) > 0.0
    eps = max(linfs) * 1.01 + 1e-9
    assert token_mismatches(list(zip(res, seq)), "tol", eps) == []
    # exact requests must still match the oracle bit-for-bit
    for a, b in zip(res, seq):
        if a.exact:
            assert a.tokens == b.tokens, (a.req_id, a.tokens, b.tokens)


def test_huge_recompute_budget_degenerates_to_exact(setup):
    """recompute_tokens >= every doc length: relocated hits all reclassify
    as plain misses, so even reversed-order reuse is bit-identical."""
    cfg, params, corpus, base_idx, wl = setup
    idx = FlippableIndex(base_idx)
    rt = _chunk_runtime(cfg, params, corpus, idx, recompute_tokens=10_000)
    rt.serve(wl, max_new_tokens=3)
    idx.reverse = True
    res = sorted(rt.serve(wl, max_new_tokens=3), key=lambda r: r.req_id)
    assert rt.metrics.reloc_chunk_hits == 0
    assert all(r.exact for r in res)
    srv = RAGServer(cfg, params, corpus, idx, config=EngineConfig(top_k=2))
    seq = sorted(srv.serve(wl, max_new_tokens=3), key=lambda r: r.req_id)
    for a, b in zip(res, seq):
        assert a.tokens == b.tokens, (a.req_id, a.tokens, b.tokens)


def test_chunk_mode_block_accounting_balances(setup):
    cfg, params, corpus, idx, wl = setup
    rt = _chunk_runtime(cfg, params, corpus, idx)
    rt.serve(wl, max_new_tokens=3)
    rt.serve(wl, max_new_tokens=3)
    rt.tree.check_invariants()
    tree_blocks = sum(len(n.payload_gpu.blocks) for n in rt.tree.nodes()
                      if n.in_gpu and n.payload_gpu is not None)
    live = rt.store.pool.n_blocks - rt.store.pool.free_blocks
    assert live == tree_blocks + 1      # +1 scratch
    rt.store.pool.check()


def test_chunk_mode_requires_paged(setup):
    cfg, params, corpus, idx, _ = setup
    with pytest.raises(ValueError, match="requires the paged engine"):
        ContinuousRuntime(cfg, params, corpus, idx,
                          config=EngineConfig(top_k=2, attn="dense",
                                              reuse="chunk"))
    # the bad-mode check moved into EngineConfig itself: the config is now
    # the sole front door, so it rejects the value before any engine exists
    with pytest.raises(ValueError, match="reuse must be"):
        EngineConfig(top_k=2, reuse="suffix")
