"""In-process e2e for the ``launch/serve.py`` driver's main() — the A/B
path (`--check-tokens`), the sequential engine, and multi-replica routing
were previously only exercised by hand; this drives the real argument
parser + drivers on a tiny config so CI catches flag/pipeline bitrot.
"""
import pytest

jax = pytest.importorskip("jax")

from repro.launch import serve  # noqa: E402

TINY = ["--requests", "4", "--docs", "8", "--doc-tokens", "10",
        "--top-k", "2", "--max-new-tokens", "2", "--rate", "100"]


def _run_main(monkeypatch, capsys, extra):
    monkeypatch.setattr("sys.argv", ["serve.py"] + TINY + extra)
    serve.main()
    return capsys.readouterr().out


def test_main_check_tokens_single_replica(monkeypatch, capsys):
    """Continuous vs sequential A/B on one replica: main() must run both
    engines and report identical greedy tokens."""
    out = _run_main(monkeypatch, capsys, ["--check-tokens"])
    assert "[continuous]" in out and "[sequential]" in out
    assert "token check: all 4 requests identical" in out


def test_main_chunk_reuse_tolerance(monkeypatch, capsys):
    """--reuse chunk --check-tokens tol:<eps>: the chunk-cache engine's
    approximate outputs verify against the sequential oracle through the
    tolerance comparator (docs/ARCHITECTURE.md §11)."""
    out = _run_main(monkeypatch, capsys,
                    ["--attn", "paged", "--reuse", "chunk",
                     "--recompute-tokens", "8", "--block-size", "8",
                     "--check-tokens", "tol:5"])
    assert "token check: all 4 requests within tol 5" in out


def test_main_check_tokens_two_replicas(monkeypatch, capsys):
    """--replicas 2 --routing affinity: routing never changes computation,
    so the fleet's tokens stay bit-identical to the single sequential
    engine, and the fleet report renders."""
    out = _run_main(monkeypatch, capsys,
                    ["--check-tokens", "--replicas", "2",
                     "--routing", "affinity"])
    assert "continuous x2 (affinity)" in out
    assert "token check: all 4 requests identical" in out
    assert "fleet: 2 replicas" in out
    assert "routed per replica" in out


def test_main_check_tokens_paged_attn(monkeypatch, capsys):
    """--attn paged: decode straight from the paged pool must keep greedy
    tokens bit-identical to the (dense) sequential engine."""
    out = _run_main(monkeypatch, capsys, ["--check-tokens", "--attn", "paged"])
    assert "token check: all 4 requests identical" in out


def test_main_check_tokens_paged_attn_three_replicas(monkeypatch, capsys):
    """--attn paged at N=3: every replica decodes through the kernel-backed
    paged path; the fleet's tokens still match the single dense sequential
    engine exactly."""
    out = _run_main(monkeypatch, capsys,
                    ["--check-tokens", "--attn", "paged", "--replicas", "3"])
    assert "continuous x3 (affinity)" in out
    assert "token check: all 4 requests identical" in out


def test_main_check_tokens_paged_prefill_chunked(monkeypatch, capsys):
    """--attn paged --prefill-chunk: chunked ragged prefill scatters KV
    straight into pool pages (no dense gather anywhere), and greedy tokens
    stay bit-identical to the dense sequential engine."""
    out = _run_main(monkeypatch, capsys,
                    ["--check-tokens", "--attn", "paged",
                     "--prefill-chunk", "6"])
    assert "token check: all 4 requests identical" in out


def test_main_check_tokens_paged_prefill_three_replicas(monkeypatch, capsys):
    """--attn paged --prefill-chunk at N=3: every replica prefills AND
    decodes through the paged kernels; the fleet still matches the single
    dense sequential engine exactly."""
    out = _run_main(monkeypatch, capsys,
                    ["--check-tokens", "--attn", "paged",
                     "--prefill-chunk", "6", "--replicas", "3"])
    assert "continuous x3 (affinity)" in out
    assert "token check: all 4 requests identical" in out


@pytest.mark.multidevice
@pytest.mark.skipif(
    jax.local_device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4 "
           "set before jax import (CI multidevice lane)")
def test_main_check_tokens_paged_prefill_tp2(monkeypatch, capsys):
    """--tp 2 --attn paged --prefill-chunk: the sharded paged-prefill path
    (per-shard kernel dispatch over head-local pool planes) keeps greedy
    tokens bit-identical to the unsharded dense sequential engine."""
    out = _run_main(monkeypatch, capsys,
                    ["--check-tokens", "--attn", "paged",
                     "--prefill-chunk", "6", "--tp", "2"])
    assert "token check: all 4 requests identical" in out


def test_main_sequential_only(monkeypatch, capsys):
    out = _run_main(monkeypatch, capsys, ["--sequential"])
    assert "[sequential] served 4 requests" in out
    assert "[continuous]" not in out


FRONTDOOR = ["--requests", "16", "--max-batch", "2", "--frontdoor",
             "--tenants", "2", "--tenant-queries", "3"]


def test_main_frontdoor_check_tokens_single_replica(monkeypatch, capsys):
    """--frontdoor on repeat-heavy tenant traffic: the query cache absorbs
    repeats (trace longer than the in-flight window, so originals complete
    and populate the cache), and --check-tokens compares ONLY the admitted
    misses against the sequential engine — bit-identical."""
    out = _run_main(monkeypatch, capsys, FRONTDOOR + ["--check-tokens"])
    assert "[frontdoor x1" in out
    assert "hit_exact" in out                    # repeats actually hit
    assert "front door" in out and "SLO tenant0" in out
    assert "front-door miss requests identical" in out
    assert "excluded by construction" in out


def test_main_frontdoor_check_tokens_three_replicas(monkeypatch, capsys):
    """--frontdoor --replicas 3: misses fan out across the fleet through
    the affinity router and still match the single sequential engine."""
    out = _run_main(monkeypatch, capsys,
                    FRONTDOOR + ["--check-tokens", "--replicas", "3"])
    assert "frontdoor x3 (affinity)" in out
    assert "fleet: 3 replicas" in out
    assert "front-door miss requests identical" in out


def test_main_frontdoor_ignored_for_sequential(monkeypatch, capsys):
    out = _run_main(monkeypatch, capsys, ["--frontdoor", "--sequential"])
    assert "--frontdoor requires the continuous engine; ignored" in out
    assert "[sequential] served 4 requests" in out


def test_main_workload_knob_flags(monkeypatch, capsys):
    """PR 6 satellite: drift/zipf/phase/output-length knobs are plumbed
    through the CLI into make_workload."""
    out = _run_main(monkeypatch, capsys,
                    ["--sequential", "--zipf-s", "1.5", "--drift", "0.3",
                     "--n-phases", "4", "--output-len-mean", "2"])
    assert "[sequential] served 4 requests" in out
