"""In-process e2e for the ``launch/serve.py`` driver's main() — the A/B
path (`--check-tokens`), the sequential engine, and multi-replica routing
were previously only exercised by hand; this drives the real argument
parser + drivers on a tiny config so CI catches flag/pipeline bitrot.
"""
import pytest

jax = pytest.importorskip("jax")

from repro.launch import serve  # noqa: E402

TINY = ["--requests", "4", "--docs", "8", "--doc-tokens", "10",
        "--top-k", "2", "--max-new-tokens", "2", "--rate", "100"]


def _run_main(monkeypatch, capsys, extra):
    monkeypatch.setattr("sys.argv", ["serve.py"] + TINY + extra)
    serve.main()
    return capsys.readouterr().out


def test_main_check_tokens_single_replica(monkeypatch, capsys):
    """Continuous vs sequential A/B on one replica: main() must run both
    engines and report identical greedy tokens."""
    out = _run_main(monkeypatch, capsys, ["--check-tokens"])
    assert "[continuous]" in out and "[sequential]" in out
    assert "token check: all 4 requests identical" in out


def test_main_check_tokens_two_replicas(monkeypatch, capsys):
    """--replicas 2 --routing affinity: routing never changes computation,
    so the fleet's tokens stay bit-identical to the single sequential
    engine, and the fleet report renders."""
    out = _run_main(monkeypatch, capsys,
                    ["--check-tokens", "--replicas", "2",
                     "--routing", "affinity"])
    assert "continuous x2 (affinity)" in out
    assert "token check: all 4 requests identical" in out
    assert "fleet: 2 replicas" in out
    assert "routed per replica" in out


def test_main_check_tokens_paged_attn(monkeypatch, capsys):
    """--attn paged: decode straight from the paged pool must keep greedy
    tokens bit-identical to the (dense) sequential engine."""
    out = _run_main(monkeypatch, capsys, ["--check-tokens", "--attn", "paged"])
    assert "token check: all 4 requests identical" in out


def test_main_check_tokens_paged_attn_three_replicas(monkeypatch, capsys):
    """--attn paged at N=3: every replica decodes through the kernel-backed
    paged path; the fleet's tokens still match the single dense sequential
    engine exactly."""
    out = _run_main(monkeypatch, capsys,
                    ["--check-tokens", "--attn", "paged", "--replicas", "3"])
    assert "continuous x3 (affinity)" in out
    assert "token check: all 4 requests identical" in out


def test_main_sequential_only(monkeypatch, capsys):
    out = _run_main(monkeypatch, capsys, ["--sequential"])
    assert "[sequential] served 4 requests" in out
    assert "[continuous]" not in out
