"""Per-architecture smoke tests: reduced variant of each assigned family runs
one forward and one train step on CPU; output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, get_config, get_reduced
from repro.models import model as M
from repro.training.optimizer import AdamWConfig, init_state
from repro.training.train_lib import make_train_step


def _inputs(cfg, key, B=2, S=24, with_labels=False):
    if cfg.n_codebooks:
        toks = jax.random.randint(key, (B, cfg.n_codebooks, S), 0,
                                  cfg.vocab_size)
        out = {"tokens": toks}
        if with_labels:
            out["labels"] = jnp.roll(toks, -1, axis=-1)
        return out
    if cfg.family == "vlm":
        vt = cfg.vision_tokens
        toks = jax.random.randint(key, (B, S - vt), 0, cfg.vocab_size)
        out = {"tokens": toks,
               "patch_embeds": 0.02 * jax.random.normal(
                   key, (B, vt, cfg.d_model))}
        if with_labels:
            out["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
            out["loss_mask"] = jnp.concatenate(
                [jnp.zeros((B, vt)), jnp.ones((B, S - vt))], axis=1)
        return out
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    out = {"tokens": toks}
    if with_labels:
        out["labels"] = jnp.roll(toks, -1, axis=-1)
    return out


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_smoke(arch):
    cfg = get_reduced(arch)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    assert cfg.moe_experts <= 4
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    inp = _inputs(cfg, key)
    logits = M.forward(cfg, params, inp)
    B, S = 2, 24
    if cfg.n_codebooks:
        assert logits.shape == (B, S, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_smoke(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(1)
    params = M.init_params(cfg, key)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    state = init_state(params)
    step = make_train_step(cfg, opt)
    batch = _inputs(cfg, key, with_labels=True)
    params2, state2, metrics = step(params, state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    spec = {
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab_size) == spec
