"""Trip-count-aware HLO analyzer: exactness on scan fixtures (the roofline's
foundation — plain cost_analysis undercounts while bodies)."""
import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.launch.hlo_analysis import analyze, normalize_cost_analysis


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_single_matmul_flops():
    x = jax.ShapeDtypeStruct((256, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 64), jnp.float32)
    t = analyze(_compile(lambda a, b: a @ b, x, w).as_text())
    assert t.flops == pytest.approx(2 * 256 * 128 * 64, rel=0.01)


def test_scan_multiplies_trip_count():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 128, 128), jnp.float32)

    def f(x, ws):
        return lax.scan(lambda c, w: (c @ w, None), x, ws)[0]

    t = analyze(_compile(f, x, ws).as_text())
    assert t.flops == pytest.approx(7 * 2 * 128 ** 3, rel=0.01)


def test_nested_scan():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 5, 64, 64), jnp.float32)

    def f(x, ws):
        def outer(c, wrow):
            return lax.scan(lambda c2, w: (c2 @ w, None), c, wrow)[0], None
        return lax.scan(outer, x, ws)[0]

    t = analyze(_compile(f, x, ws).as_text())
    assert t.flops == pytest.approx(15 * 2 * 64 ** 3, rel=0.01)


def test_undercount_vs_raw_cost_analysis():
    """Documents the undercount that motivates the analyzer."""
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)

    def f(x, ws):
        return lax.scan(lambda c, w: (c @ w, None), x, ws)[0]

    comp = _compile(f, x, ws)
    raw = normalize_cost_analysis(comp.cost_analysis()).get("flops", 0.0)
    ours = analyze(comp.as_text()).flops
    assert ours >= 9 * raw   # raw counts the body once


def test_dot_bytes_positive():
    x = jax.ShapeDtypeStruct((32, 32), jnp.bfloat16)
    t = analyze(_compile(lambda a: a @ a, x).as_text())
    assert t.dot_bytes >= 3 * 32 * 32 * 2
