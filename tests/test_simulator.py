"""Discrete-event simulator: end-to-end behaviour + paper-trend assertions."""
import dataclasses

import pytest

from repro.core.profiler import A10G_MISTRAL_7B
from repro.retrieval.corpus import make_corpus, make_workload
from repro.retrieval.vectordb import IVFIndex
from repro.serving.simulator import (RAGSimulator, SimConfig,
                                     merge_sim_metrics, simulate_replicas)


@pytest.fixture(scope="module")
def setup():
    corpus = make_corpus(1000, mean_doc_tokens=800, seed=0)
    idx = IVFIndex(corpus.doc_vectors, n_clusters=32, nprobe=8)
    wl = make_workload(corpus, n_requests=150, rate=0.8, zipf_s=1.0, seed=1)
    return corpus, idx, wl


def run(setup, **kw):
    corpus, idx, wl = setup
    cfg = SimConfig(profile=A10G_MISTRAL_7B, **kw)
    return RAGSimulator(cfg, corpus, idx, wl).run()


def test_all_requests_complete(setup):
    m = run(setup)
    assert m.completed == 150
    assert m.avg_ttft > 0 and m.p99_ttft >= m.p50_ttft


def test_ragcache_beats_vllm_baseline(setup):
    """Paper Fig. 13/14 trend: caching cuts TTFT vs no-cache vLLM."""
    rag = run(setup)
    vllm = run(setup, gpu_cache_bytes=0, host_cache_bytes=0,
               reorder=False, speculative=False)
    assert rag.avg_ttft < vllm.avg_ttft
    assert rag.doc_hit_rate > 0.2 and vllm.doc_hit_rate == 0.0


def test_ragcache_beats_gpu_only_lru(setup):
    """Paper trend vs SGLang-like baseline (GPU-only cache, LRU)."""
    rag = run(setup)
    sgl = run(setup, host_cache_bytes=0, policy="lru",
              reorder=False, speculative=False,
              gpu_cache_bytes=2 * 2**30)
    assert rag.doc_hit_rate >= sgl.doc_hit_rate
    assert rag.avg_ttft <= sgl.avg_ttft * 1.05


def test_pgdsf_beats_lru_hit_rate(setup):
    """Paper Fig. 17: PGDSF >= LRU document hit rate at equal capacity."""
    small = dict(gpu_cache_bytes=1 * 2**30, host_cache_bytes=4 * 2**30,
                 reorder=False, speculative=False)
    pg = run(setup, policy="pgdsf", **small)
    lru = run(setup, policy="lru", **small)
    assert pg.doc_hit_rate >= lru.doc_hit_rate - 0.01


def test_dsp_reduces_non_overlap(setup):
    """Paper Fig. 19 / Table 3: DSP shrinks non-overlapped search time."""
    dsp = run(setup, speculative=True)
    nod = run(setup, speculative=False)
    assert dsp.avg_non_overlap_search <= nod.avg_non_overlap_search + 1e-9
    assert nod.wasted_prefills == 0


def test_cache_accounting_consistent(setup):
    corpus, idx, wl = setup
    cfg = SimConfig(profile=A10G_MISTRAL_7B)
    sim = RAGSimulator(cfg, corpus, idx, wl)
    sim.run()
    sim.tree.check_invariants()


def test_simulator_is_deterministic(setup):
    """Two runs with the same seeded config + workload produce identical
    SimMetrics field-for-field: the simulator owns a seeded
    ``random.Random`` (SimConfig.seed) and touches no module-level global
    RNG state.  Run WITH latency jitter so the assertion is not vacuous —
    the stochastic path itself must be seed-reproducible — and check a
    different seed actually changes the stochastic outcome."""
    corpus, idx, wl = setup
    cfg = SimConfig(profile=A10G_MISTRAL_7B, seed=7, latency_jitter=0.2)
    m1 = RAGSimulator(cfg, corpus, idx, wl).run()
    m2 = RAGSimulator(cfg, corpus, idx, wl).run()
    assert dataclasses.asdict(m1) == dataclasses.asdict(m2)
    other = dataclasses.replace(cfg, seed=8)
    m3 = RAGSimulator(other, corpus, idx, wl).run()
    assert m3.ttfts != m1.ttfts
    # and the analytic (jitter-free) path is deterministic trivially
    base = SimConfig(profile=A10G_MISTRAL_7B)
    b1 = RAGSimulator(base, corpus, idx, wl).run()
    b2 = RAGSimulator(base, corpus, idx, wl).run()
    assert dataclasses.asdict(b1) == dataclasses.asdict(b2)


def test_multi_replica_sim_deterministic_and_complete(setup):
    """The replica-sim harness (same ReplicaRouter the real driver uses)
    serves every request exactly once, deterministically, and affinity
    keeps at least as many GPU-tier hit tokens as round-robin scatter."""
    corpus, idx, wl = setup
    cfg = SimConfig(profile=A10G_MISTRAL_7B)
    a1 = simulate_replicas(cfg, corpus, idx, wl, n_replicas=3,
                           routing="affinity")
    a2 = simulate_replicas(cfg, corpus, idx, wl, n_replicas=3,
                           routing="affinity")
    rr = simulate_replicas(cfg, corpus, idx, wl, n_replicas=3,
                           routing="round_robin")
    assert a1.metrics.completed == rr.metrics.completed == len(wl)
    assert sum(a1.router_stats["routed"]) == len(wl)
    assert dataclasses.asdict(a1.metrics) == dataclasses.asdict(a2.metrics)
    assert a1.router_stats == a2.router_stats
    assert a1.metrics.hit_tokens_gpu >= rr.metrics.hit_tokens_gpu
    # merging one replica's metrics is the identity on the headline numbers
    solo = simulate_replicas(cfg, corpus, idx, wl, n_replicas=1)
    remerged = merge_sim_metrics(solo.per_replica)
    assert remerged.avg_ttft == pytest.approx(solo.metrics.avg_ttft)
    assert remerged.completed == solo.metrics.completed
