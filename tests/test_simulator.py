"""Discrete-event simulator: end-to-end behaviour + paper-trend assertions."""
import pytest

from repro.core.profiler import A10G_MISTRAL_7B
from repro.retrieval.corpus import make_corpus, make_workload
from repro.retrieval.vectordb import IVFIndex
from repro.serving.simulator import RAGSimulator, SimConfig


@pytest.fixture(scope="module")
def setup():
    corpus = make_corpus(1000, mean_doc_tokens=800, seed=0)
    idx = IVFIndex(corpus.doc_vectors, n_clusters=32, nprobe=8)
    wl = make_workload(corpus, n_requests=150, rate=0.8, zipf_s=1.0, seed=1)
    return corpus, idx, wl


def run(setup, **kw):
    corpus, idx, wl = setup
    cfg = SimConfig(profile=A10G_MISTRAL_7B, **kw)
    return RAGSimulator(cfg, corpus, idx, wl).run()


def test_all_requests_complete(setup):
    m = run(setup)
    assert m.completed == 150
    assert m.avg_ttft > 0 and m.p99_ttft >= m.p50_ttft


def test_ragcache_beats_vllm_baseline(setup):
    """Paper Fig. 13/14 trend: caching cuts TTFT vs no-cache vLLM."""
    rag = run(setup)
    vllm = run(setup, gpu_cache_bytes=0, host_cache_bytes=0,
               reorder=False, speculative=False)
    assert rag.avg_ttft < vllm.avg_ttft
    assert rag.doc_hit_rate > 0.2 and vllm.doc_hit_rate == 0.0


def test_ragcache_beats_gpu_only_lru(setup):
    """Paper trend vs SGLang-like baseline (GPU-only cache, LRU)."""
    rag = run(setup)
    sgl = run(setup, host_cache_bytes=0, policy="lru",
              reorder=False, speculative=False,
              gpu_cache_bytes=2 * 2**30)
    assert rag.doc_hit_rate >= sgl.doc_hit_rate
    assert rag.avg_ttft <= sgl.avg_ttft * 1.05


def test_pgdsf_beats_lru_hit_rate(setup):
    """Paper Fig. 17: PGDSF >= LRU document hit rate at equal capacity."""
    small = dict(gpu_cache_bytes=1 * 2**30, host_cache_bytes=4 * 2**30,
                 reorder=False, speculative=False)
    pg = run(setup, policy="pgdsf", **small)
    lru = run(setup, policy="lru", **small)
    assert pg.doc_hit_rate >= lru.doc_hit_rate - 0.01


def test_dsp_reduces_non_overlap(setup):
    """Paper Fig. 19 / Table 3: DSP shrinks non-overlapped search time."""
    dsp = run(setup, speculative=True)
    nod = run(setup, speculative=False)
    assert dsp.avg_non_overlap_search <= nod.avg_non_overlap_search + 1e-9
    assert nod.wasted_prefills == 0


def test_cache_accounting_consistent(setup):
    corpus, idx, wl = setup
    cfg = SimConfig(profile=A10G_MISTRAL_7B)
    sim = RAGSimulator(cfg, corpus, idx, wl)
    sim.run()
    sim.tree.check_invariants()
