"""Three-tier (GPU/host/disk) knowledge-tree cache: mmap disk tier, PGDSF
clock cascade, pin safety, and file reclamation.  Fast lane — the disk tier
runs against a pytest tmpdir, no slow marker needed."""
import numpy as np
import pytest

from repro.core.controller import RAGController
from repro.core.knowledge_tree import (CacheBackend, EvictionError,
                                       KnowledgeTree)
from repro.core.profiler import A10G_MISTRAL_7B, CostProfiler
from repro.kvcache.paged import DiskSegmentStore, PagedKVStore

KV_SHAPE = dict(n_layers=2, n_blocks=32, block_size=4, n_kv=2, head_dim=8)
KV_BYTES = 2 * 2 * 2 * 8 * 4            # 2(k,v) * L * KV * hd * f32


def paged_tree(tmp_path, gpu_tokens=10, host_tokens=10, disk_tokens=100):
    """A tree whose payloads are real paged segments and whose disk tier is
    real mmap files under ``tmp_path`` (the serving runtime's backend)."""
    from repro.serving.runtime import PagedBackend
    store = PagedKVStore(**KV_SHAPE)
    disk = DiskSegmentStore(str(tmp_path / "kv"), disk_tokens * KV_BYTES)
    tree = KnowledgeTree(gpu_tokens * KV_BYTES, host_tokens * KV_BYTES,
                         disk_tokens * KV_BYTES,
                         backend=PagedBackend(store, disk),
                         bytes_per_token=KV_BYTES)
    return tree, store, disk


def rand_kv(tokens, seed):
    rng = np.random.default_rng(seed)
    k = rng.normal(size=(2, 1, tokens, 2, 8)).astype(np.float32)
    v = rng.normal(size=(2, 1, tokens, 2, 8)).astype(np.float32)
    return k, v


def put_doc(tree, store, parent, doc_id, tokens=10, seed=None):
    k, v = rand_kv(tokens, doc_id if seed is None else seed)
    node, _ = tree.insert(parent, doc_id, tokens, store.put(k, v))
    tree.update_on_access(node, False, 0, tokens)
    return node, k, v


def test_disk_roundtrip_bit_identical(tmp_path):
    """A doc's KV must survive GPU -> host -> disk -> GPU unchanged, bit for
    bit (mmap write + read + re-put into the paged store)."""
    tree, store, disk = paged_tree(tmp_path)
    node, k, v = put_doc(tree, store, tree.root, 7)
    tree.evict_gpu(10 * KV_BYTES)        # GPU -> host (dense numpy copy)
    assert node.in_host and not node.in_gpu
    tree.evict_host(10 * KV_BYTES)       # host -> disk (mmap write)
    assert node.in_disk and not node.in_host and not node.in_gpu
    assert disk.n_files == 1
    tree.ensure_in_gpu([node])           # disk -> host -> GPU
    assert node.in_gpu and node.in_host and node.in_disk
    k2, v2 = store.gather(node.payload_gpu)
    assert np.array_equal(np.asarray(k2), k)
    assert np.array_equal(np.asarray(v2), v)
    tree.check_invariants()
    assert tree.stats["spill_bytes"] == tree.stats["fetch_bytes"] == 10 * KV_BYTES


def test_spill_only_once(tmp_path):
    """The swap-out-only-once invariant one tier down: while a node's disk
    file is live, re-demoting it from host moves zero bytes."""
    tree, store, disk = paged_tree(tmp_path)
    node, _, _ = put_doc(tree, store, tree.root, 1)
    tree.evict_gpu(10 * KV_BYTES)
    tree.evict_host(10 * KV_BYTES)       # first spill: writes the file
    assert tree.stats["spill_bytes"] == 10 * KV_BYTES
    tree.fetch_to_host(node)             # disk -> host again
    assert node.in_host and node.in_disk
    tree.evict_host(10 * KV_BYTES)       # second demotion: file still live
    assert tree.stats["spill_bytes"] == 10 * KV_BYTES   # no second write
    assert tree.stats["spill_skipped"] == 1
    assert disk.n_files == 1
    tree.check_invariants()


def test_eviction_cascade_respects_pins(tmp_path):
    """A pinned path must never be demoted by the cascade; when everything
    in GPU is pinned, eviction fails loudly instead of breaking a request."""
    tree, store, _ = paged_tree(tmp_path, gpu_tokens=20)
    a, _, _ = put_doc(tree, store, tree.root, 1)
    b, _, _ = put_doc(tree, store, a, 2)
    a.pinned = b.pinned = True
    with pytest.raises(EvictionError):
        tree.insert(tree.root, 9, 10, None)   # needs room; all pinned
    assert a.in_gpu and b.in_gpu
    b.pinned = False
    tree.insert(tree.root, 9, 10, store.put(*rand_kv(10, 9)))
    assert not b.in_gpu and a.in_gpu          # only the unpinned leaf moved
    tree.check_invariants()


def test_disk_files_reclaimed_on_eviction(tmp_path):
    """Disk-tier eviction and node death must delete the mmap files — byte
    and file accounting return to zero."""
    tree, store, disk = paged_tree(tmp_path, gpu_tokens=10, host_tokens=10,
                                   disk_tokens=20)
    nodes = []
    for d in range(4):                   # each insert cascades the previous
        n, _, _ = put_doc(tree, store, tree.root, d)
        nodes.append(n)
    # capacity: 1 node in GPU, 1 in host, 2 on disk -> the 4th insert's
    # cascade must have dropped one disk file already
    assert disk.n_files <= 2
    assert disk.used_bytes == disk.n_files * 10 * KV_BYTES
    # drain everything through the cascade: all files must be reclaimed
    tree.evict_gpu_until(lambda: tree.gpu_used == 0)
    tree.evict_host(tree.host_capacity)
    tree.evict_disk(tree.disk_capacity)
    assert disk.n_files == 0 and disk.used_bytes == 0
    assert list((tmp_path / "kv").iterdir()) == []
    tree.check_invariants()


def test_disk_tier_requires_host_tier():
    with pytest.raises(ValueError):
        KnowledgeTree(100, 0, 100)


def test_prefetch_stages_disk_into_host(tmp_path):
    """fetch_to_host is the retrieval-overlap hook: it stages a disk-only
    node into host so the engine-critical promote is a pure host->GPU copy."""
    tree, store, _ = paged_tree(tmp_path)
    node, _, _ = put_doc(tree, store, tree.root, 3)
    tree.evict_gpu(10 * KV_BYTES)
    tree.evict_host(10 * KV_BYTES)
    assert node.fastest_tier() == 2      # disk-only
    tree.fetch_to_host(node)
    fetched = tree.stats["fetch_bytes"]
    assert node.in_host and fetched == 10 * KV_BYTES
    tree.ensure_in_gpu([node])           # prefetched: no second disk read
    assert tree.stats["fetch_bytes"] == fetched
    tree.check_invariants()


def test_pgdsf_ordering_across_tiers():
    """Property (hypothesis): after accessing sibling docs with random
    frequencies and then cascading them down the hierarchy, tier residency
    respects PGDSF order — every GPU resident outranks every host-only
    resident, which outranks every disk-only resident, which outranks
    everything evicted off the end."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    TOK = 10

    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.integers(1, 50), min_size=1, max_size=12),
           st.integers(0, 3))
    def prop(freqs, filler_count):
        prof = CostProfiler.from_profile(A10G_MISTRAL_7B)
        # GPU holds all docs during the access phase; fillers then shrink
        # the effective GPU space and force the cascade
        gpu = (len(freqs) + 3) * TOK
        tree = KnowledgeTree(gpu, 2 * TOK, 2 * TOK, profiler=prof,
                             bytes_per_token=1)
        nodes = {}
        for i, f in enumerate(freqs):
            n, _ = tree.insert(tree.root, i, TOK)
            for _ in range(f):
                tree.update_on_access(n, False, 0, TOK)
            nodes[i] = n
        # hot fillers push the real docs down the hierarchy
        for j in range(3 + filler_count):
            n, _ = tree.insert(tree.root, 1000 + j, TOK)
            for _ in range(1000):
                tree.update_on_access(n, True, 0, TOK)
        tree.check_invariants()

        def rank(n):                     # higher = faster tier
            if n.in_gpu:
                return 3
            if n.in_host:
                return 2
            if n.in_disk:
                return 1
            return 0

        ranked = sorted(nodes.items(), key=lambda kv: freqs[kv[0]])
        for (i, a), (j, b) in zip(ranked, ranked[1:]):
            if freqs[i] < freqs[j]:      # ties may order either way
                assert rank(a) <= rank(b), (
                    f"doc {i} (f={freqs[i]}) in tier rank {rank(a)} above "
                    f"doc {j} (f={freqs[j]}) in rank {rank(b)}")

    prop()


def test_three_tier_invariants_under_random_workload():
    """Property (hypothesis): random plan/promote/commit traffic through the
    controller never violates tier invariants, byte accounting, or the
    live-copy flags, with the disk tier enabled."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.lists(
        st.tuples(st.lists(st.integers(0, 6), min_size=1, max_size=4),
                  st.integers(10, 120)),
        min_size=1, max_size=60))
    def prop(ops):
        prof = CostProfiler.from_profile(A10G_MISTRAL_7B)
        t = KnowledgeTree(500, 300, 900, profiler=prof, bytes_per_token=1)
        c = RAGController(t)
        for doc_ids, tok in ops:
            doc_ids = list(dict.fromkeys(doc_ids))
            plan = c.plan(doc_ids, [tok] * len(doc_ids), 16)
            c.promote(plan)
            c.commit(plan)
            t.check_invariants()
        assert 0.0 <= c.doc_hit_rate <= 1.0
        alpha_total = (t.stats["hit_tokens_gpu"] + t.stats["hit_tokens_host"]
                       + t.stats["hit_tokens_disk"])
        assert alpha_total >= 0

    prop()


def test_gpu_failure_recovery_reclaims_disk(tmp_path):
    """Device loss with a disk tier: nodes with host/disk replicas survive,
    and slower-tier state stranded under a lost parent is reclaimed — disk
    files included (unreachable state would leak its mmap segments)."""
    from repro.core.fault_tolerance import (recover_from_gpu_failure,
                                            replicate_hot_nodes)
    tree, store, disk = paged_tree(tmp_path, gpu_tokens=30, host_tokens=10,
                                   disk_tokens=40)
    a, _, _ = put_doc(tree, store, tree.root, 1)        # will be replicated
    b, _, _ = put_doc(tree, store, a, 2)                # GPU-only: lost
    c, _, _ = put_doc(tree, store, b, 3)                # pushed to disk
    tree.evict_gpu(10 * KV_BYTES)                       # c -> host
    tree.evict_host(10 * KV_BYTES)                      # c -> disk
    assert c.fastest_tier() == 2 and disk.n_files == 1
    replicate_hot_nodes(tree, 10 * KV_BYTES)            # a gets a host copy
    assert a.in_host
    recovered, lost = recover_from_gpu_failure(tree)
    tree.check_invariants()
    # a survives on host; b is lost (GPU-only), which strands c's disk file
    assert a.in_host and not a.in_gpu
    assert not b.cached and not c.cached
    assert (recovered, lost) == (1, 2)
    assert disk.n_files == 0 and tree.disk_used == 0


def test_accounting_backend_cascade():
    """The default (accounting-only) backend drives the same cascade — the
    simulator's configuration.  Chained payload handles follow the node."""
    t = KnowledgeTree(100, 100, 100, backend=CacheBackend(),
                      bytes_per_token=1)
    n, _ = t.insert(t.root, 1, 100, payload="kv")
    t.update_on_access(n, False, 0, 100)
    t.evict_gpu(100)
    t.evict_host(100)
    assert n.payload_disk == "kv" and n.fastest_tier() == 2
    t.ensure_in_gpu([n])
    assert n.payload_gpu == "kv"
    t.check_invariants()


@pytest.mark.parametrize("max_new", [3])
def test_runtime_disk_tier_tokens_identical(tmp_path, max_new):
    """End-to-end acceptance: the continuous runtime with a disk tier and a
    GPU+host budget small enough to force disk demotions mid-run produces
    greedy tokens bit-identical to the sequential engine (the disk tier is
    a pure placement change)."""
    jax = pytest.importorskip("jax")
    from repro.configs import get_reduced
    from repro.models import model as M
    from repro.retrieval.corpus import make_corpus, make_workload
    from repro.retrieval.vectordb import IVFIndex
    from repro.serving.engine import RAGServer
    from repro.serving.runtime import ContinuousRuntime

    cfg = get_reduced("qwen2-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    corpus = make_corpus(16, mean_doc_tokens=24, vocab=cfg.vocab_size, seed=0)
    idx = IVFIndex(corpus.doc_vectors, n_clusters=4, nprobe=4)
    wl = make_workload(corpus, n_requests=6, rate=100.0, question_tokens=8,
                       vocab=cfg.vocab_size, zipf_s=1.2, seed=1)
    from repro.serving.config import EngineConfig
    econf = EngineConfig(gpu_cache_bytes=112 * 1024,
                         host_cache_bytes=32 * 1024,
                         disk_cache_bytes=2 * 2**20,
                         disk_cache_dir=str(tmp_path), top_k=2)
    rt = ContinuousRuntime(cfg, params, corpus, idx, config=econf)
    res = rt.serve(wl, max_new_tokens=max_new)
    srv = RAGServer(cfg, params, corpus, idx, config=econf)
    seq = sorted(srv.serve(wl, max_new_tokens=max_new), key=lambda r: r.req_id)
    for a, b in zip(res, seq):
        assert a.req_id == b.req_id and a.tokens == b.tokens
    # the tiny budgets must actually have exercised the disk tier
    assert rt.tree.stats["spill_bytes"] > 0, "no disk demotion happened"
    rt.tree.check_invariants()
    srv.tree.check_invariants()
    # force every cached doc onto disk, then re-serve: the prefix hit now
    # comes from the disk tier (prefetch overlapped with search + fetch on
    # promote) and the tokens are unchanged
    rt.tree.evict_gpu_until(lambda: rt.tree.gpu_used == 0)
    rt.tree.evict_host(rt.tree.host_capacity)
    assert all(n.fastest_tier() == 2 for n in rt.tree.nodes() if n.cached)
    again = rt.serve([wl[0]], max_new_tokens=max_new)
    assert again[0].tokens == res[0].tokens
    # the hit was served by bytes that lived only on disk: the mmap read
    # happened (fetch), it was prefetched during retrieval stages (overlap),
    # and the request got a cached prefix it could not have had otherwise.
    # Plan-time tier attribution may credit host or even GPU — the prefetch
    # and a speculative promote can stage the path upward before the final
    # plan runs, which is exactly the overlap working as designed.
    assert rt.tree.stats["fetch_bytes"] > 0, "disk hit never fetched"
    assert rt.metrics.summary()["disk_prefetches"] > 0
    assert again[0].alpha > 0, "disk-resident prefix was not hit"
    rt.tree.check_invariants()
