"""Vector DB: staged-search semantics + retrieval-pattern characterization."""
import numpy as np
import pytest

from repro.retrieval.corpus import access_cdf, make_corpus, make_workload
from repro.retrieval.vectordb import FlatIndex, IVFIndex


@pytest.fixture(scope="module")
def corpus():
    return make_corpus(300, embed_dim=16, seed=0)


def test_staged_final_equals_full(corpus):
    flat = FlatIndex(corpus.doc_vectors, n_stages=4)
    ivf = IVFIndex(corpus.doc_vectors, n_clusters=16, nprobe=16)
    rng = np.random.default_rng(1)
    for _ in range(10):
        q = rng.normal(size=16).astype(np.float32)
        full = flat.search(q, 3)
        stages = list(flat.staged_search(q, 3))
        assert list(stages[-1].topk) == full
        assert stages[-1].is_final and not stages[0].is_final
        # IVF with all clusters probed must match exact search
        ivf_stages = list(ivf.staged_search(q, 3))
        assert list(ivf_stages[-1].topk) == full


def test_staged_fraction_monotone(corpus):
    flat = FlatIndex(corpus.doc_vectors, n_stages=5)
    q = corpus.doc_vectors[0]
    fr = [s.fraction_searched for s in flat.staged_search(q, 2)]
    assert fr == sorted(fr) and fr[-1] <= 1.0


def test_ivf_recall(corpus):
    """IVF top-1 recall vs exact search — queries are near their target doc."""
    ivf = IVFIndex(corpus.doc_vectors, n_clusters=16, nprobe=4)
    wl = make_workload(corpus, n_requests=100, rate=10, seed=2)
    hit = sum(ivf.search(r.query_vec, 1)[0] == r.target_doc for r in wl)
    assert hit >= 85


def test_retrieval_pattern_is_skewed(corpus):
    """Paper §3.2 / Fig. 5: a small fraction of docs gets most accesses."""
    wl = make_workload(corpus, n_requests=2000, rate=10, zipf_s=1.0, seed=3)
    frac, cdf = access_cdf([r.target_doc for r in wl], 300)
    top10pct = cdf[int(0.10 * 300)]
    assert top10pct > 0.5, top10pct      # >>10% of accesses on top 10% docs
    uniform = 0.10
    assert top10pct > 3 * uniform
