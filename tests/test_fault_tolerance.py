"""Fault tolerance (§6) + iterative retrieval (§9) tests."""
import pytest

from repro.core.controller import RAGController
from repro.core.fault_tolerance import (RetryPolicy, recover_from_gpu_failure,
                                        replicate_hot_nodes, serve_with_retry)
from repro.core.iterative import run_iterative
from repro.core.knowledge_tree import KnowledgeTree
from repro.core.profiler import A10G_MISTRAL_7B, CostProfiler


def make_tree(gpu=1000, host=4000):
    return KnowledgeTree(gpu, host, profiler=CostProfiler.from_profile(
        A10G_MISTRAL_7B), bytes_per_token=1)


def test_hot_node_replication_and_recovery():
    t = make_tree()
    c = RAGController(t)
    # hot chain [1,2], cold node [3]
    for _ in range(5):
        p = c.plan([1, 2], [100, 100], 16)
        c.promote(p)
        c.commit(p)
    p = c.plan([3], [100], 16)
    c.promote(p)
    c.commit(p)
    n = replicate_hot_nodes(t, budget_bytes=200)
    assert n == 200            # the two hottest nodes
    t.check_invariants()
    recovered, lost = recover_from_gpu_failure(t)
    assert recovered == 2 and lost == 1
    t.check_invariants()
    # the hot path is still a (host) cache hit; the cold one is gone
    assert len(t.match_prefix([1, 2])) == 2
    assert len(t.match_prefix([3])) == 0


def test_recovery_never_leaves_orphan_children():
    t = make_tree()
    c = RAGController(t)
    p = c.plan([1, 2, 3], [100] * 3, 16)
    c.promote(p)
    c.commit(p)
    # replicate only the root child -> children must be dropped on failure
    replicate_hot_nodes(t, budget_bytes=100)
    recovered, lost = recover_from_gpu_failure(t)
    assert recovered == 1 and lost == 2
    t.check_invariants()


def test_retry_wrapper():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return "ok"

    assert serve_with_retry(flaky, RetryPolicy(max_attempts=3)) == "ok"
    with pytest.raises(RuntimeError):
        serve_with_retry(lambda: 1 / 0, RetryPolicy(max_attempts=2))


def test_iterative_retrieval_extends_prefix():
    """Hop i+1 must hit the whole path hop i inserted (paper §9)."""
    t = make_tree(gpu=10_000, host=10_000)
    c = RAGController(t)
    hops = run_iterative(
        c,
        retrieve_fn=lambda h: [10 + h],
        doc_tokens_fn=lambda d: 100,
        n_hops=3,
        question_tokens=16,
    )
    # hop 0: all new; hop k: k cached docs
    assert [h.alpha for h in hops] == [0, 100, 200]
    assert [len(h.plan.hit_nodes) for h in hops] == [0, 1, 2]
    # a second identical chain is a full hit
    hops2 = run_iterative(c, lambda h: [10 + h], lambda d: 100, 3, 16)
    assert hops2[-1].alpha == 300
