"""Conformance tests for the ``serving/backend.py::Backend`` protocol.

The tier-hop contract used to exist only by convention across three
backends; this file holds all FOUR implementations (the duck-typed
``CacheBackend`` base, ``PagedBackend``, the tensor-parallel
``ShardedPagedBackend``, ``_JaxBackend``, ``_SimBackend``) to the explicit
Protocol, and exercises the base implementation's tier moves live so the
generic ``demote_copy``/``promote_copy``/``free_tier`` dispatch stays
wired to the named hops.
"""
import pytest

jax = pytest.importorskip("jax")

from repro.core.knowledge_tree import CacheBackend, Node  # noqa: E402
from repro.core.profiler import A10G_MISTRAL_7B  # noqa: E402
from repro.serving.backend import Backend, conforms  # noqa: E402
from repro.serving.engine import _JaxBackend  # noqa: E402
from repro.serving.runtime import (PagedBackend,  # noqa: E402
                                   ShardedPagedBackend)
from repro.serving.simulator import _SimBackend  # noqa: E402


def _node():
    return Node(doc_id=0, parent=None, n_tokens=4, bytes_=64)


@pytest.mark.parametrize("make", [
    CacheBackend,
    lambda: PagedBackend(store=None, disk=None),
    lambda: ShardedPagedBackend(store=None, disk=None),
    _JaxBackend,
    lambda: _SimBackend(A10G_MISTRAL_7B),
], ids=["base", "paged", "sharded_paged", "jax", "sim"])
def test_backend_conforms(make):
    """Every implementation satisfies the Protocol (method presence)."""
    assert conforms(make())


def test_protocol_is_runtime_checkable_and_strict():
    """A lookalike missing one hop method must NOT conform — the protocol
    exists exactly to catch this drift (e.g. a misspelled free method)."""

    class Almost:
        def swap_out(self, node): return 0.0
        def load(self, node): return 0.0
        def spill(self, node): return 0.0
        def fetch(self, node): return 0.0
        def free_gpu(self, node): pass
        def free_host(self, node): pass
        # free_disk missing
        def demote_copy(self, node, level): return 0.0
        def promote_copy(self, node, level): return 0.0
        def free_tier(self, node, level): pass

    assert not conforms(Almost())
    assert not isinstance(object(), Backend)


def test_base_backend_hops_return_seconds_and_move_payloads():
    """Live exercise of the contract's semantics on the accounting base:
    hops return float seconds, frees return None, and the tier-indexed
    dispatch reaches the same payload slots as the named hops."""
    b, n = CacheBackend(), _node()
    n.payload_gpu = "seg"
    assert isinstance(b.demote_copy(n, 0), float)    # swap_out
    assert n.payload_host == "seg"
    assert isinstance(b.demote_copy(n, 1), float)    # spill
    assert n.payload_disk == "seg"
    assert b.free_tier(n, 0) is None and n.payload_gpu is None
    assert isinstance(b.promote_copy(n, 2), float)   # fetch
    assert isinstance(b.promote_copy(n, 1), float)   # load
    assert n.payload_gpu == "seg"
    b.free_tier(n, 2)
    assert n.payload_disk is None


def test_sim_backend_hop_costs_are_analytic_transfer_times():
    """The simulator backend's seconds come from the hardware profile, so
    they must scale with payload bytes (and with_tp scales the link)."""
    prof = A10G_MISTRAL_7B
    b = _SimBackend(prof)
    small, big = _node(), _node()
    small.bytes_, big.bytes_ = 2**20, 2**24
    small.payload_gpu = big.payload_gpu = object()
    assert b.swap_out(big) > b.swap_out(small) > 0.0
    b2 = _SimBackend(prof.with_tp(2))
    assert b2.swap_out(big) < b.swap_out(big)   # tp-parallel shard copies
