"""Paper §8 features: TPOT metric + large-top-k cache truncation."""
from repro.core.controller import RAGController
from repro.core.knowledge_tree import KnowledgeTree
from repro.core.profiler import A10G_MISTRAL_7B, CostProfiler
from repro.retrieval.corpus import make_corpus, make_workload
from repro.retrieval.vectordb import IVFIndex
from repro.serving.simulator import RAGSimulator, SimConfig


def test_commit_max_docs_truncates():
    t = KnowledgeTree(10_000, 10_000,
                      profiler=CostProfiler.from_profile(A10G_MISTRAL_7B),
                      bytes_per_token=1)
    c = RAGController(t)
    plan = c.plan([1, 2, 3, 4, 5], [10] * 5, 8)
    c.commit(plan, max_docs=3)
    assert len(t.match_prefix([1, 2, 3, 4, 5])) == 3
    t.check_invariants()


def test_tpot_metric_populated():
    corpus = make_corpus(200, mean_doc_tokens=500, seed=0)
    idx = IVFIndex(corpus.doc_vectors, n_clusters=16, nprobe=4)
    wl = make_workload(corpus, n_requests=40, rate=1.0, output_len_mean=6,
                       seed=1)
    m = RAGSimulator(SimConfig(profile=A10G_MISTRAL_7B), corpus, idx,
                     wl).run()
    assert m.avg_tpot > 0
    assert m.avg_tpot < m.avg_ttft   # decode steps are far cheaper (paper §8)


def test_cache_top_k_keeps_invariants():
    corpus = make_corpus(300, mean_doc_tokens=500, seed=0)
    idx = IVFIndex(corpus.doc_vectors, n_clusters=16, nprobe=4)
    wl = make_workload(corpus, n_requests=60, rate=1.0, seed=2)
    sim = RAGSimulator(SimConfig(profile=A10G_MISTRAL_7B, top_k=5,
                                 cache_top_k=3), corpus, idx, wl)
    m = sim.run()
    sim.tree.check_invariants()
    # no tree path may be deeper than cache_top_k
    for n in sim.tree.nodes():
        assert len(n.path()) <= 3
    assert m.completed == 60
