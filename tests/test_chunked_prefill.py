"""Chunked + batched prefill: shared chunk splitter, scheduler packing /
continuation / abort protocol, paged append, runtime mid-prefill
cancellation with partial-KV free + clean recompute, and token identity
between chunked and unchunked engines."""
import dataclasses

import numpy as np
import pytest

from repro.kvcache.paged import PagedKVStore
from repro.serving.scheduler import (DECODE, PREFILL,
                                     ContinuousBatchScheduler,
                                     SchedulerConfig, prefill_piece_sizes)


# ---- shared chunk splitter -----------------------------------------------

def test_piece_sizes_disabled_is_one_piece():
    assert prefill_piece_sizes([100, 24, 8], 0) == [132]
    assert prefill_piece_sizes([], 0) == []
    assert prefill_piece_sizes([0, 0], 512) == []


def test_piece_sizes_never_span_segments():
    # 100-token doc + 24-token doc + 8-token question at chunk 32: every
    # segment splits independently — a piece never crosses a boundary, so
    # per-segment attention calls are shape-identical to unchunked prefill
    assert prefill_piece_sizes([100, 24, 8], 32) == [32, 32, 32, 4, 24, 8]
    assert prefill_piece_sizes([64], 32) == [32, 32]
    assert prefill_piece_sizes([1], 32) == [1]


def test_piece_sizes_total_preserved():
    for chunk in (1, 3, 7, 512):
        assert sum(prefill_piece_sizes([37, 12, 9], chunk)) == 58


# ---- scheduler chunk protocol --------------------------------------------

@dataclasses.dataclass
class Job:
    name: str
    compute: int
    cancelled: bool = False
    done: bool = False


def make_sched(**kw):
    cfg = SchedulerConfig(max_batch=4, **kw)
    return ContinuousBatchScheduler(
        cfg, viable=lambda j: not j.cancelled and not j.done)


def test_packing_respects_token_budget():
    s = make_sched(prefill_chunk=32, max_prefill_tokens=64)
    for i in range(4):
        s.submit(Job(f"j{i}", 100), cached_len=0, compute_len=100)
    act = s.next_action(n_running=0)
    assert act.kind == PREFILL
    assert len(act.chunks) == 2                       # 2 x 32 fills 64
    assert sum(c.tokens for c in act.chunks) <= 64
    assert all(c.first for c in act.chunks)
    assert len({id(c.item) for c in act.chunks}) == len(act.chunks)


def test_single_request_iterations_without_budget():
    s = make_sched(prefill_chunk=32, max_prefill_tokens=0)
    s.submit(Job("a", 100), 0, 100)
    s.submit(Job("b", 100), 0, 100)
    act = s.next_action(0)
    assert act.kind == PREFILL and len(act.chunks) == 1


def test_continuation_uses_engine_reported_pieces():
    s = make_sched(prefill_chunk=32, max_prefill_tokens=0)
    j = Job("a", 100)
    s.submit(j, 0, 100)
    act = s.next_action(0)
    assert act.chunks[0].first
    # engine ran the first piece and reports the authoritative remainder
    s.note_chunk_done(j, [32, 32, 4])
    act2 = s.next_action(0)
    assert act2.kind == PREFILL
    assert not act2.chunks[0].first
    assert act2.chunks[0].item is j and act2.chunks[0].tokens == 32
    # drain
    s.note_chunk_done(j, [4])
    assert s.next_action(0).chunks[0].tokens == 4
    s.note_chunk_done(j, [])
    assert s.pool_size() == 0


def test_unreported_partial_not_reissued():
    s = make_sched(prefill_chunk=32, max_prefill_tokens=0)
    j = Job("a", 100)
    s.submit(j, 0, 100)
    assert s.next_action(0).kind == PREFILL
    # engine has not reported yet: the item must not be issued again
    assert s.next_action(1).kind == DECODE
    assert s.pool_size() == 1                          # still in flight


def test_abort_prefill_releases_partial():
    s = make_sched(prefill_chunk=32, max_prefill_tokens=0)
    j = Job("a", 100)
    s.submit(j, 0, 100)
    s.next_action(0)
    s.note_chunk_done(j, [32, 4])
    j.cancelled = True
    s.abort_prefill(j)
    assert s.pool_size() == 0
    assert s.next_action(1).kind == DECODE


def test_stale_partial_skipped_until_engine_aborts():
    s = make_sched(prefill_chunk=32, max_prefill_tokens=0)
    j = Job("a", 100)
    s.submit(j, 0, 100)
    s.next_action(0)
    s.note_chunk_done(j, [32, 4])
    j.cancelled = True
    # scheduler never issues chunks for a non-viable partial
    assert s.next_action(1).kind == DECODE


def test_budget_packs_continuations_and_new_jobs():
    s = make_sched(prefill_chunk=32, max_prefill_tokens=96)
    a, b = Job("a", 100), Job("b", 100)
    s.submit(a, 0, 100)
    s.submit(b, 0, 100)
    act = s.next_action(0)
    for c in act.chunks:
        s.note_chunk_done(c.item, [32, 4] if c.item is a else [32])
    act2 = s.next_action(0)
    items = [c.item for c in act2.chunks]
    assert a in items and b in items                   # both continue packed
    assert sum(c.tokens for c in act2.chunks) <= 96


def test_ragged_packing_ages_entries_once_per_round():
    """However many jobs one ragged batch pops, queue entries age exactly
    one skip per scheduling round (starvation windows keep their meaning)."""
    s = make_sched(prefill_chunk=32, max_prefill_tokens=64)
    stay = Job("stay", 100)
    s.submit(stay, cached_len=0, compute_len=100)
    s.submit(Job("a", 100), cached_len=50, compute_len=100)
    s.submit(Job("b", 100), cached_len=50, compute_len=100)
    act = s.next_action(n_running=0)
    assert len(act.chunks) == 2                       # a and b packed
    assert stay not in [c.item for c in act.chunks]
    (entry,) = s.queue._entries
    assert entry.item is stay and entry.skipped == 1


# ---- paged append --------------------------------------------------------

def test_paged_append_extends_segment():
    store = PagedKVStore(n_layers=1, n_blocks=8, block_size=4, n_kv=1,
                        head_dim=2)
    rng = np.random.default_rng(0)
    k1 = rng.normal(size=(1, 1, 6, 1, 2)).astype(np.float32)
    v1 = rng.normal(size=(1, 1, 6, 1, 2)).astype(np.float32)
    seg = store.put(k1, v1)
    assert seg.n_tokens == 6 and len(seg.blocks) == 2
    k2 = rng.normal(size=(1, 1, 5, 1, 2)).astype(np.float32)
    v2 = rng.normal(size=(1, 1, 5, 1, 2)).astype(np.float32)
    store.append(seg, k2, v2)                          # fills slot 6,7 + new
    assert seg.n_tokens == 11 and len(seg.blocks) == 3
    gk, gv = store.gather(seg)
    np.testing.assert_array_equal(np.asarray(gk)[0, 0],
                                  np.concatenate([k1, k2], axis=2)[0, 0])
    np.testing.assert_array_equal(np.asarray(gv)[0, 0],
                                  np.concatenate([v1, v2], axis=2)[0, 0])
    store.free(seg)
    store.pool.check()
    assert store.pool.free_blocks == 8


def test_paged_append_out_of_blocks_leaves_segment_intact():
    from repro.kvcache.paged import OutOfBlocks
    store = PagedKVStore(n_layers=1, n_blocks=2, block_size=4, n_kv=1,
                        head_dim=2)
    seg = store.put(np.zeros((1, 1, 8, 1, 2)), np.zeros((1, 1, 8, 1, 2)))
    with pytest.raises(OutOfBlocks):
        store.append(seg, np.ones((1, 1, 4, 1, 2)), np.ones((1, 1, 4, 1, 2)))
    assert seg.n_tokens == 8 and len(seg.blocks) == 2


# ---- runtime: real-model chunked execution -------------------------------

@pytest.fixture(scope="module")
def setup():
    import jax
    from repro.configs import get_reduced
    from repro.models import model as M
    from repro.retrieval.corpus import make_corpus, make_workload
    from repro.retrieval.vectordb import IVFIndex
    cfg = get_reduced("qwen2-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    corpus = make_corpus(12, mean_doc_tokens=16, vocab=cfg.vocab_size, seed=0)
    idx = IVFIndex(corpus.doc_vectors, n_clusters=4, nprobe=4)
    wl = make_workload(corpus, n_requests=4, rate=100.0, question_tokens=8,
                       vocab=cfg.vocab_size, zipf_s=1.2, seed=1)
    return cfg, params, corpus, idx, wl


def _runtime(setup, **kw):
    from repro.serving.config import EngineConfig
    from repro.serving.runtime import ContinuousRuntime
    cfg, params, corpus, idx, _ = setup
    return ContinuousRuntime(cfg, params, corpus, idx,
                             config=EngineConfig(top_k=2, **kw))


def test_chunked_batched_tokens_match_sequential(setup):
    """The headline guarantee survives chunking + ragged packing: greedy
    tokens are bit-identical to the (unchunked) sequential engine."""
    from repro.serving.engine import RAGServer
    cfg, params, corpus, idx, wl = setup
    from repro.serving.config import EngineConfig
    srv = RAGServer(cfg, params, corpus, idx, config=EngineConfig(top_k=2))
    seq = sorted(srv.serve(wl, max_new_tokens=3), key=lambda r: r.req_id)
    rt = _runtime(setup, prefill_chunk=6, max_prefill_tokens=18)
    res = rt.serve(wl, max_new_tokens=3)
    assert [r.tokens for r in res] == [r.tokens for r in seq]
    s = rt.metrics.summary()
    assert s["prefill_chunks"] > s["prefill_iterations"] > 0
    # ragged packing actually happened and never blew the token budget
    assert s["max_prefill_batch"] >= 2
    for n_chunks, n_tokens in rt.metrics.prefill_batches:
        if n_chunks > 1:
            assert n_tokens <= 18
    # no leaks: only the scratch block and tree payloads stay live
    rt.store.pool.check()
    rt.tree.check_invariants()
    tree_blocks = sum(len(n.payload_gpu.blocks) for n in rt.tree.nodes()
                      if n.in_gpu and n.payload_gpu is not None)
    live = rt.store.pool.n_blocks - rt.store.pool.free_blocks
    assert live == tree_blocks + 1


def test_mid_prefill_cancellation_frees_partial_kv(setup):
    """Cancel a chunked prefill between chunks: the paged partial KV must be
    freed, the remaining chunk tokens counted as saved, and a fresh prefill
    of the same request must recompute cleanly with identical tokens."""
    import heapq
    from repro.serving.runtime import _Job
    cfg, params, corpus, idx, wl = setup
    rt = _runtime(setup, prefill_chunk=4, speculative=False)
    # a completed reference serve of another request (also warms jit and
    # builds the decode fn)
    ref = rt.serve([wl[0]], max_new_tokens=2)[0]
    baseline_free = rt.store.pool.free_blocks

    # inject a request and drain ONLY arrival + retrieval-stage events: with
    # speculation off, the final stage launches the prefill, whose first
    # chunk runs synchronously — the completion event stops the drain
    rt._push(rt.now, "arrival", wl[1])
    req_state = None
    while rt._events and rt._events[0][2] in ("arrival", "stage"):
        rt.now, _, kind, payload = heapq.heappop(rt._events)
        getattr(rt, f"_on_{kind}")(payload)
        if kind == "arrival":
            req_state = rt._all[-1]
    assert req_state is not None
    # the engine is mid-prefill now: first chunk executed, more pending,
    # and the partial KV lives in the paged store
    assert rt._partial_jobs, "expected an in-flight chunked prefill"
    job = rt._partial_jobs[0]
    if rt.attn == "paged":
        # paged engine: the chunk's KV was scattered straight into the
        # request-owned page segments — no dense partial_seg exists
        assert job.cs.partial_seg is None
        assert sum(len(pg.blocks) for pg in job.cs.pg_segs) > 0
    else:
        assert job.cs.partial_seg is not None
        assert len(job.cs.partial_seg.blocks) > 0
    assert rt.store.pool.free_blocks < baseline_free
    saved_expect = sum(job.cs.pieces)
    assert saved_expect > 0
    # cancel between chunks (what a stale retrieval stage does)
    job.cancelled = True
    while rt._events:
        rt.now, _, kind, payload = heapq.heappop(rt._events)
        getattr(rt, f"_on_{kind}")(payload)
    # partial KV freed, savings accounted
    assert job.cs is None and not rt._partial_jobs
    assert rt.metrics.chunks_cancelled >= 1
    assert rt.metrics.chunk_tokens_saved >= saved_expect
    rt.store.pool.check()
    # recompute cleanly: resubmit the same docs as a fresh job
    redo = _Job(req=req_state, docs=req_state.final_docs,
                speculative=False, enqueued=rt.now)
    req_state.jobs.append(redo)
    cached, compute = rt._job_lens(redo)
    rt.sched.submit(redo, cached, compute)
    rt._engine_kick()
    while rt._events:
        rt.now, _, kind, payload = heapq.heappop(rt._events)
        getattr(rt, f"_on_{kind}")(payload)
    assert req_state.state == "finished"
    assert len(req_state.tokens) == 2
    # and the same request served standalone still matches the reference
    again = rt.serve([wl[0]], max_new_tokens=2)[0]
    assert again.tokens == ref.tokens


def test_runtime_chunk_equals_unchunked_tokens(setup):
    """Chunk size must not change tokens (chunk boundaries do not change
    attention semantics)."""
    cfg, params, corpus, idx, wl = setup
    rt_plain = _runtime(setup)
    base = rt_plain.serve(wl[:2], max_new_tokens=3)
    rt_chunk = _runtime(setup, prefill_chunk=5)
    chunked = rt_chunk.serve(wl[:2], max_new_tokens=3)
    assert [r.tokens for r in base] == [r.tokens for r in chunked]


@pytest.mark.slow
def test_property_any_chunk_size_identical_tokens(setup):
    """Hypothesis property: ANY chunk size yields tokens identical to
    unchunked prefill (per-segment splitting preserves attention exactly)."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st_
    from repro.serving.engine import RAGServer
    cfg, params, corpus, idx, wl = setup
    from repro.serving.config import EngineConfig
    ref_srv = RAGServer(cfg, params, corpus, idx,
                        config=EngineConfig(top_k=2))
    ref = sorted(ref_srv.serve(wl[:2], max_new_tokens=2),
                 key=lambda r: r.req_id)
    ref_tokens = [r.tokens for r in ref]

    @settings(max_examples=8, deadline=None)
    @given(chunk=st_.integers(min_value=1, max_value=40))
    def check(chunk):
        srv = RAGServer(cfg, params, corpus, idx,
                        config=EngineConfig(top_k=2, prefill_chunk=chunk))
        out = sorted(srv.serve(wl[:2], max_new_tokens=2),
                     key=lambda r: r.req_id)
        assert [r.tokens for r in out] == ref_tokens

    check()
