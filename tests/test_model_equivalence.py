"""Serving-path correctness: prefill+decode == full forward, and
prefix-cached prefill == full prefill (the core RAGCache guarantee that
caching never changes generation results)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced
from repro.models import model as M

ARCHS = ["qwen2-0.5b", "gemma2-27b", "gemma3-12b", "mixtral-8x7b",
         "hymba-1.5b", "xlstm-1.3b", "musicgen-large",
         "phi3.5-moe-42b-a6.6b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(1)
    params = M.init_params(cfg, key)
    B, S, P = 2, 12, 8
    shape = (B, cfg.n_codebooks, S) if cfg.n_codebooks else (B, S)
    toks = jax.random.randint(key, shape, 0, cfg.vocab_size)
    full = M.forward(cfg, params, {"tokens": toks})
    _, pc = M.prefill(cfg, params, {"tokens": toks[..., :P]})
    if cfg.family == "ssm":
        cache = pc
    else:
        cache = M.init_decode_cache(cfg, B, S)
        cache["k"] = cache["k"].at[:, :, :P].set(pc["k"])
        cache["v"] = cache["v"].at[:, :, :P].set(pc["v"])
        if cfg.family == "hybrid":
            cache["ssm"] = pc["ssm"]
    pos = jnp.full((B,), P, jnp.int32)
    for t in range(S - P):
        pos = pos + 1
        lg, cache = M.decode_step(cfg, params, toks[..., P + t: P + t + 1],
                                  cache, pos)
        err = float(jnp.abs(lg[:, 0] - full[:, P + t]).max())
        assert err < 5e-2, (arch, t, err)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefix_cached_prefill_exact(arch):
    """Paper §5.1: reusing cached document KV must reproduce the exact
    full-prefill logits (no approximation, unlike PromptCache/CacheGen)."""
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(2)
    params = M.init_params(cfg, key)
    B, P, Q = 1, 20, 6
    shape = (B, cfg.n_codebooks, P + Q) if cfg.n_codebooks else (B, P + Q)
    toks = jax.random.randint(key, shape, 0, cfg.vocab_size)
    lg_full, _ = M.prefill(cfg, params, {"tokens": toks})
    _, doc_cache = M.prefill(cfg, params, {"tokens": toks[..., :P]})
    lg_c, _ = M.prefill(cfg, params, {"tokens": toks[..., P:]},
                        prefix_cache=doc_cache, prefix_len=P)
    assert float(jnp.abs(lg_full - lg_c).max()) < 1e-3


def test_document_order_sensitivity():
    """Paper §5.1: KV of [D1,D3] differs from [D2,D3] for the same D3 —
    the reason the cache must be a *prefix tree*, not a flat doc->KV map."""
    cfg = get_reduced("qwen2-0.5b")
    key = jax.random.PRNGKey(3)
    params = M.init_params(cfg, key)
    d1 = jax.random.randint(jax.random.PRNGKey(10), (1, 8), 0, cfg.vocab_size)
    d2 = jax.random.randint(jax.random.PRNGKey(11), (1, 8), 0, cfg.vocab_size)
    d3 = jax.random.randint(jax.random.PRNGKey(12), (1, 8), 0, cfg.vocab_size)
    _, c13 = M.prefill(cfg, params,
                       {"tokens": jnp.concatenate([d1, d3], 1)})
    _, c23 = M.prefill(cfg, params,
                       {"tokens": jnp.concatenate([d2, d3], 1)})
    kv_d3_after_d1 = c13["k"][:, :, 8:]
    kv_d3_after_d2 = c23["k"][:, :, 8:]
    assert float(jnp.abs(kv_d3_after_d1 - kv_d3_after_d2).max()) > 1e-3
