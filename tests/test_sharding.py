"""Sharding + dry-run machinery on a small forced-device mesh.

Runs in a subprocess so the 8-device XLA flag never leaks into other tests
(the dry-run proper uses 512 devices via launch/dryrun.py).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    from repro.configs import get_reduced
    from repro.launch import specs as SP
    from repro.launch.specs import input_specs, shape_applicable
    from repro.launch.hlo_analysis import analyze

    SP.SHAPES = {
        "train_4k": dict(kind="train", seq=64, batch=8),
        "prefill_32k": dict(kind="prefill", seq=128, batch=8),
        "decode_32k": dict(kind="decode", seq=128, batch=8),
        "long_500k": dict(kind="decode", seq=256, batch=1),
    }
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    out = {}
    for arch in ["qwen2-0.5b", "mixtral-8x7b", "xlstm-1.3b", "hymba-1.5b"]:
        cfg = get_reduced(arch)
        for shape in SP.SHAPES:
            ok, _ = shape_applicable(cfg, shape)
            if not ok:
                continue
            with mesh:
                fn, args, donate, out_sh = input_specs(cfg, shape, mesh)
                c = jax.jit(fn, donate_argnums=donate,
                            out_shardings=out_sh).lower(*args).compile()
                t = analyze(c.as_text())
                out[f"{arch}/{shape}"] = dict(
                    flops=t.flops, coll=sum(t.coll.values()))
    print("RESULT" + json.dumps(out))
""")


@pytest.mark.slow
def test_dryrun_machinery_small_mesh():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT")][0]
    res = json.loads(line[len("RESULT"):])
    assert len(res) == 15  # 4 archs x 4 shapes - qwen2's long_500k skip
    for k, v in res.items():
        assert v["flops"] > 0, k


def test_param_spec_divisibility_fallback():
    """Rules must replicate when dims don't divide the axis."""
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_config
    from repro.launch.sharding import param_spec

    class FakeMesh:
        shape = {"model": 16, "data": 16}
        axis_names = ("data", "model")

    cfg = get_config("mixtral-8x7b")

    class Leaf:
        def __init__(self, shape):
            self.shape = shape
            self.ndim = len(shape)

    # experts=8 not divisible by model=16 -> falls back to d_ff sharding
    s = param_spec(("blocks", "wg"), Leaf((32, 8, 4096, 14336)), cfg,
                   FakeMesh())
    assert s == P(None, None, None, "model")
    # attention fused head dim divisible -> column parallel
    s = param_spec(("blocks", "wq"), Leaf((32, 4096, 4096)), cfg, FakeMesh())
    assert s == P(None, None, "model")
    # odd dim -> replicate
    s = param_spec(("blocks", "wq"), Leaf((32, 4096, 100)), cfg, FakeMesh())
    assert s == P(None, None, None)


def test_long_context_applicability():
    from repro.configs import get_config
    from repro.launch.specs import supports_long_context
    expected = {
        "xlstm-1.3b": True, "hymba-1.5b": True, "gemma3-12b": True,
        "gemma2-27b": True, "mixtral-8x7b": True,
        "yi-34b": False, "phi3.5-moe-42b-a6.6b": False,
        "internvl2-1b": False, "musicgen-large": False, "qwen2-0.5b": False,
    }
    for arch, want in expected.items():
        assert supports_long_context(get_config(arch)) == want, arch
