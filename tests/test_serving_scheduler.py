"""Continuous-batching scheduler: admission control, preemption trigger,
prefill/decode interleaving (shared policy for runtime + simulator)."""
import dataclasses


from repro.core.knowledge_tree import KnowledgeTree
from repro.core.profiler import A10G_MISTRAL_7B, CostProfiler
from repro.kvcache.paged import BlockPool, PagedKVStore
from repro.serving.scheduler import (DECODE, IDLE, PREEMPT, PREFILL,
                                     ContinuousBatchScheduler,
                                     PagedAdmission, SchedulerConfig,
                                     tree_pinned_gpu_bytes)


@dataclasses.dataclass
class Job:
    name: str
    cancelled: bool = False
    done: bool = False
    admissible: bool = True


def make_sched(max_batch=4, admit=True, **kw):
    cfg = SchedulerConfig(max_batch=max_batch, **kw)
    return ContinuousBatchScheduler(
        cfg,
        viable=lambda j: not j.cancelled and not j.done,
        admit=(lambda j: j.admissible) if admit else None,
    )


def test_prefill_preferred_while_batch_has_room():
    s = make_sched()
    s.submit(Job("a"), cached_len=10, compute_len=10)
    act = s.next_action(n_running=2)
    assert act.kind == PREFILL and act.item.name == "a"


def test_decode_when_batch_full_or_queue_empty():
    s = make_sched(max_batch=2)
    s.submit(Job("a"), 1, 1)
    assert s.next_action(n_running=2).kind == DECODE   # batch full
    assert s.next_action(n_running=1).kind == PREFILL
    assert s.next_action(n_running=1).kind == DECODE   # queue drained


def test_idle_when_nothing_to_do():
    s = make_sched()
    assert s.next_action(n_running=0).kind == IDLE


def test_cancelled_jobs_are_pruned():
    s = make_sched()
    j = Job("stale")
    s.submit(j, 5, 5)
    j.cancelled = True
    assert s.next_action(n_running=0).kind == IDLE
    assert len(s.queue) == 0


def test_admission_blocked_job_stays_queued():
    s = make_sched()
    j = Job("big", admissible=False)
    s.submit(j, 0, 100)
    assert s.next_action(n_running=0).kind == IDLE
    assert len(s.queue) == 1          # not dropped, waiting for resources
    j.admissible = True
    assert s.next_action(n_running=0).kind == PREFILL


def test_preemption_after_starvation_window():
    s = make_sched(preempt_after_skips=3)
    s.submit(Job("starved", admissible=False), 0, 100)
    # admission-blocked rounds age the entry; decode keeps running meanwhile
    kinds = [s.next_action(n_running=2).kind for _ in range(5)]
    assert DECODE in kinds
    assert PREEMPT in kinds
    # preemption is never proposed with an empty batch (nothing to evict)
    s2 = make_sched(preempt_after_skips=1)
    s2.submit(Job("starved", admissible=False), 0, 100)
    for _ in range(5):
        assert s2.next_action(n_running=0).kind == IDLE


def test_cache_aware_job_order():
    s = make_sched()
    s.submit(Job("cold"), cached_len=0, compute_len=100)
    s.submit(Job("hot"), cached_len=90, compute_len=10)
    assert s.next_action(0).item.name == "hot"
    assert s.next_action(0).item.name == "cold"


def test_pool_size_tracks_queue_and_running_prefills():
    s = make_sched()
    s.submit(Job("a"), 1, 1)
    assert s.pool_size() == 1
    s.note_prefill_start()
    assert s.pool_size() == 2
    s.note_prefill_end()
    assert s.pool_size() == 1


# ---- PagedAdmission ------------------------------------------------------

def _tree(gpu=1 << 20, bpt=1):
    return KnowledgeTree(gpu, 1 << 20,
                         profiler=CostProfiler.from_profile(A10G_MISTRAL_7B),
                         bytes_per_token=bpt)


def test_admission_block_budget():
    pool = BlockPool(n_blocks=10, block_size=16)
    adm = PagedAdmission(pool, _tree(), decode_reserve=8)
    # ctx 100 + reserve 8 -> 7 blocks <= 10 free
    assert adm.admissible(context_tokens=100, beta_tokens=10)
    # ctx 200 + 8 -> 13 blocks > 10
    assert not adm.admissible(context_tokens=200, beta_tokens=10)
    pool.alloc(6)
    adm.invalidate()                   # resource state changed
    assert not adm.admissible(context_tokens=100, beta_tokens=10)


def test_admission_counts_evictable_tree_blocks():
    store = PagedKVStore(n_layers=1, n_blocks=8, block_size=4, n_kv=1,
                         head_dim=2)
    import numpy as np
    tree = _tree(bpt=store.bytes_per_token())
    seg = store.put(np.zeros((1, 1, 16, 1, 2)), np.zeros((1, 1, 16, 1, 2)))
    node, _ = tree.insert(tree.root, 0, 16, payload=seg)
    assert store.pool.free_blocks == 4
    adm = PagedAdmission(store.pool, tree, decode_reserve=0)
    # 20 tokens -> 5 blocks: only 4 free, but 4 more evictable via the tree
    assert adm.admissible(context_tokens=20, beta_tokens=0)
    node.pinned = True                 # pinned nodes are not evictable
    adm.invalidate()
    assert not adm.admissible(context_tokens=20, beta_tokens=0)
    # blocks refcount-shared into a running table are NOT evictable-counted
    node.pinned = False
    store.share(seg)
    adm.invalidate()
    assert not adm.admissible(context_tokens=20, beta_tokens=0)


def test_admission_tree_pin_headroom():
    tree = _tree(gpu=100, bpt=1)
    node, _ = tree.insert(tree.root, 0, 60)
    node.pinned = True
    adm = PagedAdmission(BlockPool(100, 16), tree, decode_reserve=0)
    assert tree_pinned_gpu_bytes(tree) == 60
    assert adm.admissible(context_tokens=10, beta_tokens=40)
    assert not adm.admissible(context_tokens=10, beta_tokens=41)


def test_preemption_threshold_not_double_counted():
    """Blocked entries age exactly once per scheduling round, whether the
    round popped an admissible job or not."""
    s = make_sched(max_batch=8, preempt_after_skips=4)
    s.submit(Job("whale", admissible=False), 0, 100)
    rounds = 0
    # stream of small admissible jobs: every round pops one
    while True:
        s.submit(Job(f"small{rounds}"), 10, 1)
        act = s.next_action(n_running=2)
        rounds += 1
        if act.kind == PREEMPT:
            break
        assert act.kind == PREFILL
        assert rounds < 20
    assert rounds == 5                 # 4 aging rounds + the firing round
