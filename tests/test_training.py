"""Training substrate: loss decreases, checkpoint roundtrip, chunked loss."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import model as M
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, make_batches
from repro.training.optimizer import AdamWConfig, init_state, schedule
from repro.training.train_lib import loss_fn, make_train_step


def test_loss_decreases():
    cfg = get_reduced("qwen2-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=100)
    state = init_state(params)
    step = jax.jit(make_train_step(cfg, opt))
    data = make_batches(DataConfig(batch_size=8, seq_len=32,
                                   vocab_size=cfg.vocab_size), cfg)
    losses = []
    for _ in range(25):
        b = next(data)
        params, state, m = step(params, state,
                                {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, (losses[0], losses[-1])


def test_chunked_loss_matches_plain():
    cfg = get_reduced("qwen2-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    key = jax.random.PRNGKey(2)
    toks = jax.random.randint(key, (2, 37), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    l1 = loss_fn(cfg, params, batch, seq_chunk=8)
    logits = M.forward(cfg, params, batch)
    lse = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, batch["labels"][..., None], -1)[..., 0]
    l2 = (lse - gold).mean()
    assert abs(float(l1 - l2)) < 1e-3


def test_schedule_shape():
    opt = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(schedule(opt, jnp.asarray(0))) == pytest.approx(0.0)
    assert float(schedule(opt, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(schedule(opt, jnp.asarray(100))) == pytest.approx(0.1)


def test_checkpoint_roundtrip():
    cfg = get_reduced("mixtral-8x7b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(os.path.join(d, "c"), params, step=7)
        p2, s = ckpt.restore(os.path.join(d, "c"), params)
        assert s == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32))


def test_synthetic_data_learnable_structure():
    data = make_batches(DataConfig(batch_size=4, seq_len=16, vocab_size=64))
    b = next(data)
    assert b["tokens"].shape == (4, 16) and b["labels"].shape == (4, 16)
    # labels are the shifted stream: labels[t] == tokens[t+1]
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
