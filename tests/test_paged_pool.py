"""Paged KV block pool: unit + hypothesis property tests.  Only the
property test needs hypothesis — the unit and regression tests must run
without the optional dev deps."""
import numpy as np
import pytest

from repro.kvcache.paged import BlockPool, OutOfBlocks, PagedKVStore


def test_alloc_free_roundtrip():
    p = BlockPool(8, 16)
    a = p.alloc(3)
    assert p.free_blocks == 5
    p.decref(a)
    assert p.free_blocks == 8
    p.check()


def test_refcount_sharing():
    p = BlockPool(4, 16)
    a = p.alloc(2)
    p.incref(a)           # a second path shares these blocks
    p.decref(a)
    assert p.free_blocks == 2   # still held by the sharer
    p.decref(a)
    assert p.free_blocks == 4


def test_out_of_blocks():
    p = BlockPool(2, 16)
    p.alloc(2)
    with pytest.raises(OutOfBlocks):
        p.alloc(1)


def test_paged_store_roundtrip():
    store = PagedKVStore(n_layers=2, n_blocks=8, block_size=4, n_kv=2,
                         head_dim=8)
    rng = np.random.default_rng(0)
    k = rng.normal(size=(2, 1, 10, 2, 8)).astype(np.float32)
    v = rng.normal(size=(2, 1, 10, 2, 8)).astype(np.float32)
    seg = store.put(k, v)
    k2, v2 = store.gather(seg)
    np.testing.assert_allclose(np.asarray(k2), k)
    np.testing.assert_allclose(np.asarray(v2), v)
    store.free(seg)
    assert store.pool.free_blocks == 8


def test_unaligned_doc_is_shared_not_copied():
    """Regression (block-aligned tree insertion, ROADMAP): a cached doc
    whose token count is NOT a block multiple must still be refcount-shared
    into a request's decode slot mapping — the token-level (block, slot)
    mapping absorbs the unaligned tail, so only the question/new tokens are
    copied into private blocks."""
    jax = pytest.importorskip("jax")
    from repro.configs import get_reduced
    from repro.models import model as M
    from repro.retrieval.corpus import make_corpus, make_workload
    from repro.retrieval.vectordb import IVFIndex
    from repro.serving.runtime import ContinuousRuntime

    cfg = get_reduced("qwen2-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    corpus = make_corpus(6, mean_doc_tokens=20, vocab=cfg.vocab_size, seed=3)
    bs = 16
    for i in range(len(corpus.doc_lengths)):
        # force every doc to 20 tokens: NOT a multiple of the 16-token block
        corpus.doc_lengths[i] = 20
        corpus.doc_tokens[i] = np.resize(corpus.doc_tokens[i], 20)
    idx = IVFIndex(corpus.doc_vectors, n_clusters=3, nprobe=3)
    wl = make_workload(corpus, n_requests=4, rate=100.0, question_tokens=8,
                       vocab=cfg.vocab_size, zipf_s=1.5, seed=2)
    from repro.serving.config import EngineConfig
    rt = ContinuousRuntime(cfg, params, corpus, idx,
                           config=EngineConfig(top_k=1, block_size=bs))
    res = rt.serve(wl, max_new_tokens=2)
    assert len(res) == len(wl)
    # at least one request hit the tree and shared the unaligned doc
    assert any(r.alpha > 0 for r in res)
    assert rt.metrics.blocks_shared > 0, \
        "unaligned cached doc was copied instead of refcount-shared"
    rt.store.pool.check()
    rt.tree.check_invariants()


try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:                                  # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.one_of(
        st.tuples(st.just("alloc"), st.integers(1, 4)),
        st.tuples(st.just("free"), st.integers(0, 10)),
    ), min_size=1, max_size=40))
    def test_pool_never_double_allocates(ops):
        """Property: live segments never share blocks; accounting exact."""
        p = BlockPool(16, 4)
        live = []
        for op, arg in ops:
            if op == "alloc":
                try:
                    live.append(p.alloc(arg))
                except OutOfBlocks:
                    pass
            elif live:
                seg = live.pop(arg % len(live))
                p.decref(seg)
            all_live = [b for seg in live for b in seg]
            assert len(all_live) == len(set(all_live))
            p.check()
