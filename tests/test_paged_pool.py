"""Paged KV block pool: unit + hypothesis property tests."""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import numpy as np
from hypothesis import given, settings

from repro.kvcache.paged import BlockPool, OutOfBlocks, PagedKVStore


def test_alloc_free_roundtrip():
    p = BlockPool(8, 16)
    a = p.alloc(3)
    assert p.free_blocks == 5
    p.decref(a)
    assert p.free_blocks == 8
    p.check()


def test_refcount_sharing():
    p = BlockPool(4, 16)
    a = p.alloc(2)
    p.incref(a)           # a second path shares these blocks
    p.decref(a)
    assert p.free_blocks == 2   # still held by the sharer
    p.decref(a)
    assert p.free_blocks == 4


def test_out_of_blocks():
    p = BlockPool(2, 16)
    p.alloc(2)
    with pytest.raises(OutOfBlocks):
        p.alloc(1)


def test_paged_store_roundtrip():
    store = PagedKVStore(n_layers=2, n_blocks=8, block_size=4, n_kv=2,
                         head_dim=8)
    rng = np.random.default_rng(0)
    k = rng.normal(size=(2, 1, 10, 2, 8)).astype(np.float32)
    v = rng.normal(size=(2, 1, 10, 2, 8)).astype(np.float32)
    seg = store.put(k, v)
    k2, v2 = store.gather(seg)
    np.testing.assert_allclose(np.asarray(k2), k)
    np.testing.assert_allclose(np.asarray(v2), v)
    store.free(seg)
    assert store.pool.free_blocks == 8


@settings(max_examples=50, deadline=None)
@given(st.lists(st.one_of(
    st.tuples(st.just("alloc"), st.integers(1, 4)),
    st.tuples(st.just("free"), st.integers(0, 10)),
), min_size=1, max_size=40))
def test_pool_never_double_allocates(ops):
    """Property: live segments never share blocks; accounting always exact."""
    p = BlockPool(16, 4)
    live = []
    for op, arg in ops:
        if op == "alloc":
            try:
                live.append(p.alloc(arg))
            except OutOfBlocks:
                pass
        elif live:
            seg = live.pop(arg % len(live))
            p.decref(seg)
        all_live = [b for seg in live for b in seg]
        assert len(all_live) == len(set(all_live))
        p.check()
