"""Real-execution end-to-end serving: the full RAGCache pipeline with actual
model states on CPU (tiny model). Slowest tests — kept small."""
import jax
import pytest

from repro.configs import get_reduced
from repro.models import model as M
from repro.retrieval.corpus import make_corpus, make_workload
from repro.retrieval.vectordb import IVFIndex
from repro.serving.config import EngineConfig
from repro.serving.engine import RAGServer


@pytest.fixture(scope="module")
def served():
    cfg = get_reduced("qwen2-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    corpus = make_corpus(30, mean_doc_tokens=24, vocab=cfg.vocab_size, seed=0)
    idx = IVFIndex(corpus.doc_vectors, n_clusters=8, nprobe=4)
    return cfg, params, corpus, idx


def test_cache_hit_reproduces_tokens(served):
    """The RAGCache guarantee: a cache-hit answer equals the cold answer."""
    cfg, params, corpus, idx = served
    srv = RAGServer(cfg, params, corpus, idx,
                    config=EngineConfig(top_k=2, reorder=False))
    wl = make_workload(corpus, n_requests=1, rate=10,
                       question_tokens=8, vocab=cfg.vocab_size, seed=1)
    cold = srv.serve([wl[0]], max_new_tokens=4)[0]
    warm = srv.serve([wl[0]], max_new_tokens=4)[0]
    assert cold.alpha == 0 and warm.alpha > 0
    assert cold.tokens == warm.tokens
    assert warm.beta < cold.beta


def test_hit_rate_grows_under_skew(served):
    cfg, params, corpus, idx = served
    srv = RAGServer(cfg, params, corpus, idx, config=EngineConfig(top_k=1))
    wl = make_workload(corpus, n_requests=8, rate=10, zipf_s=1.4,
                       question_tokens=8, vocab=cfg.vocab_size, seed=2)
    srv.serve(wl, max_new_tokens=1)
    assert srv.controller.doc_hit_rate > 0.0
    srv.tree.check_invariants()


def test_ssm_state_caching_e2e():
    """xLSTM document caching: the node payload is the recurrent state."""
    cfg = get_reduced("xlstm-1.3b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    corpus = make_corpus(10, mean_doc_tokens=16, vocab=cfg.vocab_size, seed=0)
    idx = IVFIndex(corpus.doc_vectors, n_clusters=4, nprobe=4)
    srv = RAGServer(cfg, params, corpus, idx,
                    config=EngineConfig(top_k=1, reorder=False))
    wl = make_workload(corpus, n_requests=1, rate=10, question_tokens=8,
                       vocab=cfg.vocab_size, seed=3)
    cold = srv.serve([wl[0]], max_new_tokens=3)[0]
    warm = srv.serve([wl[0]], max_new_tokens=3)[0]
    assert warm.alpha > 0
    assert cold.tokens == warm.tokens
