"""Paged decode attention: parity of the three implementations (Pallas
kernel in interpret mode, per-page jnp online softmax, dense oracle) on the
store's layer-major layout, the slot-mapping edge cases the shape sweep in
test_kernels.py misses (length-0 rows, mid-slot shared tails, GQA R > 1,
sliding windows, logit softcap), the run-table packing contract, a
hypothesis permutation property against the dense ``decode_step`` attention,
and the e2e guarantee: ``attn="paged"`` reproduces the dense engine's greedy
tokens without ever materializing the dense (L, B, S, KV, hd) context.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models import layers as L
from repro.models import model as M

KEY = jax.random.PRNGKey(0)


def _runs_to_dense(kp, vp, tables, counts, layer):
    """Gather the logical sequences out of the page planes: (B, Smax, KV, hd)
    dense caches + (B,) lengths, padding rows to the longest request."""
    B, n_slots = tables.shape
    page = kp.shape[2]
    lengths = np.asarray(counts.sum(axis=1))
    smax = max(int(lengths.max()), 1)
    KV, hd = kp.shape[3], kp.shape[4]
    dk = np.zeros((B, smax, KV, hd), np.asarray(kp).dtype)
    dv = np.zeros_like(dk)
    for b in range(B):
        t = 0
        for j in range(n_slots):
            c = int(counts[b, j])
            dk[b, t:t + c] = np.asarray(kp)[layer, int(tables[b, j]), :c]
            dv[b, t:t + c] = np.asarray(vp)[layer, int(tables[b, j]), :c]
            t += c
    return jnp.asarray(dk), jnp.asarray(dv), jnp.asarray(lengths, jnp.int32)


def _random_case(key, B, H, KV, hd, page, n_pages, n_slots, dtype=jnp.float32):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    q = jax.random.normal(k1, (B, H, hd), dtype)
    kp = jax.random.normal(k2, (3, n_pages, page, KV, hd), dtype)
    vp = jax.random.normal(k3, (3, n_pages, page, KV, hd), dtype)
    tables = jax.random.randint(k4, (B, n_slots), 0, n_pages)
    counts = jax.random.randint(k5, (B, n_slots), 0, page + 1)
    starts = jnp.concatenate([jnp.zeros((B, 1), jnp.int32),
                              jnp.cumsum(counts, axis=1)[:, :-1]], axis=1)
    qpos = counts.sum(axis=1) - 1
    return q, kp, vp, tables, counts.astype(jnp.int32), starts, qpos


@pytest.mark.parametrize("B,H,KV,hd,page,n_slots", [
    (2, 4, 2, 32, 8, 4),       # GQA R=2
    (1, 8, 2, 64, 16, 3),      # GQA R=4
    (3, 4, 4, 128, 8, 6),      # MHA
    (2, 6, 1, 32, 8, 5),       # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.slow
def test_paged_decode_parity_sweep(B, H, KV, hd, page, n_slots, dtype):
    """Interpret-mode kernel and jnp path agree with the dense oracle on the
    layer-major layout, including runs that end mid-slot (counts < page)."""
    q, kp, vp, tables, counts, starts, qpos = _random_case(
        jax.random.fold_in(KEY, B * H + hd), B, H, KV, hd, page, 16, n_slots,
        dtype)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    for layer in (0, 2):
        want = ref.reference_paged_decode(q, kp, vp, tables, counts, starts,
                                          qpos, layer)
        for impl in ("interpret", "jnp"):
            got = ops.paged_decode_attention(
                q, kp, vp, tables, counts, starts, qpos,
                jnp.int32(layer), jnp.int32(0), impl=impl)
            np.testing.assert_allclose(np.asarray(got, np.float32),
                                       np.asarray(want, np.float32),
                                       atol=tol, err_msg=f"{impl}/L{layer}")


@pytest.mark.slow
def test_matches_dense_decode_attention_with_midslot_tail():
    """A request whose last live token sits mid-slot in a shared unaligned
    tail block (counts < page on the FINAL run too) must agree with the
    model's dense ``decode_attention`` over the gathered sequence."""
    q, kp, vp, _, _, _, _ = _random_case(KEY, 2, 4, 2, 32, 8, 16, 4)
    tables = jnp.asarray([[3, 7, 1, 9], [5, 5, 0, 0]], jnp.int32)
    # row 0: two unaligned doc tails (5, 3) then a full page then a 2-token
    # tail; row 1: one page reused twice (refcount-shared) + empty runs
    counts = jnp.asarray([[5, 3, 8, 2], [8, 8, 0, 0]], jnp.int32)
    starts = jnp.concatenate([jnp.zeros((2, 1), jnp.int32),
                              jnp.cumsum(counts, axis=1)[:, :-1]], axis=1)
    qpos = counts.sum(axis=1) - 1
    layer = 1
    dk, dv, lengths = _runs_to_dense(kp, vp, tables, counts, layer)
    want = L.decode_attention(q[:, None], dk, dv, pos=lengths)[:, 0]
    for impl in ("interpret", "jnp"):
        got = ops.paged_decode_attention(q, kp, vp, tables, counts, starts,
                                         qpos, jnp.int32(layer), jnp.int32(0),
                                         impl=impl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4, err_msg=impl)


@pytest.mark.slow
def test_length_zero_rows_produce_zero_not_nan():
    """An all-masked row (padding decode slot before its first token) must
    return exactly 0, not NaN and not an average of garbage pages."""
    q, kp, vp, tables, _, _, _ = _random_case(KEY, 3, 4, 2, 32, 8, 16, 4)
    counts = jnp.asarray([[8, 4, 0, 0], [0, 0, 0, 0], [1, 0, 0, 0]],
                         jnp.int32)
    starts = jnp.concatenate([jnp.zeros((3, 1), jnp.int32),
                              jnp.cumsum(counts, axis=1)[:, :-1]], axis=1)
    qpos = counts.sum(axis=1) - 1
    for impl in ("interpret", "jnp"):
        out = np.asarray(ops.paged_decode_attention(
            q, kp, vp, tables, counts, starts, qpos,
            jnp.int32(0), jnp.int32(0), impl=impl))
        assert np.isfinite(out).all(), impl
        assert np.abs(out[1]).max() == 0.0, impl
        assert np.abs(out[0]).max() > 0.0, impl


@pytest.mark.parametrize("window", [3, 9])
@pytest.mark.slow
def test_sliding_window_and_softcap_parity(window):
    """Window masking works on absolute positions reconstructed from the run
    starts — a mid-slot tail shifts every later position, which is exactly
    what breaks if the kernel assumed page-aligned runs."""
    q, kp, vp, _, _, _, _ = _random_case(KEY, 2, 4, 2, 32, 8, 16, 4)
    tables = jnp.asarray([[3, 7, 1, 9], [5, 2, 0, 0]], jnp.int32)
    counts = jnp.asarray([[5, 3, 8, 2], [8, 5, 0, 0]], jnp.int32)
    starts = jnp.concatenate([jnp.zeros((2, 1), jnp.int32),
                              jnp.cumsum(counts, axis=1)[:, :-1]], axis=1)
    qpos = counts.sum(axis=1) - 1
    layer, cap = 2, 30.0
    dk, dv, lengths = _runs_to_dense(kp, vp, tables, counts, layer)
    want = L.decode_attention(q[:, None], dk, dv, pos=lengths,
                              window=window, logit_cap=cap)[:, 0]
    for impl in ("interpret", "jnp"):
        got = ops.paged_decode_attention(
            q, kp, vp, tables, counts, starts, qpos,
            jnp.int32(layer), jnp.int32(window), logit_cap=cap, impl=impl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4, err_msg=impl)


@pytest.mark.slow
def test_single_layer_wrapper_matches_legacy_reference():
    """ops.paged_attention (the contiguous single-layer view) still honors
    the legacy lengths semantics through the layer-major kernel."""
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    B, H, KV, hd, page, n_pages, n_slots = 2, 8, 2, 64, 16, 8, 3
    q = jax.random.normal(k1, (B, H, hd))
    kp = jax.random.normal(k2, (n_pages, page, KV, hd))
    vp = jax.random.normal(k3, (n_pages, page, KV, hd))
    bt = jax.random.randint(k4, (B, n_slots), 0, n_pages)
    lengths = jnp.asarray([1, 37], jnp.int32)
    out = ops.paged_attention(q, kp, vp, bt, lengths, interpret=True)
    want = ref.reference_paged_attention(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4)


_PERM_SHAPE = dict(B=1, H=4, KV=2, hd=16, page=8, n_pages=12, n_slots=3)


def _check_permutation_invariance(perm, length):
    """kernel == ref.reference_paged_attention == dense decode_step
    attention for one physical page placement of the logical sequence."""
    s = _PERM_SHAPE
    k1, k2 = jax.random.split(KEY)
    q = jax.random.normal(k1, (s["B"], s["H"], s["hd"]))
    kv = jax.random.normal(k2, (s["n_slots"] * s["page"], s["KV"], s["hd"]))
    order = list(perm)[:s["n_slots"]]
    kp = jnp.zeros((s["n_pages"], s["page"], s["KV"], s["hd"]))
    vp = jnp.zeros_like(kp)
    for i, pid in enumerate(order):
        kp = kp.at[pid].set(kv[i * s["page"]:(i + 1) * s["page"]])
        vp = vp.at[pid].set(kv[i * s["page"]:(i + 1) * s["page"]] * 0.5)
    bt = jnp.asarray([order], jnp.int32)
    lengths = jnp.asarray([length], jnp.int32)
    kern = ops.paged_attention(q, kp, vp, bt, lengths, interpret=True)
    oracle = ref.reference_paged_attention(q, kp, vp, bt, lengths)
    dense = L.decode_attention(
        q[:, None], kv[None, :length], kv[None, :length] * 0.5,
        pos=lengths)[:, 0]
    np.testing.assert_allclose(np.asarray(kern), np.asarray(oracle),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(dense),
                               atol=1e-4)


@pytest.mark.slow
def test_block_table_permutation_spot_checks():
    """Fixed placements incl. a mid-slot last token (length % page != 0) —
    runs even where hypothesis is unavailable."""
    _check_permutation_invariance(range(12), 20)
    _check_permutation_invariance([7, 3, 11, 0], 24)
    _check_permutation_invariance([5, 0, 9], 1)


@pytest.mark.slow
def test_hypothesis_block_table_permutation_property():
    """For ANY physical page placement of the same logical sequence:
    kernel == ref.reference_paged_attention == dense decode_step attention
    (the paged layout is a pure storage change)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    s = _PERM_SHAPE

    @settings(max_examples=20, deadline=None)
    @given(perm=st.permutations(range(s["n_pages"])),
           length=st.integers(1, s["n_slots"] * s["page"]))
    def check(perm, length):
        _check_permutation_invariance(perm, length)

    check()


# ---------------------------------------------------------------------------
# model + runtime integration
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def serving_setup():
    from repro.configs import get_reduced
    from repro.retrieval.corpus import make_corpus, make_workload
    from repro.retrieval.vectordb import IVFIndex
    cfg = get_reduced("qwen2-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    corpus = make_corpus(16, mean_doc_tokens=22, vocab=cfg.vocab_size, seed=0)
    idx = IVFIndex(corpus.doc_vectors, n_clusters=8, nprobe=4)
    wl = make_workload(corpus, n_requests=6, rate=100.0, question_tokens=8,
                       vocab=cfg.vocab_size, zipf_s=1.2, seed=1)
    return cfg, params, corpus, idx, wl


def test_paged_decode_step_matches_decode_step(serving_setup):
    """paged_decode_step == decode_step logits on an unaligned multi-run
    layout driven through the real model (rope, GQA, scan over layers)."""
    cfg, params, _, _, _ = serving_setup
    bs, n_blocks = 8, 24
    B = 2
    lens = [21, 13]                      # runs: [8,8,6] and [8,6] (mid-slot)
    rng = np.random.default_rng(0)
    smax = max(lens) + 1
    k = jax.random.normal(KEY, (cfg.n_layers, B, smax, cfg.n_kv_heads,
                                cfg.hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 1), k.shape)
    mask = (np.arange(smax)[None] < np.asarray(lens)[:, None])[None, :, :,
                                                               None, None]
    cache = {"k": k * mask, "v": v * mask}
    # scatter the dense caches into paged planes with unaligned runs
    kp = jnp.zeros((cfg.n_layers, n_blocks, bs, cfg.n_kv_heads, cfg.hd))
    vp = jnp.zeros_like(kp)
    free = list(rng.permutation(n_blocks - 1) + 1)   # block 0 = scratch
    T = 6
    tables = np.zeros((B, T), np.int32)
    counts = np.zeros((B, T), np.int32)
    starts = np.zeros((B, T), np.int32)
    wblk = np.zeros((B,), np.int32)
    wslot = np.zeros((B,), np.int32)
    # run lengths cover lens[b] + 1 tokens: the final run's last slot is the
    # reserved position the new token is appended into (counts include it,
    # per the paged_decode_step contract)
    run_lens = {0: [8, 8, 6], 1: [8, 6]}
    for b in range(B):
        t = 0
        for j, c in enumerate(run_lens[b]):
            blk = free.pop()
            take = min(c, lens[b] - t)             # last run: slot reserved
            kp = kp.at[:, blk, :take].set(cache["k"][:, b, t:t + take])
            vp = vp.at[:, blk, :take].set(cache["v"][:, b, t:t + take])
            tables[b, j] = blk
            starts[b, j] = t
            t += take
        counts[b, :len(run_lens[b])] = run_lens[b]
        last = lens[b]                             # the new token's position
        assert sum(run_lens[b]) == last + 1
        wblk[b] = tables[b, len(run_lens[b]) - 1]
        wslot[b] = last - starts[b, len(run_lens[b]) - 1]
    toks = jnp.asarray([[3], [7]], jnp.int32)
    pos = jnp.asarray([lens[b] + 1 for b in range(B)], jnp.int32)
    want_logits, want_cache = M.decode_step(cfg, params, toks, cache, pos)
    got_logits, kp2, vp2 = M.paged_decode_step(
        cfg, params, toks, kp, vp, jnp.asarray(tables), jnp.asarray(counts),
        jnp.asarray(starts), jnp.asarray(wblk), jnp.asarray(wslot), pos,
        attn_impl="jnp")
    # the reduced model runs bf16 activations: online softmax vs padded
    # dense softmax reassociate differently, so logits agree to bf16 ULP
    # (bit-identical GREEDY TOKENS are asserted e2e below and in
    # test_serve_main.py; exact f32 parity is asserted kernel-level above)
    np.testing.assert_allclose(np.asarray(got_logits), np.asarray(want_logits),
                               atol=3e-2)
    for b in range(B):
        assert int(jnp.argmax(got_logits[b, -1])) == int(
            jnp.argmax(want_logits[b, -1]))
    # the appended KV landed at the advertised (block, slot) — compared at
    # bf16 tolerance since layer>0 projections see ULP-shifted activations
    bidx = jnp.arange(B)
    new_k = want_cache["k"][:, bidx, pos - 1]
    np.testing.assert_allclose(np.asarray(kp2[:, wblk, wslot], np.float32),
                               np.asarray(new_k, np.float32), atol=2e-2)
    assert np.abs(np.asarray(vp2[:, wblk, wslot], np.float32)).max() > 0


def test_runtime_paged_tokens_match_dense_and_tables_pack_runs(serving_setup):
    """e2e: --attn paged reproduces the dense engine's greedy tokens, and
    the packed run tables obey the slot-mapping contract (runs start at
    slot 0; unaligned shared tails appear as counts < block_size)."""
    from repro.serving.runtime import ContinuousRuntime
    cfg, params, corpus, idx, wl = serving_setup
    seen = {"midslot_tail": 0, "rows": 0}
    from repro.serving.config import EngineConfig
    rt = ContinuousRuntime(cfg, params, corpus, idx,
                           config=EngineConfig(top_k=2, attn="paged"))
    orig = rt._paged_decode_args

    def spy(batch):
        args = orig(batch)
        counts = np.asarray(args[2])
        for i, st in enumerate(batch):
            seen["rows"] += 1
            row = counts[i][counts[i] > 0]
            # non-final runs shorter than a block = shared unaligned tails
            if len(row) > 1 and (row[:-1] < rt.store.block_size).any():
                seen["midslot_tail"] += 1
            assert row.sum() == st.length + 1
        return args

    rt._paged_decode_args = spy
    res_p = rt.serve(wl, max_new_tokens=4)
    rt_d = ContinuousRuntime(cfg, params, corpus, idx,
                             config=EngineConfig(top_k=2, attn="dense"))
    res_d = rt_d.serve(wl, max_new_tokens=4)
    assert [r.tokens for r in res_p] == [r.tokens for r in res_d]
    assert seen["rows"] > 0 and seen["midslot_tail"] > 0
    rt.tree.check_invariants()
    rt.store.pool.check()


def test_paged_step_never_materializes_dense_context(serving_setup):
    """Inspect the jaxpr of the paged decode step: no intermediate may reach
    the dense-gather footprint L*B*S*KV*hd the dense engine pays — the
    whole point of wiring the kernel is deleting that array from the
    steady-state loop.  (The pool planes themselves are threaded through
    unchanged and are allowed.)"""
    cfg, params, corpus, idx, wl = serving_setup
    from repro.serving.runtime import ContinuousRuntime
    from repro.serving.config import EngineConfig
    rt = ContinuousRuntime(cfg, params, corpus, idx, n_blocks=64,
                           config=EngineConfig(top_k=2, attn="paged"))
    rt.max_new_tokens = 4
    max_ctx = 2 * int(max(corpus.doc_lengths)) + 16
    n_slots = rt.store.pool.blocks_for_tokens(max_ctx) + 1
    S = n_slots * rt.store.block_size
    dense_elems = (cfg.n_layers * rt.sched.config.max_batch * S
                   * cfg.n_kv_heads * cfg.hd)
    pool_elems = int(np.prod(rt.store.k.shape))
    B, T = rt.sched.config.max_batch, n_slots + rt.top_k + 1
    jaxpr = jax.make_jaxpr(
        lambda p, toks, tb, ct, st_, pos, wb, ws, kp, vp:
        M.paged_decode_step(cfg, p, toks, kp, vp, tb, ct, st_, wb, ws, pos,
                            attn_impl="jnp"))(
        params, jnp.zeros((B, 1), jnp.int32),
        jnp.zeros((B, T), jnp.int32), jnp.zeros((B, T), jnp.int32),
        jnp.zeros((B, T), jnp.int32), jnp.ones((B,), jnp.int32),
        jnp.zeros((B,), jnp.int32), jnp.zeros((B,), jnp.int32),
        rt.store.k, rt.store.v)

    def max_interm(jpr):
        worst = 0
        for eqn in jpr.eqns:
            for val in eqn.params.values():
                for v in (val if isinstance(val, (list, tuple)) else [val]):
                    # duck-typed sub-jaxpr descent (jax.core.{Closed,}Jaxpr
                    # move between jax versions): ClosedJaxpr has .jaxpr,
                    # a raw Jaxpr has .eqns
                    if hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
                        worst = max(worst, max_interm(v.jaxpr))
                    elif hasattr(v, "eqns"):
                        worst = max(worst, max_interm(v))
            for var in eqn.outvars:
                sz = int(np.prod(var.aval.shape)) if var.aval.shape else 1
                if sz != pool_elems:      # threaded pool planes are fine
                    worst = max(worst, sz)
        return worst

    worst = max_interm(jaxpr.jaxpr)
    assert worst < dense_elems, (worst, dense_elems)
