"""Freeze the ``ruff format`` burn-down manifest (pyproject.toml).

The ``[tool.ruff.format].exclude`` list grandfathers pre-formatter files
out of the blocking CI format gate.  It is a RATCHET: entries may only be
REMOVED (after ``ruff format <file>``), never quietly added — but this
container ships no ruff binary (offline image, see the blocker note in
pyproject.toml), so the gate itself cannot police additions here.  This
test does: the manifest is snapshotted below, and any NEW entry fails
tier-1 loudly with instructions, turning a one-line append into an
explicit, reviewable two-file change.

To legitimately grow the snapshot (a new file written in the repo's
hand-aligned house style while no ruff binary is available to verify it
clean): add the path to BOTH pyproject.toml and ``FROZEN`` below in the
same commit, and extend the blocker note in pyproject.toml.  To shrink
it (the goal): ``ruff format <file>``, then delete the entry from both.
"""
import pathlib

import pytest

try:
    import tomllib                      # py311+
except ImportError:                     # py310 fast lane
    tomli = pytest.importorskip("tomli")
    tomllib = tomli

REPO = pathlib.Path(__file__).resolve().parent.parent

FROZEN = frozenset([
    "benchmarks/common.py",
    "benchmarks/fig13_overall.py",
    "benchmarks/fig_frontdoor.py",
    "benchmarks/perf_guard.py",
    "benchmarks/fig15_topk.py",
    "benchmarks/fig16_large_models.py",
    "benchmarks/fig17_policy.py",
    "benchmarks/fig18_reorder.py",
    "benchmarks/fig19_speculative.py",
    "benchmarks/fig2_prefill_scaling.py",
    "benchmarks/fig4_cache_hit.py",
    "benchmarks/fig5_retrieval_pattern.py",
    "benchmarks/fig_chunk_reuse.py",
    "benchmarks/fig_replica_routing.py",
    "benchmarks/fig_tp_scaling.py",
    "benchmarks/fig_tiered_cache.py",
    "benchmarks/kernel_bench.py",
    "benchmarks/run.py",
    "benchmarks/tab4_sched_time.py",
    "benchmarks/throughput_batching.py",
    "benchmarks/tpot_topk.py",
    "examples/policy_ablation.py",
    "examples/quickstart.py",
    "examples/rag_serving.py",
    "examples/train_tiny.py",
    "src/repro/configs/__init__.py",
    "src/repro/configs/gemma2_27b.py",
    "src/repro/configs/gemma3_12b.py",
    "src/repro/configs/hymba_1p5b.py",
    "src/repro/configs/internvl2_1b.py",
    "src/repro/configs/llama2_70b.py",
    "src/repro/configs/llama2_7b.py",
    "src/repro/configs/mistral_7b.py",
    "src/repro/configs/mixtral_8x7b.py",
    "src/repro/configs/musicgen_large.py",
    "src/repro/configs/phi35_moe_42b.py",
    "src/repro/configs/qwen2_0p5b.py",
    "src/repro/configs/xlstm_1p3b.py",
    "src/repro/configs/yi_34b.py",
    "src/repro/core/controller.py",
    "src/repro/core/fault_tolerance.py",
    "src/repro/core/iterative.py",
    "src/repro/core/knowledge_tree.py",
    "src/repro/core/profiler.py",
    "src/repro/core/reorder.py",
    "src/repro/core/speculative.py",
    "src/repro/kernels/ops.py",
    "src/repro/kernels/paged_attention.py",
    "src/repro/kernels/paged_prefill.py",
    "src/repro/kernels/prefix_attention.py",
    "src/repro/kernels/ref.py",
    "src/repro/kvcache/paged.py",
    "src/repro/launch/dryrun.py",
    "src/repro/launch/hlo_analysis.py",
    "src/repro/launch/mesh.py",
    "src/repro/launch/perf_probe.py",
    "src/repro/launch/serve.py",
    "src/repro/launch/sharding.py",
    "src/repro/launch/specs.py",
    "src/repro/launch/train.py",
    "src/repro/models/config.py",
    "src/repro/models/layers.py",
    "src/repro/models/model.py",
    "src/repro/retrieval/corpus.py",
    "src/repro/retrieval/traffic.py",
    "src/repro/retrieval/vectordb.py",
    "src/repro/serving/backend.py",
    "src/repro/serving/config.py",
    "src/repro/serving/engine.py",
    "src/repro/serving/frontdoor.py",
    "src/repro/serving/metrics.py",
    "src/repro/serving/router.py",
    "src/repro/serving/runtime.py",
    "src/repro/serving/scheduler.py",
    "src/repro/serving/simulator.py",
    "src/repro/training/checkpoint.py",
    "src/repro/training/data.py",
    "src/repro/training/optimizer.py",
    "src/repro/training/train_lib.py",
    "tests/test_arch_smoke.py",
    "tests/test_backend_protocol.py",
    "tests/test_chunk_reuse.py",
    "tests/test_chunked_prefill.py",
    "tests/test_engine_config.py",
    "tests/test_engine_e2e.py",
    "tests/test_fault_tolerance.py",
    "tests/test_format_ratchet.py",
    "tests/test_frontdoor.py",
    "tests/test_hlo_analysis.py",
    "tests/test_kernels.py",
    "tests/test_knowledge_tree.py",
    "tests/test_layers.py",
    "tests/test_model_equivalence.py",
    "tests/test_paged_decode.py",
    "tests/test_paged_pool.py",
    "tests/test_paged_prefill.py",
    "tests/test_perf_guard.py",
    "tests/test_reorder_properties.py",
    "tests/test_replica_router.py",
    "tests/test_retrieval.py",
    "tests/test_scheduling.py",
    "tests/test_serve_main.py",
    "tests/test_serving_metrics.py",
    "tests/test_serving_runtime.py",
    "tests/test_serving_scheduler.py",
    "tests/test_sharding.py",
    "tests/test_simulator.py",
    "tests/test_tiered_cache.py",
    "tests/test_tp_serving.py",
    "tests/test_tpot_topk.py",
    "tests/test_traffic.py",
    "tests/test_training.py",
])


def _manifest():
    with open(REPO / "pyproject.toml", "rb") as f:
        cfg = tomllib.load(f)
    return cfg["tool"]["ruff"]["format"]["exclude"]


def test_no_new_files_land_in_the_manifest():
    added = set(_manifest()) - FROZEN
    assert not added, (
        f"NEW file(s) added to the ruff-format burn-down manifest "
        f"([tool.ruff.format].exclude in pyproject.toml): {sorted(added)}.\n"
        f"The manifest is a ratchet — run `ruff format <file>` and keep the "
        f"file OUT of the exclude list. If that is genuinely impossible "
        f"(no ruff binary in the environment), freeze it explicitly: add "
        f"the path to FROZEN in tests/test_format_ratchet.py AND extend "
        f"the blocker note in pyproject.toml, in the same commit.")


def test_manifest_entries_exist():
    """Deleted/renamed files must leave the manifest — dead entries make
    the burn-down count lie."""
    stale = [p for p in _manifest() if not (REPO / p).is_file()]
    assert not stale, (f"manifest entries with no file on disk: {stale} — "
                      f"remove them from [tool.ruff.format].exclude")


def test_manifest_has_no_duplicates():
    m = _manifest()
    dupes = {p for p in m if m.count(p) > 1}
    assert not dupes, f"duplicate manifest entries: {sorted(dupes)}"


def test_manifest_only_shrinks_against_snapshot():
    """Entries removed from pyproject (reformatted files — the goal!) should
    also be pruned from FROZEN so the snapshot tracks reality."""
    gone = FROZEN - set(_manifest())
    assert not gone, (
        f"FROZEN lists entries no longer in pyproject.toml: {sorted(gone)} "
        f"— prune them from tests/test_format_ratchet.py (ratchet "
        f"progress, keep the snapshot honest)")
