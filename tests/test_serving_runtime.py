"""Continuous-batching runtime e2e (real execution, tiny model): batched
paged decode must reproduce the sequential engine's greedy tokens exactly,
decode iterations must actually batch >= 2 requests, retrieval must overlap
speculative prefill, and block accounting must balance under admission
pressure/preemption."""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import model as M
from repro.retrieval.corpus import make_corpus, make_workload
from repro.retrieval.vectordb import IVFIndex
from repro.serving.config import EngineConfig
from repro.serving.engine import RAGServer
from repro.serving.runtime import ContinuousRuntime


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("qwen2-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    corpus = make_corpus(20, mean_doc_tokens=24, vocab=cfg.vocab_size, seed=0)
    idx = IVFIndex(corpus.doc_vectors, n_clusters=8, nprobe=4)
    wl = make_workload(corpus, n_requests=8, rate=100.0, question_tokens=8,
                       vocab=cfg.vocab_size, zipf_s=1.2, seed=1)
    return cfg, params, corpus, idx, wl


@pytest.fixture(scope="module")
def continuous_run(setup):
    cfg, params, corpus, idx, wl = setup
    rt = ContinuousRuntime(cfg, params, corpus, idx,
                           config=EngineConfig(top_k=2))
    res = rt.serve(wl, max_new_tokens=4)
    return rt, res


def test_tokens_match_sequential_engine(setup, continuous_run):
    """The headline guarantee: continuous batching through the paged store
    is a pure scheduling change — greedy tokens are bit-identical."""
    cfg, params, corpus, idx, wl = setup
    _, res = continuous_run
    srv = RAGServer(cfg, params, corpus, idx, config=EngineConfig(top_k=2))
    seq = sorted(srv.serve(wl, max_new_tokens=4), key=lambda r: r.req_id)
    assert len(res) == len(seq) == len(wl)
    for a, b in zip(res, seq):
        assert a.req_id == b.req_id
        assert a.tokens == b.tokens, (a.req_id, a.tokens, b.tokens)


def test_decode_actually_batches(continuous_run):
    rt, res = continuous_run
    s = rt.metrics.summary()
    assert s["completed"] == len(res)
    assert s["max_decode_batch"] >= 2
    assert s["mean_decode_batch"] >= 2.0


def test_retrieval_overlaps_prefill(continuous_run):
    """Speculative hits must take search off the TTFT critical path: the
    non-overlapped search time is strictly below the raw search time."""
    rt, _ = continuous_run
    s = rt.metrics.summary()
    assert s["speculative_hits"] >= 1
    assert (s["non_overlapped_search"]["mean"]
            < s["search"]["mean"] - 1e-9)
    for tl in rt.metrics.completed():
        if tl.speculative_hit and tl.final_prefill_start < tl.search_end:
            assert tl.non_overlapped_search < tl.search_time


def test_block_accounting_balances(continuous_run):
    """After serving, the only live blocks are the scratch block and the
    knowledge tree's GPU-resident payload segments (no leaks from request
    tables, wasted speculation, or eviction)."""
    rt, _ = continuous_run
    rt.tree.check_invariants()
    tree_blocks = sum(len(n.payload_gpu.blocks) for n in rt.tree.nodes()
                      if n.in_gpu and n.payload_gpu is not None)
    live = rt.store.pool.n_blocks - rt.store.pool.free_blocks
    assert live == tree_blocks + 1      # +1 scratch
    rt.store.pool.check()


def test_paged_cache_hits_reduce_beta(setup):
    """Serving the same workload twice on one runtime: second pass hits the
    tree (alpha > 0) and still produces identical tokens."""
    cfg, params, corpus, idx, wl = setup
    rt = ContinuousRuntime(cfg, params, corpus, idx,
                           config=EngineConfig(top_k=2))
    one = rt.serve([wl[0]], max_new_tokens=4)
    two = rt.serve([wl[0]], max_new_tokens=4)
    assert one[0].alpha == 0 and two[0].alpha > 0
    assert two[0].beta < one[0].beta
    assert one[0].tokens == two[0].tokens


def test_admission_pressure_and_preemption_complete_all(setup):
    """A pool far too small for the offered load forces admission waits /
    preemptions but every request must still complete with correct-length
    outputs and balanced accounting."""
    cfg, params, corpus, idx, wl = setup
    rt = ContinuousRuntime(cfg, params, corpus, idx, n_blocks=40,
                           config=EngineConfig(top_k=2, block_size=8))
    res = rt.serve(wl, max_new_tokens=3)
    assert len(res) == len(wl)
    for r in res:
        assert len(r.tokens) == 3
    s = rt.metrics.summary()
    assert s["completed"] == len(wl)
    rt.store.pool.check()
    rt.tree.check_invariants()


def test_block_sharing_when_aligned(setup):
    """Doc lengths that are multiples of the block size let running block
    tables refcount-share the knowledge-tree blocks instead of copying."""
    cfg, params, corpus, idx, wl = setup
    corpus2 = make_corpus(10, mean_doc_tokens=16, vocab=cfg.vocab_size,
                          seed=3)
    # force exact block-multiple doc lengths
    for i, l in enumerate(corpus2.doc_lengths):
        corpus2.doc_lengths[i] = 16
        corpus2.doc_tokens[i] = corpus2.doc_tokens[i][:16]
        if len(corpus2.doc_tokens[i]) < 16:
            corpus2.doc_tokens[i] = np.resize(corpus2.doc_tokens[i], 16)
    idx2 = IVFIndex(corpus2.doc_vectors, n_clusters=4, nprobe=4)
    wl2 = make_workload(corpus2, n_requests=4, rate=100.0, question_tokens=8,
                        vocab=cfg.vocab_size, zipf_s=1.4, seed=2)
    rt = ContinuousRuntime(cfg, params, corpus2, idx2,
                           config=EngineConfig(top_k=1, block_size=16))
    rt.serve(wl2, max_new_tokens=3)
    assert rt.metrics.blocks_shared > 0
    rt.store.pool.check()


def test_unserviceable_pool_fails_loudly(setup):
    """A pool that cannot hold even one worst-case request must raise at
    serve() time instead of silently returning empty tokens."""
    cfg, params, corpus, idx, wl = setup
    rt = ContinuousRuntime(cfg, params, corpus, idx, n_blocks=4,
                           config=EngineConfig(top_k=2, block_size=8))
    with pytest.raises(ValueError, match="paged pool too small"):
        rt.serve(wl[:2], max_new_tokens=2)


def test_recurrent_families_rejected():
    cfg = get_reduced("xlstm-1.3b")
    with pytest.raises(ValueError):
        ContinuousRuntime(cfg, None, None, None)
