"""Paged ragged prefill: parity of the kernel implementations (Pallas in
interpret mode, per-page jnp online softmax) against the dense oracles
(``ref.reference_paged_prefill`` and ``ref.reference_prefix_attention``),
the ragged edge cases the shape sweep misses (length-0 chunks, mid-block
unaligned cached tails, GQA R in {1, 2, 4}, sliding windows, logit softcap),
two hypothesis properties — block-table permutation invariance and
any-chunk-split row identity (the foundation of the engine's token-identity
guarantee) — the ``prefix_attention`` fast-path pin, and the model/runtime
integration: ``paged_prefill_step`` reproduces dense ``prefill`` logits
bit-for-bit without ever materializing the dense (L, B, S, KV, hd) context.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels import paged_prefill as pp
from repro.kernels import prefix_attention as pa
from repro.models import model as M

KEY = jax.random.PRNGKey(7)

IMPLS = ("interpret", "jnp")


def _random_case(key, B, H, KV, hd, page, n_pages, n_slots,
                 dtype=jnp.float32, Sq=8):
    """Arbitrary run tables (counts in [0, page], positions contiguous in
    run order) with the query span covering the FINAL Sq positions of each
    request — the mid-prefill shape: everything before q_start is cached."""
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    q = jax.random.normal(k1, (B, H, Sq, hd), dtype)
    kp = jax.random.normal(k2, (3, n_pages, page, KV, hd), dtype)
    vp = jax.random.normal(k3, (3, n_pages, page, KV, hd), dtype)
    tables = jax.random.randint(k4, (B, n_slots), 0, n_pages)
    counts = jax.random.randint(k5, (B, n_slots), 0, page + 1)
    starts = jnp.concatenate([jnp.zeros((B, 1), jnp.int32),
                              jnp.cumsum(counts, axis=1)[:, :-1]], axis=1)
    total = counts.sum(axis=1)
    q_len = jnp.minimum(total, Sq).astype(jnp.int32)
    q_start = (total - q_len).astype(jnp.int32)
    return q, kp, vp, tables, counts.astype(jnp.int32), starts, q_start, q_len


def _scatter_sequence(key, T, KV, hd, page, n_pages, order=None, layer=1):
    """Place one logical (T, KV, hd) KV sequence into physical pages (run
    order = ``order``, full pages except the final tail) and return the pool
    planes + the (1, n_slots) run table addressing it.  Non-target layers
    and unused pages hold garbage — reading them is a bug."""
    k1, k2, k3 = jax.random.split(key, 3)
    kseq = jax.random.normal(k1, (T, KV, hd))
    vseq = jax.random.normal(k2, (T, KV, hd))
    nb = -(-T // page)
    if order is None:
        order = list(range(1, nb + 1))
    kp = jax.random.normal(k3, (3, n_pages, page, KV, hd))
    vp = kp * -0.7 + 1.3
    counts = np.zeros(nb, np.int32)
    for i, pid in enumerate(order[:nb]):
        c = min(page, T - i * page)
        kp = kp.at[layer, pid, :c].set(kseq[i * page:i * page + c])
        vp = vp.at[layer, pid, :c].set(vseq[i * page:i * page + c])
        counts[i] = c
    tables = jnp.asarray([order[:nb]], jnp.int32)
    counts = jnp.asarray(counts[None])
    starts = jnp.concatenate([jnp.zeros((1, 1), jnp.int32),
                              jnp.cumsum(counts, axis=1)[:, :-1]], axis=1)
    return kseq, vseq, kp, vp, tables, counts, starts


# ---------------------------------------------------------------------------
# kernel-level parity (kernels CI lane)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,H,KV,hd,page,n_slots,Sq", [
    (2, 4, 2, 32, 8, 4, 8),       # GQA R=2
    (1, 8, 2, 64, 16, 3, 16),     # GQA R=4
    (3, 4, 4, 128, 8, 6, 8),      # MHA
    (2, 6, 1, 32, 8, 5, 24),      # MQA, multi-q-block at block_q=8
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.slow
def test_paged_prefill_parity_sweep(B, H, KV, hd, page, n_slots, Sq, dtype):
    """Interpret-mode kernel and jnp path agree with the dense oracle on the
    layer-major layout, including runs that end mid-slot (counts < page)."""
    q, kp, vp, tables, counts, starts, q_start, q_len = _random_case(
        jax.random.fold_in(KEY, B * H + hd + Sq), B, H, KV, hd, page, 16,
        n_slots, dtype, Sq=Sq)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    for layer in (0, 2):
        want = ref.reference_paged_prefill(q, kp, vp, tables, counts, starts,
                                           q_start, q_len, layer)
        for impl in IMPLS:
            got = ops.paged_prefill_attention(
                q, kp, vp, tables, counts, starts, q_start, q_len,
                jnp.int32(layer), jnp.int32(0), impl=impl)
            np.testing.assert_allclose(np.asarray(got, np.float32),
                                       np.asarray(want, np.float32),
                                       atol=tol, err_msg=f"{impl}/L{layer}")
        # multi-q-block grid (block_q < Sq) through the kernel directly
        got = pp.paged_prefill_attention(
            q, kp, vp, tables, counts, starts, q_start, q_len,
            jnp.int32(layer), jnp.int32(0), block_q=8, interpret=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=tol, err_msg=f"block_q=8/L{layer}")


@pytest.mark.parametrize("R", [1, 2, 4])
@pytest.mark.parametrize("window,cap", [(0, 0.0), (7, 0.0), (0, 30.0),
                                        (5, 30.0)])
@pytest.mark.slow
def test_matches_reference_prefix_attention(R, window, cap):
    """Against the ORIGINAL dense oracle: a contiguous [cached prefix ‖ new]
    sequence scattered into pages (unaligned tail included) must reproduce
    ``reference_prefix_attention`` for every GQA ratio, window, softcap."""
    H, hd, page = 4, 32, 8
    KV = H // R
    T, new = 29, 11                       # 29 % 8 != 0: mid-block tail
    layer = 1
    kseq, vseq, kp, vp, tables, counts, starts = _scatter_sequence(
        jax.random.fold_in(KEY, 13 * R + window), T, KV, hd, page, 12,
        layer=layer)
    q = jax.random.normal(jax.random.fold_in(KEY, R), (1, H, new, hd))
    want = ref.reference_prefix_attention(
        q, kseq.transpose(1, 0, 2)[None], vseq.transpose(1, 0, 2)[None],
        prefix_len=T - new, window=window, logit_cap=cap)
    q_start = jnp.asarray([T - new], jnp.int32)
    q_len = jnp.asarray([new], jnp.int32)
    for impl in IMPLS:
        got = ops.paged_prefill_attention(
            q, kp, vp, tables, counts, starts, q_start, q_len,
            jnp.int32(layer), jnp.int32(window), logit_cap=cap, impl=impl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4, err_msg=impl)


@pytest.mark.slow
def test_midblock_unaligned_cached_tails():
    """Cached doc tails ending mid-block (counts < page on non-final runs)
    shift every later absolute position — the exact case a page-aligned
    assumption breaks.  Gather the runs densely and compare."""
    page, KV, H, hd = 8, 2, 4, 32
    kp = jax.random.normal(jax.random.fold_in(KEY, 1), (3, 16, page, KV, hd))
    vp = jax.random.normal(jax.random.fold_in(KEY, 2), kp.shape)
    tables = jnp.asarray([[3, 7, 1, 9], [5, 5, 0, 0]], jnp.int32)
    counts = jnp.asarray([[5, 3, 8, 2], [8, 6, 0, 0]], jnp.int32)
    starts = jnp.concatenate([jnp.zeros((2, 1), jnp.int32),
                              jnp.cumsum(counts, axis=1)[:, :-1]], axis=1)
    layer, Sq = 1, 8
    total = counts.sum(axis=1)
    q_len = jnp.asarray([Sq, 6], jnp.int32)
    q_start = total - q_len
    q = jax.random.normal(jax.random.fold_in(KEY, 3), (2, H, Sq, hd))
    want = ref.reference_paged_prefill(q, kp, vp, tables, counts, starts,
                                       q_start, q_len, layer)
    # cross-check the oracle against the dense prefix reference per request
    for b in range(2):
        t = int(total[b])
        dk = np.zeros((t, KV, hd), np.float32)
        dv = np.zeros_like(dk)
        for j in range(tables.shape[1]):
            c, s0 = int(counts[b, j]), int(starts[b, j])
            dk[s0:s0 + c] = np.asarray(kp)[layer, int(tables[b, j]), :c]
            dv[s0:s0 + c] = np.asarray(vp)[layer, int(tables[b, j]), :c]
        n = int(q_len[b])
        dense = ref.reference_prefix_attention(
            q[b:b + 1, :, :n], jnp.asarray(dk.transpose(1, 0, 2))[None],
            jnp.asarray(dv.transpose(1, 0, 2))[None], prefix_len=t - n)
        np.testing.assert_allclose(np.asarray(want[b:b + 1, :, :n]),
                                   np.asarray(dense), atol=1e-4)
    for impl in IMPLS:
        got = ops.paged_prefill_attention(q, kp, vp, tables, counts, starts,
                                          q_start, q_len, jnp.int32(layer),
                                          jnp.int32(0), impl=impl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4, err_msg=impl)


@pytest.mark.slow
def test_length_zero_chunks_produce_zero_not_nan():
    """q_len == 0 rows (ragged-batch padding slots) must return exactly 0 —
    not NaN, not an average of whatever garbage the scratch page holds —
    and rows past q_len of a live request must be exactly 0 too."""
    q, kp, vp, tables, counts, starts, q_start, q_len = _random_case(
        KEY, 3, 4, 2, 32, 8, 16, 4, Sq=8)
    q_len = jnp.asarray([8, 0, 5], jnp.int32)
    q_start = jnp.maximum(counts.sum(axis=1) - q_len, 0)
    for impl in IMPLS:
        out = np.asarray(ops.paged_prefill_attention(
            q, kp, vp, tables, counts, starts, q_start, q_len,
            jnp.int32(0), jnp.int32(0), impl=impl), np.float32)
        assert np.isfinite(out).all(), impl
        assert np.abs(out[1]).max() == 0.0, impl           # whole dead row
        assert np.abs(out[2, :, 5:]).max() == 0.0, impl    # ragged tail
        assert np.abs(out[0]).max() > 0.0, impl
        assert np.abs(out[2, :, :5]).max() > 0.0, impl


# ---------------------------------------------------------------------------
# hypothesis properties
# ---------------------------------------------------------------------------

_PERM = dict(H=4, KV=2, hd=16, page=8, n_pages=12, T=22, new=9)


def _check_permutation_invariance(order):
    """Same logical sequence, ANY physical page placement: kernel == oracle
    == dense prefix reference (the paged layout is pure storage)."""
    s = _PERM
    kseq, vseq, kp, vp, tables, counts, starts = _scatter_sequence(
        KEY, s["T"], s["KV"], s["hd"], s["page"], s["n_pages"], order=order)
    q = jax.random.normal(jax.random.fold_in(KEY, 4),
                          (1, s["H"], s["new"], s["hd"]))
    q_start = jnp.asarray([s["T"] - s["new"]], jnp.int32)
    q_len = jnp.asarray([s["new"]], jnp.int32)
    dense = ref.reference_prefix_attention(
        q, kseq.transpose(1, 0, 2)[None], vseq.transpose(1, 0, 2)[None],
        prefix_len=s["T"] - s["new"])
    for impl in IMPLS:
        got = ops.paged_prefill_attention(
            q, kp, vp, tables, counts, starts, q_start, q_len,
            jnp.int32(1), jnp.int32(0), impl=impl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(dense),
                                   atol=1e-4, err_msg=impl)


@pytest.mark.slow
def test_block_table_permutation_spot_checks():
    _check_permutation_invariance(None)            # identity-ish placement
    _check_permutation_invariance([7, 3, 11])
    _check_permutation_invariance([11, 0, 5])


@pytest.mark.slow
def test_hypothesis_block_table_permutation_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=20, deadline=None)
    @given(perm=st.permutations(range(_PERM["n_pages"])))
    def check(perm):
        _check_permutation_invariance(list(perm))

    check()


def _check_chunk_split_identity(cuts, impl):
    """With the KV fully resident, computing the query span in ANY sequence
    of chunks yields the row outputs of the one-shot call: each row's
    online softmax walks the same slots in the same order whatever chunk it
    rides in.  Equality is to f32 ULP (XLA may retile the q·k matmul per Sq
    shape); BITWISE logits identity under chunking is asserted at the model
    level below, where bf16 activations absorb the ULP wobble — that is the
    kernel half of the engine's any-chunk-size token-identity guarantee."""
    s = _PERM
    _, _, kp, vp, tables, counts, starts = _scatter_sequence(
        KEY, s["T"], s["KV"], s["hd"], s["page"], s["n_pages"])
    new = s["new"]
    q = jax.random.normal(jax.random.fold_in(KEY, 5),
                          (1, s["H"], new, s["hd"]))
    q0 = s["T"] - new
    one = ops.paged_prefill_attention(
        q, kp, vp, tables, counts, starts, jnp.asarray([q0], jnp.int32),
        jnp.asarray([new], jnp.int32), jnp.int32(1), jnp.int32(0), impl=impl)
    bounds = [0] + sorted(cuts) + [new]
    pieces = []
    for a, b in zip(bounds, bounds[1:]):
        if a == b:
            continue
        pieces.append(ops.paged_prefill_attention(
            q[:, :, a:b], kp, vp, tables, counts, starts,
            jnp.asarray([q0 + a], jnp.int32), jnp.asarray([b - a], jnp.int32),
            jnp.int32(1), jnp.int32(0), impl=impl))
    got = jnp.concatenate(pieces, axis=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(one),
                               atol=2e-6, err_msg=impl)


@pytest.mark.slow
def test_chunk_split_identity_spot_checks():
    for impl in IMPLS:
        _check_chunk_split_identity([4], impl)
        _check_chunk_split_identity([1, 2, 3, 8], impl)
        _check_chunk_split_identity(list(range(1, _PERM["new"])), impl)


@pytest.mark.slow
def test_hypothesis_any_chunk_split_identity_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=15, deadline=None)
    @given(cuts=st.sets(st.integers(1, _PERM["new"] - 1), max_size=5))
    def check(cuts):
        _check_chunk_split_identity(sorted(cuts), "jnp")

    check()


# ---------------------------------------------------------------------------
# prefix_attention fast path (the dense A/B baseline)
# ---------------------------------------------------------------------------


class _FakeRef:
    def __init__(self, a):
        self.a = a

    def __getitem__(self, idx):
        return self.a

    def __setitem__(self, idx, val):
        self.a = val


@pytest.mark.slow
def test_prefix_fastpath_branches_bitwise_equivalent():
    """The ``pl.when`` fast path on fully-visible kv blocks skips the
    iota/compare/select; pin that the masked branch with an all-True mask
    performs the BITWISE-identical accumulator update (``jnp.where(True, s,
    NEG_INF)`` must return ``s`` unchanged), so the fast path can never
    change results — only skip work."""
    k1, k2 = jax.random.split(KEY)
    s = jax.random.normal(k1, (8, 8), jnp.float32) * 4.0
    v = jax.random.normal(k2, (8, 32), jnp.float32)
    mask = jnp.ones_like(s, bool)
    states = []
    for scores in (s, jnp.where(mask, s, pa.NEG_INF)):
        acc = _FakeRef(jnp.ones((8, 32), jnp.float32))
        m = _FakeRef(jnp.full((8,), -1.0, jnp.float32))
        el = _FakeRef(jnp.full((8,), 2.0, jnp.float32))
        pa._accumulate(scores, v, acc, m, el)
        states.append((acc.a, m.a, el.a))
    for got, want in zip(states[0], states[1]):
        assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("window", [0, 100])
@pytest.mark.slow
def test_prefix_flash_attention_fastpath_parity(window):
    """End to end through the rewritten kernel: a prefix-heavy shape where
    whole kv blocks take the fast path (prefix_len covers multiple full
    block_k tiles) still matches the dense oracle, and the deprecated
    ``prefix_attention`` wrapper forwards bit-for-bit."""
    B, H, KV, hd = 1, 4, 2, 32
    Sq, prefix = 24, 80
    k1, k2, k3 = jax.random.split(jax.random.fold_in(KEY, window), 3)
    q = jax.random.normal(k1, (B, H, Sq, hd))
    k = jax.random.normal(k2, (B, KV, prefix + Sq, hd))
    v = jax.random.normal(k3, k.shape)
    got = pa.prefix_flash_attention(q, k, v, prefix_len=prefix,
                                    window=window, block_q=8, block_k=16,
                                    interpret=True)
    want = ref.reference_prefix_attention(q, k, v, prefix_len=prefix,
                                          window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)
    legacy = pa.prefix_attention(q, k, v, prefix_len=prefix, window=window,
                                 block_q=8, block_k=16, interpret=True)
    assert np.array_equal(np.asarray(legacy), np.asarray(got))


# ---------------------------------------------------------------------------
# model + runtime integration
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def serving_setup():
    from repro.configs import get_reduced
    from repro.retrieval.corpus import make_corpus, make_workload
    from repro.retrieval.vectordb import IVFIndex
    cfg = get_reduced("qwen2-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    corpus = make_corpus(16, mean_doc_tokens=22, vocab=cfg.vocab_size, seed=0)
    idx = IVFIndex(corpus.doc_vectors, n_clusters=8, nprobe=4)
    wl = make_workload(corpus, n_requests=6, rate=100.0, question_tokens=8,
                       vocab=cfg.vocab_size, zipf_s=1.2, seed=1)
    return cfg, params, corpus, idx, wl


def _alloc_plan(cfg, n_tokens, bs, n_blocks, rng):
    """Random page placement for a fresh sequence: per-token write coords +
    the run table addressing them (one run per block, runs start at slot 0)."""
    nb = -(-n_tokens // bs)
    blocks = rng.permutation(n_blocks - 1)[:nb] + 1
    pos = np.arange(n_tokens)
    wblk = blocks[pos // bs].astype(np.int32)
    wslot = (pos % bs).astype(np.int32)
    T = nb + 2
    tables = np.zeros((1, T), np.int32)
    counts = np.zeros((1, T), np.int32)
    starts = np.zeros((1, T), np.int32)
    tables[0, :nb] = blocks
    counts[0, :nb] = [min(bs, n_tokens - i * bs) for i in range(nb)]
    starts[0, :nb] = np.arange(nb) * bs
    return wblk, wslot, tables, counts, starts


def test_paged_prefill_step_matches_dense_prefill(serving_setup):
    """paged_prefill_step == dense prefill logits BIT-FOR-BIT through the
    real model (rope, GQA, per-layer windows, scan), one-shot and split
    into chunks — the engine-level token-identity contract in miniature."""
    cfg, params, _, _, _ = serving_setup
    rng = np.random.default_rng(3)
    n_tokens, bs, n_blocks = 23, 8, 32
    toks = rng.integers(0, cfg.vocab_size, size=(1, n_tokens)).astype(np.int32)
    want_logits, _ = M.prefill(cfg, params, {"tokens": jnp.asarray(toks)})
    want = np.asarray(want_logits[:, -1:])

    wblk, wslot, tables, counts, starts = _alloc_plan(
        cfg, n_tokens, bs, n_blocks, rng)
    kp = jnp.zeros((cfg.n_layers, n_blocks, bs, cfg.n_kv_heads, cfg.hd),
                   cfg.jdtype)
    vp = jnp.zeros_like(kp)
    got, kp1, vp1 = M.paged_prefill_step(
        cfg, params, jnp.asarray(toks), kp, vp, jnp.asarray(tables),
        jnp.asarray(counts), jnp.asarray(starts),
        jnp.zeros((1,), jnp.int32), jnp.asarray([n_tokens], jnp.int32),
        jnp.asarray(wblk[None]), jnp.asarray(wslot[None]), attn_impl="jnp")
    assert np.array_equal(np.asarray(got), want)

    # chunked: same table, two calls threading the pool — still bitwise
    kp2, vp2 = jnp.zeros_like(kp), jnp.zeros_like(vp)
    cut = 9
    for a, b in ((0, cut), (cut, n_tokens)):
        got, kp2, vp2 = M.paged_prefill_step(
            cfg, params, jnp.asarray(toks[:, a:b]), kp2, vp2,
            jnp.asarray(tables), jnp.asarray(counts), jnp.asarray(starts),
            jnp.asarray([a], jnp.int32), jnp.asarray([b - a], jnp.int32),
            jnp.asarray(wblk[None, a:b]), jnp.asarray(wslot[None, a:b]),
            attn_impl="jnp")
    assert np.array_equal(np.asarray(got), want)
    # the scattered KV is identical too: chunking changes no pool byte
    assert np.array_equal(np.asarray(kp1), np.asarray(kp2))
    assert np.array_equal(np.asarray(vp1), np.asarray(vp2))


def test_paged_prefill_never_materializes_dense_context(serving_setup):
    """jaxpr regression: no intermediate of the paged prefill step may reach
    the dense-gather footprint L*B*S*KV*hd the retired concat path paid —
    the pool planes threaded through unchanged are the one exemption."""
    cfg, params, corpus, idx, wl = serving_setup
    from repro.serving.runtime import ContinuousRuntime
    from repro.serving.config import EngineConfig
    rt = ContinuousRuntime(cfg, params, corpus, idx, n_blocks=64,
                           config=EngineConfig(top_k=2, attn="paged"))
    rt.max_new_tokens = 4
    max_ctx = 2 * int(max(corpus.doc_lengths)) + 16
    n_slots = rt.store.pool.blocks_for_tokens(max_ctx) + 1
    S = n_slots * rt.store.block_size
    B, Sq = rt.sched.config.max_prefill_bs, 16
    dense_elems = cfg.n_layers * B * S * cfg.n_kv_heads * cfg.hd
    pool_elems = int(np.prod(rt.store.k.shape))
    T = n_slots + rt.top_k + 1
    jaxpr = jax.make_jaxpr(
        lambda p, toks, tb, ct, st_, qs, ql, wb, ws, kp, vp:
        M.paged_prefill_step(cfg, p, toks, kp, vp, tb, ct, st_, qs, ql,
                             wb, ws, attn_impl="jnp"))(
        params, jnp.zeros((B, Sq), jnp.int32),
        jnp.zeros((B, T), jnp.int32), jnp.zeros((B, T), jnp.int32),
        jnp.zeros((B, T), jnp.int32), jnp.zeros((B,), jnp.int32),
        jnp.ones((B,), jnp.int32), jnp.zeros((B, Sq), jnp.int32),
        jnp.zeros((B, Sq), jnp.int32), rt.store.k, rt.store.v)

    def max_interm(jpr):
        worst = 0
        for eqn in jpr.eqns:
            for val in eqn.params.values():
                for v in (val if isinstance(val, (list, tuple)) else [val]):
                    if hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
                        worst = max(worst, max_interm(v.jaxpr))
                    elif hasattr(v, "eqns"):
                        worst = max(worst, max_interm(v))
            for var in eqn.outvars:
                sz = int(np.prod(var.aval.shape)) if var.aval.shape else 1
                if sz != pool_elems:      # threaded pool planes are fine
                    worst = max(worst, sz)
        return worst

    worst = max_interm(jaxpr.jaxpr)
    assert worst < dense_elems, (worst, dense_elems)


def test_runtime_paged_prefill_tokens_match_dense(serving_setup):
    """e2e: the paged engine's chunked ragged prefill reproduces the dense
    engine's greedy tokens, batches real rows with ragged q_len, reuses hit
    pages in place (hit_runs populated on cache hits), and leaks nothing."""
    from repro.serving.runtime import ContinuousRuntime
    cfg, params, corpus, idx, wl = serving_setup
    seen = {"rows": 0, "ragged": 0, "hit_runs": 0}
    from repro.serving.config import EngineConfig
    rt = ContinuousRuntime(cfg, params, corpus, idx,
                           config=EngineConfig(top_k=2, attn="paged",
                                               prefill_chunk=6))
    orig = rt._run_paged_rows

    def spy(rows):
        seen["rows"] += len(rows)
        lens = {r[-1] for r in rows}
        if len(lens) > 1:
            seen["ragged"] += 1
        for r in rows:
            seen["hit_runs"] += len(r[0].cs.hit_runs)
        return orig(rows)

    rt._run_paged_rows = spy
    res_p = rt.serve(wl, max_new_tokens=4)
    rt_d = ContinuousRuntime(cfg, params, corpus, idx,
                             config=EngineConfig(top_k=2, attn="dense",
                                                 prefill_chunk=6))
    res_d = rt_d.serve(wl, max_new_tokens=4)
    assert [r.tokens for r in res_p] == [r.tokens for r in res_d]
    assert seen["rows"] > 0
    assert seen["hit_runs"] > 0, "expected cache hits to be read in place"
    rt.tree.check_invariants()
    rt.store.pool.check()
    # leak freedom: every pool block is owned by the tree (plus the scratch
    # block) once all requests retire
    tree_blocks = sum(len(n.payload_gpu.blocks) for n in rt.tree.nodes()
                      if n.in_gpu and n.payload_gpu is not None)
    live = rt.store.pool.n_blocks - rt.store.pool.free_blocks
    assert live == tree_blocks + 1
