"""ReorderQueue property tests: the ``bump_skipped`` / ``prune`` /
``remove`` bookkeeping paths had no direct coverage.  Properties checked
over arbitrary submit/pop/prune/bump interleavings:

  * the queue's pending set is exactly {pushed} - {popped} - {pruned};
  * a pruned item is never resurrected by any later operation;
  * pops never duplicate and never return pruned items;
  * ``max_skipped`` never exceeds the number of passing rounds, and the
    starvation window guarantees any entry is popped within ``window``
    pops of joining.
"""
import pytest

from repro.core.reorder import ReorderQueue

pytest.importorskip("hypothesis")

import hypothesis.strategies as st         # noqa: E402
from hypothesis import given, settings     # noqa: E402

# operation alphabet: push(cached, compute), pop, prune one live item (by
# rotating index), prune a predicate class, bump_skipped, refresh
ops_strategy = st.lists(st.one_of(
    st.tuples(st.just("push"), st.integers(0, 50), st.integers(1, 50)),
    st.tuples(st.just("pop"), st.just(0), st.just(0)),
    st.tuples(st.just("prune_one"), st.integers(0, 10), st.just(0)),
    st.tuples(st.just("prune_even"), st.just(0), st.just(0)),
    st.tuples(st.just("bump"), st.just(0), st.just(0)),
), min_size=1, max_size=60)


@settings(max_examples=150, deadline=None)
@given(ops=ops_strategy, window=st.integers(1, 8),
       enabled=st.booleans())
def test_interleavings_preserve_pending_set(ops, window, enabled):
    q = ReorderQueue(window=window, enabled=enabled)
    next_id = 0
    pending = set()                # what the queue must currently hold
    popped = []
    pruned = set()
    for op, a, b in ops:
        if op == "push":
            q.push(next_id, a, b)
            pending.add(next_id)
            next_id += 1
        elif op == "pop":
            item = q.pop()
            if pending:
                assert item in pending, "pop returned a non-pending item"
                pending.remove(item)
                popped.append(item)
            else:
                assert item is None
        elif op == "prune_one" and pending:
            victim = sorted(pending)[a % len(pending)]
            removed = q.prune(lambda it: it == victim)
            assert removed == 1
            pending.remove(victim)
            pruned.add(victim)
        elif op == "prune_even":
            evens = {it for it in pending if it % 2 == 0}
            removed = q.prune(lambda it: it % 2 == 0)
            assert removed == len(evens)
            pending -= evens
            pruned |= evens
        elif op == "bump":
            q.bump_skipped()
        # invariants after EVERY operation
        assert set(q.peek_all()) == pending
        assert len(q) == len(pending)
        assert not (set(q.peek_all()) & pruned), \
            "a pruned request was resurrected"
    # drain: everything still pending comes out exactly once, nothing else
    drained = []
    while True:
        item = q.pop()
        if item is None:
            break
        drained.append(item)
    assert sorted(drained) == sorted(pending)
    assert not (set(drained) & pruned)
    assert len(set(popped + drained)) == len(popped) + len(drained), \
        "an item was popped twice"


@settings(max_examples=100, deadline=None)
@given(n_hot=st.integers(1, 20), window=st.integers(1, 5))
def test_starvation_window_after_bumps(n_hot, window):
    """bump_skipped rounds count toward the starvation window exactly like
    pops: after ``window`` passed-over rounds a starved entry must win the
    next pop even against infinitely hot competitors."""
    q = ReorderQueue(window=window)
    q.push("starved", 0, 1000)
    for i in range(n_hot):
        q.push(f"hot{i}", 100, 1)
    for _ in range(window):
        q.bump_skipped(lambda it: it == "starved")
    assert q.pop() == "starved"


@settings(max_examples=100, deadline=None)
@given(ops=ops_strategy, window=st.integers(1, 8))
def test_max_skipped_tracks_rounds(ops, window):
    """max_skipped over live entries never exceeds the number of aging
    rounds (pops + bumps) since the oldest live entry joined."""
    q = ReorderQueue(window=window)
    rounds = 0
    next_id = 0
    for op, a, b in ops:
        if op == "push":
            q.push(next_id, a, b)
            next_id += 1
        elif op == "pop":
            if q.pop() is not None:
                rounds += 1
        elif op == "bump":
            q.bump_skipped()
            rounds += 1
        elif op == "prune_one" and len(q):
            live = q.peek_all()
            q.prune(lambda it: it == live[a % len(live)])
        elif op == "prune_even":
            q.prune(lambda it: it % 2 == 0)
        assert q.max_skipped() <= rounds
    assert q.max_skipped() <= rounds