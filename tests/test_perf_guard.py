"""CI perf-regression guard (benchmarks/perf_guard.py): tracked-row
filtering, the >2x ratio gate, smoke-size mismatch skip, and the tolerant
main() exit codes ci.yml relies on (missing baseline must PASS)."""
import json

from benchmarks.perf_guard import MAX_RATIO, MIN_BASELINE_US, compare, main


def _doc(rows, smoke=True):
    return {"smoke": smoke,
            "rows": [{"name": n, "us_per_call": us} for n, us in rows]}


def test_pass_when_within_ratio():
    base = _doc([("fig_frontdoor/on", 1000.0), ("fig_replica/x", 500.0)])
    cur = _doc([("fig_frontdoor/on", 1500.0), ("fig_replica/x", 900.0)])
    regressions, notes = compare(base, cur)
    assert not regressions
    assert any("1.50x" in n for n in notes)


def test_fail_on_regression_over_ratio():
    base = _doc([("fig_frontdoor/on", 1000.0)])
    cur = _doc([("fig_frontdoor/on", 1000.0 * MAX_RATIO * 1.1)])
    regressions, _ = compare(base, cur)
    assert len(regressions) == 1
    assert "fig_frontdoor/on" in regressions[0]
    # a speedup obviously passes
    assert not compare(cur, base)[0]


def test_untracked_error_and_total_rows_are_ignored():
    base = _doc([("kernel_bench/decode", 100.0),    # untracked prefix
                 ("fig_frontdoor/_total", 100.0),   # system row
                 ("fig_frontdoor/ERROR", 100.0),    # error row
                 ("fig13_overall", 200.0)])
    cur = _doc([("kernel_bench/decode", 9900.0),
                ("fig_frontdoor/_total", 9900.0),
                ("fig_frontdoor/ERROR", 9900.0),
                ("fig13_overall", 300.0)])
    regressions, _ = compare(base, cur)
    assert not regressions                 # only fig13_overall compared, ok


def test_tiny_baselines_are_not_gated():
    # near-zero denominators are fixed-overhead noise, never a regression
    base = _doc([("fig_frontdoor/on", MIN_BASELINE_US / 2)])
    cur = _doc([("fig_frontdoor/on", MIN_BASELINE_US * 50)])
    assert not compare(base, cur)[0]


def test_smoke_size_mismatch_skips_comparison():
    base = _doc([("fig_frontdoor/on", 100.0)], smoke=False)
    cur = _doc([("fig_frontdoor/on", 10000.0)], smoke=True)
    regressions, notes = compare(base, cur)
    assert not regressions
    assert any("smoke flag differs" in n for n in notes)


def test_new_and_removed_rows_are_notes_not_failures():
    base = _doc([("fig_frontdoor/old", 1000.0)])
    cur = _doc([("fig_frontdoor/new", 1000.0)])
    regressions, notes = compare(base, cur)
    assert not regressions
    assert any("new rows" in n for n in notes)
    assert any("no comparable rows" in n for n in notes)


def test_malformed_us_values_are_skipped():
    base = {"smoke": True, "rows": [
        {"name": "fig_frontdoor/on", "us_per_call": "not-a-number"},
        {"name": "fig_frontdoor/neg", "us_per_call": -5.0},
        {"name": "fig_frontdoor/ok", "us_per_call": 1000.0}]}
    cur = _doc([("fig_frontdoor/on", 1.0), ("fig_frontdoor/neg", 1.0),
                ("fig_frontdoor/ok", 1100.0)])
    regressions, _ = compare(base, cur)
    assert not regressions


# ---------------------------------------------------------------------------
# main(): the exit-code contract ci.yml depends on
# ---------------------------------------------------------------------------

def _write(tmp_path, name, doc):
    p = tmp_path / name
    p.write_text(json.dumps(doc))
    return str(p)


def test_main_missing_baseline_passes_with_warning(tmp_path, capsys,
                                                   monkeypatch):
    monkeypatch.delenv("GITHUB_STEP_SUMMARY", raising=False)
    cur = _write(tmp_path, "cur.json", _doc([("fig_frontdoor/on", 100.0)]))
    absent = str(tmp_path / "absent.json")
    assert main([absent, cur]) == 0
    out = capsys.readouterr().out
    assert "no usable baseline" in out
    # the pass is loud: a ::warning:: annotation names the missing baseline
    assert "::warning" in out and absent in out
    assert "SKIPPED" in out


def test_main_missing_baseline_writes_step_summary(tmp_path, monkeypatch):
    summary = tmp_path / "summary.md"
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
    cur = _write(tmp_path, "cur.json", _doc([("fig_frontdoor/on", 100.0)]))
    absent = str(tmp_path / "absent.json")
    assert main([absent, cur]) == 0
    text = summary.read_text()
    assert absent in text and "SKIPPED" in text
    # appends, never truncates (the summary file accumulates per step)
    assert main([absent, cur]) == 0
    assert summary.read_text().count("SKIPPED") == 2


def test_main_missing_baseline_broken_summary_sink_still_passes(
        tmp_path, monkeypatch):
    # an unwritable GITHUB_STEP_SUMMARY must not flip the verdict
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(tmp_path / "no" / "dir"))
    cur = _write(tmp_path, "cur.json", _doc([("fig_frontdoor/on", 100.0)]))
    assert main([str(tmp_path / "absent.json"), cur]) == 0


def test_main_corrupt_baseline_passes(tmp_path, capsys, monkeypatch):
    monkeypatch.delenv("GITHUB_STEP_SUMMARY", raising=False)
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    cur = _write(tmp_path, "cur.json", _doc([("fig_frontdoor/on", 100.0)]))
    assert main([str(bad), cur]) == 0
    assert "::warning" in capsys.readouterr().out


def test_main_present_baseline_emits_no_warning(tmp_path, capsys):
    base = _write(tmp_path, "base.json", _doc([("fig_frontdoor/on", 100.0)]))
    assert main([base, base]) == 0
    assert "::warning" not in capsys.readouterr().out


def test_main_regression_fails(tmp_path, capsys):
    base = _write(tmp_path, "base.json",
                  _doc([("fig_frontdoor/on", 1000.0)]))
    cur = _write(tmp_path, "cur.json",
                 _doc([("fig_frontdoor/on", 5000.0)]))
    assert main([base, cur]) == 1
    assert "FAIL" in capsys.readouterr().out
    # same files within ratio: exit 0
    assert main([base, base]) == 0


def test_main_usage_error():
    assert main(["only-one-arg"]) == 2
