"""Multi-replica doc-affinity routing: hypothesis properties over the
``ReplicaRouter`` policy object plus a real-runtime N=1 vs N=3 e2e.

Properties (the router's contract, see serving/router.py):
  * same doc-set => same replica, absent escape-hatch / admission rerouting;
  * the escape hatch bounds per-replica queue skew;
  * the router never admits a request past a replica's pin budget.

The e2e serves the identical trace through one continuous runtime and
through three runtimes behind the affinity router and asserts (a) greedy
tokens are bit-identical — routing never changes computation — and (b) no
tree or paged-store state is referenced across replicas.
"""
import dataclasses

import pytest

from repro.serving.config import FleetConfig
from repro.serving.router import (AFFINITY, LEAST_LOADED, ROUND_ROBIN,
                                  ReplicaRouter, partition_requests,
                                  stable_doc_hash)


def _fleet(n, **kw):
    return FleetConfig(replicas=n, **kw)


class _Bare:
    """Replica handle with no tree and no admission: routing runs purely on
    the router's shadow ledger + affinity hash."""


# ---------------------------------------------------------------------------
# deterministic unit tests (run even without hypothesis)
# ---------------------------------------------------------------------------

def test_stable_hash_is_process_independent():
    # FNV-1a reference values: placement must be reproducible across runs
    assert stable_doc_hash(()) == 0xcbf29ce484222325
    assert stable_doc_hash((1, 2)) == stable_doc_hash([1, 2])
    assert stable_doc_hash((1, 2)) != stable_doc_hash((2, 1))


def test_same_docs_stick_and_prefix_attracts():
    r = ReplicaRouter([_Bare(), _Bare(), _Bare()],
                      config=_fleet(3, routing=AFFINITY, max_queue_skew=100))
    first = r.route((1, 2), (10, 20))
    again = r.route((1, 2), (10, 20))
    assert again.index == first.index
    assert again.kind == "affinity"
    assert again.overlap_tokens == 30
    # a shared prefix is drawn to the same replica
    sib = r.route((1, 3), (10, 5))
    assert sib.index == first.index and sib.overlap_tokens == 10


def test_round_robin_cycles_and_least_loaded_balances():
    rr = ReplicaRouter([_Bare(), _Bare()],
                       config=_fleet(2, routing=ROUND_ROBIN))
    assert [rr.route((7,)).index for _ in range(4)] == [0, 1, 0, 1]
    ll = ReplicaRouter([_Bare(), _Bare()],
                       config=_fleet(2, routing=LEAST_LOADED))
    assert [ll.route((7,)).index for _ in range(4)] == [0, 1, 0, 1]


def test_cold_empty_docs_go_least_loaded():
    r = ReplicaRouter([_Bare(), _Bare()], config=_fleet(2, routing=AFFINITY))
    busy = r.route((9,), (4,)).index
    d = r.route((), ())
    assert d.kind == "cold"
    assert d.index == 1 - busy     # the idle replica


def test_note_complete_guards_double_completion():
    r = ReplicaRouter([_Bare()], config=_fleet(1, routing=AFFINITY))
    d = r.route((1,), (1,))
    r.note_complete(d.index)
    with pytest.raises(ValueError):
        r.note_complete(d.index)


def test_shadow_ledger_is_bounded():
    """The shadow ledger is a bounded LRU of routed paths: old paths age
    out (bounded memory for long-running routers), fresh paths keep their
    affinity."""
    r = ReplicaRouter([_Bare(), _Bare()],
                      config=_fleet(2, routing=AFFINITY, max_shadow_paths=8,
                                    max_queue_skew=10**9))
    for i in range(100):
        r.route((i, i + 1), (1, 1))

    def count(node):
        return sum(1 + count(c) for c in node.children.values())

    assert sum(count(s) for s in r._shadow) <= 8 * 2
    assert r.route((99, 100), (1, 1)).kind == "affinity"  # fresh: retained
    assert r.route((0, 1), (1, 1)).kind == "hash"         # aged out


def test_partition_window_drains_depth():
    r = ReplicaRouter([_Bare(), _Bare()],
                      config=_fleet(2, routing=AFFINITY, max_queue_skew=2))
    reqs = [(i % 5,) for i in range(40)]
    shares = partition_requests(r, reqs, docs_of=lambda d: d, window=4)
    assert sum(len(s) for s in shares) == len(reqs)
    assert r.depth == [0, 0]
    assert sum(r.routed) == len(reqs)
    assert r.max_skew_observed <= 2


# ---------------------------------------------------------------------------
# admission mock (also used by the non-hypothesis admission test)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _MockAdmission:
    """Stands in for serving.scheduler.PagedAdmission: a hard pin budget in
    tokens, consumed by dispatches and released by completions."""
    budget: int
    used: int = 0
    invalidated: int = 0

    def invalidate(self):
        self.invalidated += 1

    def admissible(self, context_tokens, beta_tokens, promote_tokens=0):
        return self.used + beta_tokens + promote_tokens <= self.budget


class _Admitted:
    def __init__(self, budget):
        self.admission = _MockAdmission(budget)


def test_admission_refusal_charges_nothing():
    replicas = [_Admitted(3), _Admitted(3)]
    router = ReplicaRouter(replicas, config=_fleet(2, routing=AFFINITY))
    ok = router.route((1,), (1,), context_tokens=2)
    assert ok.admitted
    replicas[ok.index].admission.used = 2
    # both replicas now refuse a 4-token job: nothing is charged
    no = router.route((2,), (1,), context_tokens=4)
    assert not no.admitted
    assert sum(router.depth) == 1 and sum(router.routed) == 1


def test_admission_derives_beta_from_replica_tree():
    """A replica that already caches the doc path is charged only the
    residual beta, so it can admit a request a cold replica must refuse."""
    class _Tree:
        def __init__(self, cached):
            self._cached = cached

        def match_prefix(self, docs):
            class _N:
                n_tokens = self._cached
                in_gpu = True
            return [_N()] if self._cached else []

    class _Replica:
        def __init__(self, budget, cached):
            self.admission = _MockAdmission(budget)
            self.tree = _Tree(cached)

    warm, cold = _Replica(10, cached=90), _Replica(10, cached=0)
    router = ReplicaRouter([cold, warm], config=_fleet(2, routing=AFFINITY))
    # ctx=100: cold needs beta=100 > 10 (refuse); warm needs 10 (admit)
    d = router.route((1,), (100,), context_tokens=100)
    assert d.admitted and d.replica is warm


# ---------------------------------------------------------------------------
# hypothesis properties (skipped, not errored, when hypothesis is absent —
# the unit tests and the e2e below must run regardless)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                              # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    doc_sets = st.lists(st.integers(0, 7), min_size=1, max_size=4).map(tuple)
    traces = st.lists(doc_sets, min_size=1, max_size=60)

    @settings(max_examples=100, deadline=None)
    @given(trace=traces, n=st.integers(1, 4))
    def test_same_docset_same_replica_absent_escapes(trace, n):
        """With the escape hatch effectively off, routing is a
        deterministic sticky assignment: every occurrence of a doc-set
        lands on the replica its first occurrence chose."""
        router = ReplicaRouter(
            [_Bare() for _ in range(n)],
            config=_fleet(n, routing=AFFINITY, max_queue_skew=10**9))
        where = {}
        for docs in trace:
            d = router.route(docs, tuple(1 for _ in docs))
            assert d.admitted
            assert d.kind in ("affinity", "hash")
            assert where.setdefault(docs, d.index) == d.index
        assert router.escaped == 0

    @settings(max_examples=100, deadline=None)
    @given(trace=traces, n=st.integers(2, 4), skew=st.integers(1, 3),
           completes=st.lists(st.booleans(), max_size=60))
    def test_escape_hatch_bounds_queue_skew(trace, n, skew, completes):
        """While requests only arrive, global max-min queue depth never
        exceeds the bound; interleaving completions, no single dispatch
        ever pushes its target more than the bound above the least-loaded
        replica."""
        router = ReplicaRouter(
            [_Bare() for _ in range(n)],
            config=_fleet(n, routing=AFFINITY, max_queue_skew=skew))
        in_flight = []
        drain = iter(completes)
        for docs in trace:
            d = router.route(docs, tuple(1 for _ in docs))
            in_flight.append(d.index)
            # routing-induced skew is bounded by construction...
            assert router.depth[d.index] - min(router.depth) <= skew
            if next(drain, False) and in_flight:
                router.note_complete(in_flight.pop(0))
        # ...and the router's own running record agrees
        assert router.max_skew_observed <= skew
        if not completes:
            # arrivals only: the bound is global, not just per-dispatch
            assert router.skew() <= skew

    @settings(max_examples=100, deadline=None)
    @given(trace=st.lists(st.tuples(doc_sets, st.integers(1, 6)),
                          min_size=1, max_size=40),
           n=st.integers(1, 3), budget=st.integers(2, 10),
           completes=st.lists(st.booleans(), max_size=40))
    def test_router_never_admits_past_pin_budget(trace, n, budget,
                                                 completes):
        """Every admitted dispatch fits the target replica's pin budget;
        when no replica can admit, the decision comes back admitted=False
        and charges nothing.  (Treeless replicas: beta == context.)"""
        replicas = [_Admitted(budget) for _ in range(n)]
        router = ReplicaRouter(
            replicas,
            config=_fleet(n, routing=AFFINITY, max_queue_skew=10**9))
        in_flight = []             # (replica index, beta) of admitted jobs
        drain = iter(completes)
        for docs, beta in trace:
            d = router.route(docs, tuple(1 for _ in docs),
                             context_tokens=beta)
            adm = replicas[d.index].admission
            if d.admitted:
                assert adm.used + beta <= adm.budget, \
                    "router admitted past the pin budget"
                adm.used += beta
                in_flight.append((d.index, beta))
            else:
                # refused: nothing charged anywhere, depths untouched
                assert sum(router.depth) == len(in_flight)
            for a in replicas:
                assert a.admission.used <= a.admission.budget
            if next(drain, False) and in_flight:
                i, b = in_flight.pop(0)
                replicas[i].admission.used -= b
                router.note_complete(i)
        assert all(a.admission.invalidated > 0 for a in replicas) \
            or not trace


# ---------------------------------------------------------------------------
# e2e: N=1 vs N=3 on the real runtime — token identity + replica isolation
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_setup():
    jax = pytest.importorskip("jax")
    from repro.configs import get_reduced
    from repro.models import model as M
    from repro.retrieval.corpus import make_corpus, make_workload
    from repro.retrieval.vectordb import IVFIndex
    cfg = get_reduced("qwen2-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    corpus = make_corpus(16, mean_doc_tokens=20, vocab=cfg.vocab_size,
                         seed=0)
    idx = IVFIndex(corpus.doc_vectors, n_clusters=6, nprobe=3)
    wl = make_workload(corpus, n_requests=7, rate=100.0, question_tokens=8,
                       vocab=cfg.vocab_size, zipf_s=1.3, seed=1)
    return cfg, params, corpus, idx, wl


def _serve_fleet(tiny_setup, n):
    from repro.serving.runtime import ContinuousRuntime
    cfg, params, corpus, idx, wl = tiny_setup
    from repro.serving.config import EngineConfig
    rts = [ContinuousRuntime(cfg, params, corpus, idx,
                             config=EngineConfig(top_k=2))
           for _ in range(n)]
    router = ReplicaRouter(rts, config=_fleet(n, routing=AFFINITY,
                                              max_queue_skew=4))
    shares = partition_requests(
        router, wl, docs_of=lambda r: idx.search(r.query_vec, 2),
        doc_tokens_of=lambda ds: [int(corpus.doc_lengths[d]) for d in ds],
        window=8)
    out = []
    for rt, share in zip(rts, shares):
        if share:
            out.extend(rt.serve(share, max_new_tokens=3))
    out.sort(key=lambda r: r.req_id)
    return rts, router, out


def test_n1_vs_n3_token_identity_and_isolation(tiny_setup):
    _, _, one = _serve_fleet(tiny_setup, 1)
    rts, router, three = _serve_fleet(tiny_setup, 3)
    assert len(one) == len(three) == len(tiny_setup[4])
    for a, b in zip(one, three):
        assert a.req_id == b.req_id
        assert a.tokens == b.tokens, (a.req_id, a.tokens, b.tokens)
    # every request actually served somewhere, none lost or duplicated
    assert sum(router.routed) == len(three)
    # replica isolation: trees never share nodes, and every GPU payload
    # lives in its own replica's paged store (no cross-replica references)
    node_owner = {}
    for i, rt in enumerate(rts):
        rt.tree.check_invariants()
        rt.store.pool.check()
        for node in rt.tree.nodes():
            assert node_owner.setdefault(id(node), i) == i
            if node.payload_gpu is not None:
                assert node.payload_gpu.store is rt.store, \
                    f"replica {i} tree references a foreign paged store"


def test_fleet_metrics_report_renders(tiny_setup):
    """Sanity on the fleet metrics plumbing: three replicas complete the
    trace, and the FleetMetrics report renders with routing stats."""
    from repro.serving.metrics import FleetMetrics
    rts, router, res = _serve_fleet(tiny_setup, 3)
    fleet = FleetMetrics(router.stats())
    for i, rt in enumerate(rts):
        fleet.add_replica(f"replica{i}", rt.metrics)
    s = fleet.summary()
    assert s["completed"] == len(res)
    assert s["replicas"] == 3
    report = fleet.format_report()
    assert "cross-replica TTFT" in report and "routed per replica" in report
