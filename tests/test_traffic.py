"""Multi-tenant traffic model (retrieval/traffic.py): determinism, tenant
mix + corpus slicing, repeat/near-duplicate structure (what the front-door
cache feeds on), MMPP burst/diurnal arrival modulation, and the helper
surface the drivers consume (tenant_slos / repeat_rate / split_by_tenant /
make_default_workload)."""
import numpy as np
import pytest

from repro.retrieval.corpus import make_corpus
from repro.retrieval.traffic import (TenantSpec, TrafficConfig,
                                     default_tenants, make_default_workload,
                                     make_tenant_workload, repeat_rate,
                                     split_by_tenant, tenant_slos)


@pytest.fixture(scope="module")
def corpus():
    return make_corpus(60, mean_doc_tokens=30, seed=0)


def _wl(corpus, tenants, **kw):
    kw.setdefault("n_requests", 120)
    kw.setdefault("base_rate", 50.0)
    kw.setdefault("seed", 7)
    return make_tenant_workload(corpus, tenants, TrafficConfig(**kw))


def test_trace_is_deterministic_per_seed(corpus):
    a = _wl(corpus, default_tenants(2))
    b = _wl(corpus, default_tenants(2))
    c = _wl(corpus, default_tenants(2), seed=8)
    assert len(a) == len(b) == 120
    for ra, rb in zip(a, b):
        assert ra.arrival == rb.arrival
        assert ra.tenant == rb.tenant and ra.query_id == rb.query_id
        assert np.array_equal(ra.question_tokens, rb.question_tokens)
        assert np.array_equal(ra.query_vec, rb.query_vec)
    assert any(x.arrival != y.arrival for x, y in zip(a, c))


def test_request_fields_and_arrival_order(corpus):
    wl = _wl(corpus, default_tenants(3))
    assert [r.req_id for r in wl] == list(range(len(wl)))
    assert all(r.tenant.startswith("tenant") for r in wl)
    assert all(r.query_id >= 0 for r in wl)
    assert all(r.top_k == 0 for r in wl)     # engine default until degraded
    arr = [r.arrival for r in wl]
    assert arr == sorted(arr) and arr[0] > 0.0


def test_tenant_mix_follows_weights_and_slices(corpus):
    tenants = default_tenants(3)
    wl = _wl(corpus, tenants, n_requests=300)
    by = split_by_tenant(wl)
    assert set(by) == {"tenant0", "tenant1", "tenant2"}
    # 1/rank weights: the head tenant dominates the tail
    assert len(by["tenant0"]) > len(by["tenant2"])
    assert sum(len(v) for v in by.values()) == len(wl)
    # disjoint corpus slices: every target doc stays in its tenant's range
    n_docs = 60
    for i, t in enumerate(tenants):
        lo, hi = int(t.doc_lo * n_docs), int(t.doc_hi * n_docs)
        for r in by[t.name]:
            assert lo <= r.target_doc < max(lo + 1, hi)


def test_small_pools_repeat_and_repeats_are_exact(corpus):
    tenants = default_tenants(2, n_queries=4)
    wl = _wl(corpus, tenants, n_requests=200)
    assert repeat_rate(wl) > 0.8             # tiny pools: almost all repeats
    # repeats of a (tenant, query_id) reuse the EXACT tokens and vector —
    # this is what makes the front door's exact hash hit
    first = {}
    for r in wl:
        key = (r.tenant, r.query_id)
        if key in first:
            assert np.array_equal(r.question_tokens,
                                  first[key].question_tokens)
            assert np.array_equal(r.query_vec, first[key].query_vec)
        else:
            first[key] = r
    # large pools repeat less
    big = _wl(corpus, default_tenants(2, n_queries=64), n_requests=200)
    assert repeat_rate(big) < repeat_rate(wl)


def test_near_duplicates_perturb_tokens_but_not_semantics(corpus):
    t = TenantSpec(name="t", n_queries=1, near_dup_prob=1.0)
    wl = _wl(corpus, [t], n_requests=40)
    base = wl[0]
    dups = [r for r in wl[1:]
            if not np.array_equal(r.question_tokens, base.question_tokens)]
    assert dups                              # tokens perturbed: hash misses
    for r in dups:
        a = base.query_vec / np.linalg.norm(base.query_vec)
        b = r.query_vec / np.linalg.norm(r.query_vec)
        assert float(a @ b) > 0.95           # ... but the vector stays close


def test_burst_multiplier_compresses_the_trace(corpus):
    calm = _wl(corpus, default_tenants(1), n_requests=400)
    bursty = _wl(corpus, default_tenants(1), n_requests=400,
                 burst_rate_mult=8.0)
    # MMPP bursts raise the instantaneous rate for burst spans only, so the
    # same request count arrives in strictly less wall-clock time
    assert bursty[-1].arrival < calm[-1].arrival
    # ... and the minimum gap shrinks (bursts pack arrivals together)
    gaps = lambda wl: np.diff([r.arrival for r in wl])
    assert np.median(gaps(bursty)) < np.median(gaps(calm))


def test_diurnal_modulation_changes_arrivals_not_content(corpus):
    flat = _wl(corpus, default_tenants(1), n_requests=100)
    wavy = _wl(corpus, default_tenants(1), n_requests=100,
               diurnal_amplitude=0.9, diurnal_period=1.0)
    assert [r.query_id for r in flat] == [r.query_id for r in wavy]
    assert any(a.arrival != b.arrival for a, b in zip(flat, wavy))


def test_drift_reshuffles_query_popularity(corpus):
    still = _wl(corpus, default_tenants(1, n_queries=8), n_requests=200,
                drift=0.0, n_phases=4)
    drifted = _wl(corpus, default_tenants(1, n_queries=8), n_requests=200,
                  drift=0.9, n_phases=4)
    assert [r.query_id for r in still] != [r.query_id for r in drifted]


def test_output_len_mean_draws_multi_token_answers(corpus):
    t = TenantSpec(name="t", output_len_mean=4)
    wl = _wl(corpus, [t], n_requests=60)
    lens = [r.output_len for r in wl]
    assert max(lens) > 1 and all(1 <= n <= 32 for n in lens)
    one = TenantSpec(name="t", output_len_mean=1)
    assert all(r.output_len == 1 for r in _wl(corpus, [one], n_requests=20))


def test_tenant_slos_and_empty_tenants_rejected(corpus):
    tenants = default_tenants(2, slo_ttft_ms=400.0)
    slos = tenant_slos(tenants)
    assert slos["tenant0"] == pytest.approx(0.4)
    assert slos["tenant1"] > slos["tenant0"]     # tail tenants get slack
    with pytest.raises(ValueError):
        make_tenant_workload(corpus, [], TrafficConfig(n_requests=1,
                                                       base_rate=1.0))


def test_make_default_workload_one_call_setup(corpus):
    tenants, wl = make_default_workload(corpus, n_tenants=2, n_requests=50,
                                        rate=25.0, n_queries=6, seed=3,
                                        output_len_mean=2)
    assert len(tenants) == 2 and len(wl) == 50
    assert {r.tenant for r in wl} <= {t.name for t in tenants}
    assert all(t.output_len_mean == 2 for t in tenants)
    assert repeat_rate(wl) > 0.0
