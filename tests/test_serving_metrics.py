"""Serving metrics: timeline arithmetic, percentile aggregation, overlap
accounting."""
import pytest

from repro.serving.metrics import (RequestTimeline, ServingMetrics,
                                   percentiles)


def test_percentiles_known_values():
    p = percentiles([float(i) for i in range(1, 101)])
    assert p["mean"] == pytest.approx(50.5)
    assert p["p50"] == pytest.approx(50.5)
    assert p["p90"] == pytest.approx(90.1)
    assert p["p99"] == pytest.approx(99.01)
    assert p["max"] == 100.0


def test_percentiles_empty():
    p = percentiles([])
    assert p == {"mean": 0.0, "p50": 0.0, "p90": 0.0, "p99": 0.0, "max": 0.0}


def _timeline(**kw):
    tl = RequestTimeline(req_id=0, arrival=10.0)
    for k, v in kw.items():
        setattr(tl, k, v)
    return tl


def test_ttft_tpot_queueing():
    tl = _timeline(search_start=10.0, search_end=10.5, queue_enter=10.5,
                   final_prefill_start=10.6, first_token=11.0,
                   token_times=[11.2, 11.4, 11.6])
    assert tl.ttft == pytest.approx(1.0)
    assert tl.tpot == pytest.approx(0.2)
    assert tl.queueing == pytest.approx(0.1)


def test_overlap_accounting():
    # speculative prefill started mid-search: only the pre-launch part of
    # the search is on the critical path
    tl = _timeline(search_start=0.0, search_end=1.0, final_prefill_start=0.3,
                   first_token=1.5)
    assert tl.search_time == pytest.approx(1.0)
    assert tl.non_overlapped_search == pytest.approx(0.3)
    # no prefill overlap (sequential behaviour): full search is serial
    tl2 = _timeline(search_start=0.0, search_end=1.0, first_token=2.0)
    assert tl2.non_overlapped_search == pytest.approx(1.0)
    # prefill started after search finished: zero overlap
    tl3 = _timeline(search_start=0.0, search_end=1.0,
                    final_prefill_start=2.0, first_token=3.0)
    assert tl3.non_overlapped_search == pytest.approx(1.0)


def test_summary_aggregates():
    m = ServingMetrics()
    for i, (ft, spec) in enumerate([(1.0, True), (2.0, False)]):
        tl = m.timeline(i, 0.0)
        tl.first_token = ft
        tl.speculative_hit = spec
        tl.hit_docs, tl.n_docs = 1, 2
        tl.token_times = [ft + 0.1]
    unserved = m.timeline(99, 0.0)        # never completed: excluded
    assert unserved.first_token < 0
    m.record_iteration("prefill", 1)
    m.record_iteration("decode", 2)
    m.record_iteration("decode", 4)
    s = m.summary()
    assert s["completed"] == 2
    assert s["ttft"]["mean"] == pytest.approx(1.5)
    assert s["mean_decode_batch"] == pytest.approx(3.0)
    assert s["max_decode_batch"] == 4
    assert s["prefill_iterations"] == 1
    assert s["speculative_hits"] == 1
    assert s["doc_hit_rate"] == pytest.approx(0.5)
    assert "TTFT" in m.format_report()
