"""Serving metrics: timeline arithmetic, percentile aggregation, overlap
accounting."""
import pytest

from repro.serving.metrics import (RequestTimeline, ServingMetrics,
                                   percentiles)


def test_percentiles_known_values():
    p = percentiles([float(i) for i in range(1, 101)])
    assert p["mean"] == pytest.approx(50.5)
    assert p["p50"] == pytest.approx(50.5)
    assert p["p90"] == pytest.approx(90.1)
    assert p["p99"] == pytest.approx(99.01)
    assert p["max"] == 100.0


def test_percentiles_empty():
    p = percentiles([])
    assert p == {"mean": 0.0, "p50": 0.0, "p90": 0.0, "p99": 0.0, "max": 0.0}


def _timeline(**kw):
    tl = RequestTimeline(req_id=0, arrival=10.0)
    for k, v in kw.items():
        setattr(tl, k, v)
    return tl


def test_ttft_tpot_queueing():
    tl = _timeline(search_start=10.0, search_end=10.5, queue_enter=10.5,
                   final_prefill_start=10.6, first_token=11.0,
                   token_times=[11.2, 11.4, 11.6])
    assert tl.ttft == pytest.approx(1.0)
    assert tl.tpot == pytest.approx(0.2)
    assert tl.queueing == pytest.approx(0.1)


def test_overlap_accounting():
    # speculative prefill started mid-search: only the pre-launch part of
    # the search is on the critical path
    tl = _timeline(search_start=0.0, search_end=1.0, final_prefill_start=0.3,
                   first_token=1.5)
    assert tl.search_time == pytest.approx(1.0)
    assert tl.non_overlapped_search == pytest.approx(0.3)
    # no prefill overlap (sequential behaviour): full search is serial
    tl2 = _timeline(search_start=0.0, search_end=1.0, first_token=2.0)
    assert tl2.non_overlapped_search == pytest.approx(1.0)
    # prefill started after search finished: zero overlap
    tl3 = _timeline(search_start=0.0, search_end=1.0,
                    final_prefill_start=2.0, first_token=3.0)
    assert tl3.non_overlapped_search == pytest.approx(1.0)


def test_summary_aggregates():
    m = ServingMetrics()
    for i, (ft, spec) in enumerate([(1.0, True), (2.0, False)]):
        tl = m.timeline(i, 0.0)
        tl.first_token = ft
        tl.speculative_hit = spec
        tl.hit_docs, tl.n_docs = 1, 2
        tl.token_times = [ft + 0.1]
    unserved = m.timeline(99, 0.0)        # never completed: excluded
    assert unserved.first_token < 0
    m.record_iteration("prefill", 1)
    m.record_iteration("decode", 2)
    m.record_iteration("decode", 4)
    s = m.summary()
    assert s["completed"] == 2
    assert s["ttft"]["mean"] == pytest.approx(1.5)
    assert s["mean_decode_batch"] == pytest.approx(3.0)
    assert s["max_decode_batch"] == 4
    assert s["prefill_iterations"] == 1
    assert s["speculative_hits"] == 1
    assert s["doc_hit_rate"] == pytest.approx(0.5)
    assert "TTFT" in m.format_report()


# ---------------------------------------------------------------------------
# degenerate inputs: zero completed requests, all-idle replicas (PR 6)
# ---------------------------------------------------------------------------

def test_zero_completed_requests_report():
    """A run where nothing completed (all shed, or an empty trace) must
    still summarize and render — the front-door driver prints a FleetMetrics
    report even when the cache absorbed every request."""
    m = ServingMetrics()
    s = m.summary()
    assert s["completed"] == 0
    assert all(s["ttft"][k] == 0.0 for k in ("mean", "p50", "p90", "p99"))
    assert s["doc_hit_rate"] == 0.0
    rep = m.format_report()
    assert "TTFT" in rep and "nan" not in rep
    # an opened-but-never-finished timeline stays excluded, not crashing
    m.timeline(0, 0.0)
    assert m.summary()["completed"] == 0
    assert "nan" not in m.format_report()


def test_fleet_metrics_all_idle_replica():
    from repro.serving.metrics import FleetMetrics
    fleet = FleetMetrics(router_stats={"policy": "affinity"})
    fleet.add_replica("replica0", ServingMetrics())   # never served anything
    busy = ServingMetrics()
    tl = busy.timeline(1, 0.0)
    tl.first_token = 0.5
    fleet.add_replica("replica1", busy)
    s = fleet.summary()
    assert s["replicas"] == 2 and s["completed"] == 1
    assert s["ttft"]["mean"] == pytest.approx(0.5)
    rep = fleet.format_report()
    assert "replica0" in rep and "replica1" in rep and "nan" not in rep
    # no front-door stats attached: no front-door block in the report
    assert "front door" not in rep


def test_fleet_metrics_renders_frontdoor_block():
    from repro.serving.frontdoor import TenantSLO, make_frontdoor
    from repro.serving.metrics import FleetMetrics
    import numpy as np
    from repro.retrieval.corpus import Request

    fd = make_frontdoor(capacity=8, ttl=1e9, sim_threshold=1.0,
                        slos={"acme": TenantSLO(ttft_target=0.5)},
                        init_service=1e-6, min_replicas=1, max_replicas=2,
                        autoscale=True, cooldown=0.0, scale_up_backlog=0.5,
                        scale_down_backlog=0.1)
    r = Request(req_id=0, arrival=0.0,
                query_vec=np.ones(4, np.float32),
                question_tokens=np.arange(4, dtype=np.int32),
                target_doc=0, output_len=1, tenant="acme")
    assert fd.handle(r, 0.0).kind == "miss"
    fd.note_complete(r, docs=(0,), answer=[3], ttft=0.1, now=0.1)
    assert fd.handle(r, 0.2).kind == "hit_exact"

    fleet = FleetMetrics(router_stats={}, frontdoor_stats=fd.stats())
    fleet.add_replica("replica0", ServingMetrics())
    rep = fleet.format_report()
    assert "front door" in rep and "hit rate 50.00%" in rep
    assert "SLO acme" in rep and "attained 2/2 = 100.00%" in rep
    assert "target 500ms" in rep
    assert "autoscale" in rep
    assert fleet.summary()["frontdoor"]["hit_rate"] == pytest.approx(0.5)
