"""CAG (cache-augmented generation) workload mode: corpus preload
accounting, the zero-retrieval-stage invariant, token bit-exactness vs the
sequential oracle, CLI round-trips, sim/runtime doc-resolution identity,
and the legacy-kwargs TypeError contract (docs/ARCHITECTURE.md §10, §12).
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.configs import get_reduced                       # noqa: E402
from repro.core.knowledge_tree import (EvictionError,       # noqa: E402
                                       KnowledgeTree)
from repro.core.profiler import A10G_MISTRAL_7B             # noqa: E402
from repro.kvcache.paged import DiskSegmentStore, PagedKVStore  # noqa: E402
from repro.models import model as M                         # noqa: E402
from repro.retrieval.corpus import (make_corpus,            # noqa: E402
                                    make_workload)
from repro.retrieval.vectordb import IVFIndex               # noqa: E402
from repro.launch import serve                              # noqa: E402
from repro.serving.config import (EngineConfig,             # noqa: E402
                                  FleetConfig)
from repro.serving.engine import RAGServer                  # noqa: E402
from repro.serving.frontdoor import FrontDoor               # noqa: E402
from repro.serving.router import ReplicaRouter              # noqa: E402
from repro.serving.runtime import ContinuousRuntime         # noqa: E402
from repro.serving.simulator import RAGSimulator, SimConfig  # noqa: E402

KV_SHAPE = dict(n_layers=2, n_blocks=32, block_size=4, n_kv=2, head_dim=8)
KV_BYTES = 2 * 2 * 2 * 8 * 4            # 2(k,v) * L * KV * hd * f32
BIG_DISK = 256 * 2**20                  # plenty for every tiny corpus here


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("qwen2-0.5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    corpus = make_corpus(10, mean_doc_tokens=12, vocab=cfg.vocab_size,
                         seed=0)
    idx = IVFIndex(corpus.doc_vectors, n_clusters=4, nprobe=4)
    wl = make_workload(corpus, n_requests=5, rate=100.0, question_tokens=8,
                       vocab=cfg.vocab_size, zipf_s=1.2, seed=1)
    return cfg, params, corpus, idx, wl


def _cag_config(**kw):
    kw.setdefault("disk_cache_bytes", BIG_DISK)
    return EngineConfig(mode="cag", top_k=2, **kw)


# ---------------------------------------------------------------------------
# preload accounting + bulk-insert contract
# ---------------------------------------------------------------------------

def test_preload_byte_and_file_accounting(setup, tmp_path):
    """Startup preload inserts EVERY doc into the disk tier exactly once,
    with byte/file accounting that matches the corpus and the mmap store."""
    cfg, params, corpus, idx, _ = setup
    srv = RAGServer(cfg, params, corpus, idx,
                    config=_cag_config(disk_cache_dir=str(tmp_path)))
    ps = srv.preload_stats
    n_docs = len(corpus.doc_lengths)
    assert ps["docs"] == ps["files"] == n_docs
    assert ps["tokens"] == int(corpus.doc_lengths.sum())
    assert ps["bytes"] == ps["tokens"] * srv.tree.bytes_per_token
    # the tree billed exactly the preload (one spill per doc, nothing else)
    assert srv.tree.stats["spill_bytes"] == ps["bytes"]
    assert srv.disk.n_files == n_docs
    # every corpus doc is a direct disk-resident child of the root
    for d in range(n_docs):
        node = srv.tree.root.children[d]
        assert node.in_disk and not node.in_host and not node.in_gpu
        assert node.spilled_once and not node.swapped_once
    srv.tree.check_invariants()
    # preloading again is a no-op (already resident)
    again = srv.controller.preload_corpus(range(n_docs),
                                          corpus.doc_lengths)
    assert again["docs"] == 0 and again["bytes"] == 0


def test_preload_disk_is_o1_and_overflows_loudly(tmp_path):
    """Corpus-scale pre-insertion never runs the per-node eviction scan:
    inserts go straight to the disk tier, and the first doc past the disk
    budget fails with a loud EvictionError instead of evicting."""
    from repro.serving.runtime import PagedBackend
    store = PagedKVStore(**KV_SHAPE)
    disk = DiskSegmentStore(str(tmp_path / "kv"), 100 * KV_BYTES)
    tree = KnowledgeTree(10 * KV_BYTES, 10 * KV_BYTES, 3 * 10 * KV_BYTES,
                         backend=PagedBackend(store, disk),
                         bytes_per_token=KV_BYTES)

    def payload(tokens, seed):
        rng = np.random.default_rng(seed)
        return {"k": rng.normal(size=(2, 1, tokens, 2, 8))
                .astype(np.float32),
                "v": rng.normal(size=(2, 1, tokens, 2, 8))
                .astype(np.float32)}

    for d in range(3):                       # exactly fills the disk tier
        node, _ = tree.preload_disk(d, 10, payload(10, d))
        assert node.in_disk and not node.in_host
    tree.check_invariants()
    assert tree.stats["gpu_evictions"] == 0
    assert tree.stats["host_evictions"] == 0
    assert tree.stats["disk_evictions"] == 0
    with pytest.raises(EvictionError, match="corpus preload overflows"):
        tree.preload_disk(3, 10, payload(10, 3))
    # a preloaded doc still promotes through the normal cascade
    node = tree.root.children[0]
    tree.ensure_in_gpu([node])
    assert node.in_gpu
    tree.check_invariants()


def test_preload_disk_requires_disk_tier():
    tree = KnowledgeTree(10 * KV_BYTES, 10 * KV_BYTES, 0,
                         bytes_per_token=KV_BYTES)
    with pytest.raises(ValueError, match="requires a disk tier"):
        tree.preload_disk(0, 10)


def test_cag_engines_require_disk_budget(setup):
    cfg, params, corpus, idx, _ = setup
    with pytest.raises(ValueError, match="disk_cache_bytes > 0"):
        RAGServer(cfg, params, corpus, idx,
                  config=EngineConfig(mode="cag"))
    with pytest.raises(ValueError, match="disk_cache_bytes > 0"):
        ContinuousRuntime(cfg, params, corpus, idx,
                          config=EngineConfig(mode="cag"))
    with pytest.raises(ValueError, match="disk_cache_bytes > 0"):
        SimConfig(profile=A10G_MISTRAL_7B, mode="cag")
    with pytest.raises(ValueError, match="mode must be"):
        EngineConfig(mode="kag")


# ---------------------------------------------------------------------------
# zero retrieval stages + token bit-exactness
# ---------------------------------------------------------------------------

def test_runtime_cag_zero_retrieval_stages(setup):
    """The scheduler invariant: in CAG mode no staged-search event ever
    fires — docs resolve synchronously at arrival, no speculative prefill
    is launched, and tokens match the sequential oracle bit for bit."""
    cfg, params, corpus, idx, wl = setup
    rt = ContinuousRuntime(cfg, params, corpus, idx, config=_cag_config())
    res = rt.serve(wl, max_new_tokens=3)
    s = rt.metrics.summary()
    assert s["retrieval_stages"] == 0
    assert s["speculative_prefills"] == 0
    # every request was a full-context tier hit (the whole corpus is
    # resident), so nothing was ever recomputed from scratch
    assert all(r.alpha > 0 for r in res)
    srv = RAGServer(cfg, params, corpus, idx, config=_cag_config())
    seq = sorted(srv.serve(wl, max_new_tokens=3), key=lambda r: r.req_id)
    for a, b in zip(res, seq):
        assert a.req_id == b.req_id and a.tokens == b.tokens
    # RAG mode on the same workload DOES run stages (the counter counts)
    rt_rag = ContinuousRuntime(cfg, params, corpus, idx,
                               config=EngineConfig(top_k=2))
    rt_rag.serve(wl, max_new_tokens=3)
    assert rt_rag.metrics.summary()["retrieval_stages"] > 0


def test_cag_matches_rag_tokens(setup):
    """Mode changes residency and scheduling, never computation: CAG greedy
    tokens equal RAG greedy tokens for the same workload."""
    cfg, params, corpus, idx, wl = setup
    cag = ContinuousRuntime(cfg, params, corpus, idx, config=_cag_config())
    res_cag = cag.serve(wl, max_new_tokens=3)
    rag = ContinuousRuntime(cfg, params, corpus, idx,
                            config=EngineConfig(top_k=2))
    res_rag = rag.serve(wl, max_new_tokens=3)
    assert [r.tokens for r in res_cag] == [r.tokens for r in res_rag]
    assert [r.docs for r in res_cag] == [r.docs for r in res_rag]


# ---------------------------------------------------------------------------
# CLI e2e (serve.main) at N=1 / N=3 / tp=2
# ---------------------------------------------------------------------------

TINY = ["--requests", "4", "--docs", "8", "--doc-tokens", "10",
        "--top-k", "2", "--max-new-tokens", "2", "--rate", "100"]


def _run_main(monkeypatch, capsys, extra):
    monkeypatch.setattr("sys.argv", ["serve.py"] + TINY + extra)
    serve.main()
    return capsys.readouterr().out


def test_main_cag_check_tokens(monkeypatch, capsys):
    """--mode cag --check-tokens at N=1: the disk tier auto-sizes to the
    corpus, the preload summary prints, and tokens stay bit-identical to
    the sequential engine fed the same pre-resolved docs."""
    out = _run_main(monkeypatch, capsys, ["--mode", "cag", "--check-tokens"])
    assert "[cag] --disk-cache-bytes 0 -> auto-sized" in out
    assert "[cag] preloaded 8 docs" in out
    assert "token check: all 4 requests identical" in out


def test_main_cag_check_tokens_three_replicas(monkeypatch, capsys):
    """--mode cag --replicas 3: each replica preloads the full corpus, the
    affinity router (homed by doc-set hash; overlap ties across replicas)
    partitions the trace, and the fleet still matches the oracle exactly."""
    out = _run_main(monkeypatch, capsys,
                    ["--mode", "cag", "--check-tokens", "--replicas", "3"])
    assert "continuous x3 (affinity)" in out
    assert "per replica x3" in out
    assert "token check: all 4 requests identical" in out


@pytest.mark.multidevice
@pytest.mark.skipif(
    jax.local_device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4 "
           "set before jax import (CI multidevice lane)")
def test_main_cag_check_tokens_tp2(monkeypatch, capsys):
    """--mode cag --tp 2: preload computes doc KV on the pre-shard params
    (single-device dense prefill), the sharded pool re-shards promoted
    copies, and greedy tokens still match the unsharded oracle."""
    out = _run_main(monkeypatch, capsys,
                    ["--mode", "cag", "--check-tokens", "--attn", "paged",
                     "--tp", "2"])
    assert "[cag] preloaded 8 docs" in out
    assert "token check: all 4 requests identical" in out


# ---------------------------------------------------------------------------
# simulator: shared-policy identity with the runtime
# ---------------------------------------------------------------------------

def test_sim_cag_zero_stages_and_docs_match_runtime(setup):
    """The analytic simulator shares the runtime's CAG policy exactly: zero
    retrieval stages, every doc preloaded, and per-request doc resolution
    identical to the real engine's (both are ONE synchronous index probe)."""
    cfg, params, corpus, idx, wl = setup
    corpus_bytes = (int(corpus.doc_lengths.sum())
                    * int(A10G_MISTRAL_7B.kv_bytes_per_token))
    sim = RAGSimulator(SimConfig(profile=A10G_MISTRAL_7B, top_k=2,
                                 mode="cag",
                                 disk_cache_bytes=corpus_bytes),
                       corpus, idx, wl)
    m = sim.run()
    assert m.retrieval_stages == 0
    assert sim.preload_stats["docs"] == len(corpus.doc_lengths)
    assert m.completed == len(wl)
    # every path's FIRST doc is disk-resident from the preload (deeper
    # path nodes only materialise once a path is served), so the prefix
    # hit rate is strictly positive from the very first request
    assert m.doc_hit_rate > 0
    rt = ContinuousRuntime(cfg, params, corpus, idx, config=_cag_config())
    res = rt.serve(wl, max_new_tokens=1)
    sim_docs = {st.r.req_id: st.final_docs for st in sim._all_states}
    for r in res:
        assert tuple(r.docs) == sim_docs[r.req_id]
    # a RAG-mode sim of the same trace runs a positive number of stages
    m_rag = RAGSimulator(SimConfig(profile=A10G_MISTRAL_7B, top_k=2),
                         corpus, idx, wl).run()
    assert m_rag.retrieval_stages > 0


# ---------------------------------------------------------------------------
# legacy-kwargs TypeError contract (api_redesign satellite)
# ---------------------------------------------------------------------------

def test_legacy_kwargs_raise_typeerror_naming_config_field(setup):
    """The pre-PR 7 loose-kwargs constructor paths are DELETED: a stray
    kwarg raises TypeError whose message names the EngineConfig/FleetConfig
    field that replaced it (the migration hint, not a bare rejection)."""
    cfg, params, corpus, idx, _ = setup
    with pytest.raises(TypeError, match=r"EngineConfig\(\.\.\., top_k="):
        RAGServer(cfg, params, corpus, idx, top_k=3)
    with pytest.raises(TypeError,
                       match=r"EngineConfig\(\.\.\., block_size="):
        ContinuousRuntime(cfg, params, corpus, idx, block_size=8)
    with pytest.raises(TypeError, match="no EngineConfig equivalent"):
        ContinuousRuntime(cfg, params, corpus, idx, bogus_knob=1)
    # renamed kwarg: the alias map points old 'policy' at the new field
    with pytest.raises(TypeError, match=r"FleetConfig\(\.\.\., routing="):
        ReplicaRouter([object()], policy="affinity")
    with pytest.raises(TypeError,
                       match=r"FleetConfig\(\.\.\., max_shadow_paths="):
        ReplicaRouter([object()], max_shadow_paths=8)
    with pytest.raises(TypeError, match="make_frontdoor"):
        FrontDoor(None, None, capacity=8)


def test_legacy_kwargs_rejected_before_any_engine_work():
    """The TypeError fires before the constructor touches models/devices —
    a migration error is cheap and instant even with junk positionals."""
    with pytest.raises(TypeError, match="sole API"):
        RAGServer(None, None, None, None, gpu_cache_bytes=0)
    with pytest.raises(TypeError, match="sole API"):
        ContinuousRuntime(None, None, None, None, speculative=False)
