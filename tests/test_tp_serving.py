"""Tensor-parallel serving parity.

The multidevice tests need >= 4 visible devices and therefore run in the CI
``multidevice`` lane, which exports
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` BEFORE any jax
import (jax locks the device count on first init — setting the flag inside
a test is too late, hence the skip guard instead of a fixture).

Parity claim under test: sharding params (Megatron col/row), the paged
pool's KV-head planes, and the decode kernels over a (1, tp) mesh never
changes greedy tokens OR per-tier hit attribution — mesh sizes 1, 2, 4 are
bit-identical to each other and to the single-device sequential engine.
"""
import pytest

jax = pytest.importorskip("jax")

from repro.configs import get_reduced  # noqa: E402
from repro.launch import serve  # noqa: E402
from repro.launch.sharding import (assert_tp_compatible,  # noqa: E402
                                   kv_heads_shardable)

multidevice = pytest.mark.multidevice
need4 = pytest.mark.skipif(
    jax.local_device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4 "
           "set before jax import (CI multidevice lane)")

TINY = ["--requests", "4", "--docs", "8", "--doc-tokens", "10",
        "--top-k", "2", "--max-new-tokens", "2", "--rate", "100"]


def _run_main(monkeypatch, capsys, extra):
    monkeypatch.setattr("sys.argv", ["serve.py"] + TINY + extra)
    serve.main()
    return capsys.readouterr().out


# ---------------------------------------------------------------------------
# fast lane: the early mesh/model compatibility error needs NO devices
# ---------------------------------------------------------------------------

def test_tp_head_divisibility_errors_before_device_work(monkeypatch, capsys):
    """qwen2-reduced has 2 KV heads: --tp 4 would shard a KV head across
    devices.  serve.py must fail with a one-line SystemExit BEFORE any
    mesh/device-count check, so this runs (and fails identically) on a
    single-device machine."""
    monkeypatch.setattr("sys.argv", ["serve.py"] + TINY + ["--tp", "4"])
    with pytest.raises(SystemExit) as e:
        serve.main()
    msg = str(e.value)
    assert "shard a KV head" in msg and "--tp 4" in msg
    assert "[1, 2]" in msg          # suggests the clean tps


def test_kv_heads_shardable_table():
    qwen = get_reduced("qwen2-0.5b")      # H=4, KV=2
    llama = get_reduced("llama2-7b")      # H=4, KV=4
    assert [t for t in (1, 2, 4) if kv_heads_shardable(qwen, t)] == [1, 2]
    assert [t for t in (1, 2, 4) if kv_heads_shardable(llama, t)] == [1, 2, 4]
    assert_tp_compatible(llama, 4)        # no raise
    with pytest.raises(ValueError):
        assert_tp_compatible(qwen, 4)


# ---------------------------------------------------------------------------
# multidevice lane: real sharded engines on a forced-host-device mesh
# ---------------------------------------------------------------------------

@multidevice
@need4
def test_tp2_check_tokens(monkeypatch, capsys):
    """--tp 2 --check-tokens: the sharded continuous engine's greedy tokens
    match the single-device sequential engine bit-for-bit."""
    out = _run_main(monkeypatch, capsys, ["--tp", "2", "--check-tokens"])
    assert "tensor parallel: tp=2" in out
    assert "token check: all 4 requests identical" in out


@multidevice
@need4
def test_tp4_check_tokens_llama(monkeypatch, capsys):
    """--tp 4 needs 4-KV-head llama2-reduced (qwen2 tops out at tp=2)."""
    out = _run_main(monkeypatch, capsys,
                    ["--arch", "llama2-7b", "--tp", "4", "--check-tokens"])
    assert "tensor parallel: tp=4" in out
    assert "token check: all 4 requests identical" in out


@multidevice
@need4
def test_2d_fleet_replicas_x_tp(monkeypatch, capsys):
    """2D fleet: tp=2 WITHIN each replica x affinity routing ACROSS 2
    replicas; tokens still match the single sequential engine."""
    out = _run_main(monkeypatch, capsys,
                    ["--tp", "2", "--replicas", "2", "--check-tokens"])
    assert "continuous x2 (affinity)" in out
    assert "token check: all 4 requests identical" in out


@multidevice
@need4
def test_mesh_size_parity_tokens_and_tier_hits(monkeypatch):
    """Mesh sizes 1 / 2 / 4: identical greedy tokens AND identical per-tier
    hit attribution (gpu/host/disk hit tokens) — sharding must not change
    what the knowledge tree thinks it cached."""
    args = serve.build_parser().parse_args(
        TINY + ["--arch", "llama2-7b", "--requests", "6"])
    cfg, params, corpus, idx, wl, _ = serve.make_setup(args)
    runs = {}
    for tp in (1, 2, 4):
        monkeypatch.setattr(args, "tp", tp)
        rt = serve.make_runtimes(cfg, params, corpus, idx, args, 1)[0]
        res = sorted(rt.serve(wl, max_new_tokens=args.max_new_tokens),
                     key=lambda r: r.req_id)
        s = rt.tree.stats
        runs[tp] = ([list(r.tokens) for r in res],
                    {k: s[k] for k in ("hit_tokens_gpu", "hit_tokens_host",
                                       "hit_tokens_disk", "hits", "misses")})
    assert runs[1] == runs[2] == runs[4]


@multidevice
@need4
def test_tp2_chunk_reuse_tolerance(monkeypatch, capsys):
    """--tp 2 --reuse chunk: relocated-chunk reuse is approximate, so the
    sharded engine verifies against the sequential oracle through the
    tolerance comparator instead of bit-exactness."""
    out = _run_main(monkeypatch, capsys,
                    ["--tp", "2", "--attn", "paged", "--reuse", "chunk",
                     "--recompute-tokens", "8", "--block-size", "8",
                     "--check-tokens", "tol:5"])
    assert "tensor parallel: tp=2" in out
    assert "token check: all 4 requests within tol 5" in out


@multidevice
@need4
def test_tp_with_paged_disk_tiers(monkeypatch, capsys):
    """Sharded pool + tiny GPU tier: demotions/promotions run through
    ShardedPagedBackend's per-shard copies and tokens stay identical."""
    out = _run_main(monkeypatch, capsys,
                    ["--tp", "2", "--check-tokens",
                     "--gpu-cache-bytes", str(48 * 2**10),
                     "--disk-cache-bytes", str(8 * 2**20)])
    assert "token check: all 4 requests identical" in out
