"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

# interpret-mode parity sweeps are minutes-scale: the CI `kernels` lane
# runs this file on every push/PR; the fast lane skips it (slow marker)
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,H,KV,Sq,P,hd", [
    (1, 4, 2, 16, 32, 32),
    (2, 8, 8, 24, 40, 64),
    (1, 4, 1, 32, 0, 32),      # MQA, no prefix
    (2, 2, 2, 8, 8, 128),      # MHA
    (1, 6, 2, 17, 23, 32),     # ragged sizes (padding paths)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_prefix_attention_sweep(B, H, KV, Sq, P, hd, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (B, H, Sq, hd), dtype)
    k = jax.random.normal(k2, (B, KV, P + Sq, hd), dtype)
    v = jax.random.normal(k3, (B, KV, P + Sq, hd), dtype)
    out = ops.prefix_attention(q, k, v, prefix_len=P, block_q=8, block_k=8,
                               interpret=True)
    want = ref.reference_prefix_attention(q, k, v, prefix_len=P)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("window", [8, 24])
def test_prefix_attention_sliding_window(window):
    k1, k2, k3 = jax.random.split(KEY, 3)
    B, H, KV, Sq, P, hd = 2, 4, 2, 16, 32, 32
    q = jax.random.normal(k1, (B, H, Sq, hd))
    k = jax.random.normal(k2, (B, KV, P + Sq, hd))
    v = jax.random.normal(k3, (B, KV, P + Sq, hd))
    out = ops.prefix_attention(q, k, v, prefix_len=P, window=window,
                               block_q=8, block_k=8, interpret=True)
    want = ref.reference_prefix_attention(q, k, v, prefix_len=P,
                                          window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4)


def test_prefix_attention_matches_model_flash():
    """Kernel, pure-jnp flash, and naive oracle all agree."""
    from repro.models import layers as L
    k1, k2, k3 = jax.random.split(KEY, 3)
    B, H, KV, Sq, P, hd = 1, 4, 2, 16, 16, 32
    q = jax.random.normal(k1, (B, Sq, H, hd))
    k = jax.random.normal(k2, (B, P + Sq, KV, hd))
    v = jax.random.normal(k3, (B, P + Sq, KV, hd))
    flash = L.flash_attention(q, k, v, q_offset=P, q_chunk=8, kv_chunk=8)
    kern = ops.prefix_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), prefix_len=P, block_q=8, block_k=8,
        interpret=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(kern), atol=1e-4)


@pytest.mark.parametrize("B,H,KV,hd,page,npages,nslots", [
    (2, 4, 2, 32, 8, 16, 4),
    (1, 8, 8, 64, 16, 8, 3),
    (3, 4, 4, 128, 8, 32, 6),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_sweep(B, H, KV, hd, page, npages, nslots, dtype):
    k1, k2, k3, k4, k5 = jax.random.split(KEY, 5)
    q = jax.random.normal(k1, (B, H, hd), dtype)
    kp = jax.random.normal(k2, (npages, page, KV, hd), dtype)
    vp = jax.random.normal(k3, (npages, page, KV, hd), dtype)
    bt = jax.random.randint(k4, (B, nslots), 0, npages)
    maxlen = page * nslots
    lengths = jax.random.randint(k5, (B,), 1, maxlen + 1)
    out = ops.paged_attention(q, kp, vp, bt, lengths, interpret=True)
    want = ref.reference_paged_attention(q, kp, vp, bt, lengths)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_paged_attention_respects_block_table_permutation():
    """Same logical sequence under two different physical page placements
    must give identical outputs (pure paging invariance)."""
    k1, k2 = jax.random.split(KEY)
    B, H, KV, hd, page, nslots = 1, 4, 2, 32, 8, 3
    npages = 12
    q = jax.random.normal(k1, (B, H, hd))
    kv = jax.random.normal(k2, (nslots * page, KV, hd))
    lengths = jnp.asarray([20], jnp.int32)

    def place(order):
        kp = jnp.zeros((npages, page, KV, hd))
        vp = jnp.zeros((npages, page, KV, hd))
        for i, pg in enumerate(order):
            kp = kp.at[pg].set(kv[i * page:(i + 1) * page])
            vp = vp.at[pg].set(kv[i * page:(i + 1) * page] * 0.5)
        return kp, vp, jnp.asarray([order], jnp.int32)

    o1 = ops.paged_attention(q, *place([0, 1, 2]), lengths, interpret=True)
    o2 = ops.paged_attention(q, *place([7, 3, 11]), lengths, interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)
