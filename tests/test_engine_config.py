"""EngineConfig surface tests: argparse -> frozen dataclasses -> CLI
round-trips, plus the config objects actually landing in the constructors
they are threaded through (router policy knobs; the runtime/engine paths
are exercised end-to-end by tests/test_serve_main.py and
tests/test_tp_serving.py).
"""
import pytest

jax = pytest.importorskip("jax")

from repro.launch.serve import build_parser  # noqa: E402
from repro.serving.config import (EngineConfig, FleetConfig,  # noqa: E402
                                  FrontDoorConfig, MeshConfig)
from repro.serving.router import ReplicaRouter  # noqa: E402


def _parse(argv):
    return build_parser().parse_args(list(argv))


def test_engine_config_from_args_maps_flags():
    args = _parse(["--policy", "lru", "--top-k", "3", "--no-reorder",
                   "--no-spec", "--max-batch", "7", "--block-size", "8",
                   "--attn", "dense", "--prefill-chunk", "32",
                   "--gpu-cache-bytes", "1024", "--search-scale", "2.5",
                   "--tp", "2"])
    ec = EngineConfig.from_args(args)
    assert ec.policy == "lru" and ec.top_k == 3
    assert ec.reorder is False and ec.speculative is False
    assert ec.max_batch == 7 and ec.block_size == 8 and ec.attn == "dense"
    assert ec.prefill_chunk == 32 and ec.gpu_cache_bytes == 1024
    assert ec.search_time_scale == 2.5
    assert ec.mesh == MeshConfig(tp=2)


def test_configs_are_frozen_and_validated():
    ec = EngineConfig()
    with pytest.raises(Exception):
        ec.policy = "lru"                    # frozen dataclass
    with pytest.raises(ValueError):
        MeshConfig(tp=0)
    with pytest.raises(ValueError):
        MeshConfig(tp=2, axis="")


@pytest.mark.parametrize("conf", [
    EngineConfig(),
    EngineConfig(policy="lru", top_k=5, reorder=False, speculative=False,
                 max_batch=9, prefill_chunk=16, block_size=32, attn="paged",
                 disk_cache_bytes=4096, disk_cache_dir="/tmp/x",
                 search_time_scale=3.0, mesh=MeshConfig(tp=4)),
    EngineConfig(mode="cag", disk_cache_bytes=1 << 20),
    FleetConfig(),
    FleetConfig(replicas=3, routing="least_loaded", max_queue_skew=9,
                max_shadow_paths=128),
    FrontDoorConfig(),
    FrontDoorConfig(enabled=True, ttl=5.0, sim_threshold=0.5, capacity=7,
                    autoscale=True, autoscale_min=2, scale_up_backlog=3.0,
                    scale_down_backlog=1.0, cooldown=0.5, slo_ttft_ms=250.0),
], ids=["engine-default", "engine-custom", "engine-cag", "fleet-default",
        "fleet-custom", "frontdoor-default", "frontdoor-custom"])
def test_cli_round_trip(conf):
    """from_args(parse(to_cli())) is the identity for every config, so a
    config can be logged and re-run as plain flags."""
    assert type(conf).from_args(_parse(conf.to_cli())) == conf


def test_router_takes_fleet_config():
    r = ReplicaRouter([object(), object()],
                      config=FleetConfig(replicas=2, routing="round_robin",
                                         max_queue_skew=7))
    assert r.policy == "round_robin" and r.max_queue_skew == 7


# ---------------------------------------------------------------------------
# hypothesis property tests (CI installs hypothesis; local runs skip)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                              # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=50, deadline=None)
    @given(tp=st.integers(min_value=1, max_value=128))
    def test_mesh_config_cli_round_trip(tp):
        """MeshConfig survives the CLI: to_cli() -> argparse -> from_args
        reproduces the exact config for any valid tp."""
        mc = MeshConfig(tp=tp)
        assert MeshConfig.from_args(_parse(mc.to_cli())) == mc

    @settings(max_examples=25, deadline=None)
    @given(tp=st.integers(min_value=1, max_value=16),
           top_k=st.integers(min_value=1, max_value=8),
           reorder=st.booleans(), spec=st.booleans(),
           mode=st.sampled_from(["rag", "cag"]),
           disk=st.integers(min_value=1, max_value=1 << 24))
    def test_engine_config_cli_round_trip_prop(tp, top_k, reorder, spec,
                                               mode, disk):
        ec = EngineConfig(top_k=top_k, reorder=reorder, speculative=spec,
                          mode=mode, disk_cache_bytes=disk,
                          mesh=MeshConfig(tp=tp))
        assert EngineConfig.from_args(_parse(ec.to_cli())) == ec
