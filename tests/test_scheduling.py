"""Cache-aware reordering (§5.2) + dynamic speculative pipelining (§5.3)."""

from repro.core.reorder import ReorderQueue
from repro.core.speculative import (SpecState, SpeculativeController,
                                    staged_topk)


def test_reorder_prefers_cached_requests():
    q = ReorderQueue(window=10)
    q.push("cold", cached_len=0, compute_len=100)
    q.push("hot", cached_len=90, compute_len=10)
    q.push("warm", cached_len=50, compute_len=50)
    assert q.pop() == "hot"
    assert q.pop() == "warm"
    assert q.pop() == "cold"


def test_reorder_scenario_figure10a():
    """Paper Fig. 10a: prioritize larger cached contexts."""
    q = ReorderQueue(window=10)
    q.push("Q1", cached_len=2, compute_len=1)   # bigger cache
    q.push("Q2", cached_len=1, compute_len=1)
    assert q.pop() == "Q1"


def test_reorder_scenario_figure10b():
    """Paper Fig. 10b: same cache, prioritize shorter recomputation."""
    q = ReorderQueue(window=10)
    q.push("Q1", cached_len=2, compute_len=2)
    q.push("Q2", cached_len=2, compute_len=1)   # shorter recompute
    assert q.pop() == "Q2"


def test_reorder_starvation_window():
    q = ReorderQueue(window=3)
    q.push("starved", cached_len=0, compute_len=100)
    for i in range(8):
        q.push(f"hot{i}", cached_len=100, compute_len=1)
    popped = [q.pop() for _ in range(4)]
    assert "starved" in popped, popped  # surfaced within window


def test_reorder_disabled_is_fifo():
    q = ReorderQueue(window=3, enabled=False)
    q.push("a", 0, 100)
    q.push("b", 100, 1)
    assert q.pop() == "a"


def test_dsp_launch_and_terminate():
    """Algorithm 2: launch on change when pool has room; stale speculation
    terminated; full pool defers."""
    ctl = SpeculativeController(max_prefill_bs=2)
    st = SpecState(0)
    a, d = ctl.on_stage(st, (1, 3), pool_size=0)
    assert a == "launch" and d == (1, 3)
    a, _ = ctl.on_stage(st, (1, 3), pool_size=1)
    assert a == "keep"
    a, d = ctl.on_stage(st, (1, 2), pool_size=1)
    assert a == "terminate_and_launch" and d == (1, 2)
    assert st.wasted_launches == 1
    # pool full, docs change again: terminate only
    a, _ = ctl.on_stage(st, (1, 4), pool_size=2)
    assert a == "terminate"
    # final stage is always admitted (Theorem 5.1 case 3)
    a, d = ctl.on_stage(st, (1, 5), pool_size=5, is_final=True)
    assert a in ("launch", "terminate_and_launch") and d == (1, 5)
    assert st.useful


def test_dsp_matching_final_keeps_speculation():
    """Paper Fig. 11: stage-2 docs equal the final docs -> speculation is
    kept and the final stage confirms it (no re-generation)."""
    ctl = SpeculativeController(max_prefill_bs=4)
    st = SpecState(0)
    stages = staged_topk(
        [[(0.9, 1), (1.2, 3)], [(1.0, 2)], [(1.5, 4)], [(2.0, 5)]], k=2)
    assert stages == [(1, 3), (1, 2), (1, 2), (1, 2)]
    actions = []
    for i, d in enumerate(stages):
        a, _ = ctl.on_stage(st, d, 0, is_final=(i == len(stages) - 1))
        actions.append(a)
    assert actions == ["launch", "terminate_and_launch", "keep", "keep"]
    assert st.useful and st.wasted_launches == 1


def test_dsp_disabled_waits_for_final():
    ctl = SpeculativeController(max_prefill_bs=4, enabled=False)
    st = SpecState(0)
    assert ctl.on_stage(st, (1,), 0)[0] == "none"
    assert ctl.on_stage(st, (2,), 0, is_final=True)[0] == "launch"
