"""Layer-level numerics: flash attention fwd/bwd vs naive, chunkwise mLSTM
vs recurrent oracle, chunked_scan equivalence, MoE paths."""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.models import layers as L

KEY = jax.random.PRNGKey(0)


def naive_attention(q, k, v, off=0, window=0, cap=0.0):
    B, Sq, H, hd = q.shape
    R = H // k.shape[2]
    kf = jnp.repeat(k, R, axis=2)
    vf = jnp.repeat(v, R, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kf) * hd ** -0.5
    if cap:
        s = cap * jnp.tanh(s / cap)
    qpos = off + jnp.arange(Sq)
    kpos = jnp.arange(k.shape[1])
    m = kpos[None, :] <= qpos[:, None]
    if window:
        m &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(m[None, None], s, -1e30)
    return jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vf)


@pytest.mark.parametrize("window,cap", [(0, 0.0), (17, 0.0), (0, 30.0)])
def test_flash_forward(window, cap):
    ks = jax.random.split(KEY, 3)
    B, Sq, Skv, KV, R, hd = 2, 37, 53, 2, 3, 16
    q = jax.random.normal(ks[0], (B, Sq, KV * R, hd))
    k = jax.random.normal(ks[1], (B, Skv, KV, hd))
    v = jax.random.normal(ks[2], (B, Skv, KV, hd))
    off = Skv - Sq
    o1 = L.flash_attention(q, k, v, q_offset=off, window=window,
                           logit_cap=cap, q_chunk=16, kv_chunk=16)
    o2 = naive_attention(q, k, v, off, window, cap)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)


def test_flash_backward():
    ks = jax.random.split(KEY, 3)
    B, Sq, Skv, KV, R, hd = 2, 24, 40, 2, 2, 16
    q = jax.random.normal(ks[0], (B, Sq, KV * R, hd))
    k = jax.random.normal(ks[1], (B, Skv, KV, hd))
    v = jax.random.normal(ks[2], (B, Skv, KV, hd))
    off = Skv - Sq

    def f1(q, k, v):
        return (L.flash_attention(q, k, v, q_offset=off, window=13,
                                  logit_cap=30.0, q_chunk=8,
                                  kv_chunk=8) ** 2).sum()

    def f2(q, k, v):
        return (naive_attention(q, k, v, off, 13, 30.0) ** 2).sum()

    g1 = jax.grad(f1, (0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(S=st.integers(2, 40), chunk=st.integers(2, 16),
       seed=st.integers(0, 100))
def test_mlstm_chunkwise_matches_recurrent(S, chunk, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    B, H, hd = 2, 2, 8
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    ig = jax.random.normal(ks[3], (B, S, H))
    fg = jax.random.normal(ks[4], (B, S, H)) + 1.0
    h1, (C1, n1, m1) = L.mlstm_scan(q, k, v, ig, fg)
    h2, (C2, n2, m2) = L.mlstm_chunkwise(q, k, v, ig, fg, chunk=chunk)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(C1), np.asarray(C2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-5)


def test_mlstm_state_chaining():
    """Processing [a;b] equals processing a then b from a's state — the
    SSM document-caching correctness condition."""
    ks = jax.random.split(KEY, 5)
    B, S, H, hd = 1, 24, 2, 8
    q, k, v = (jax.random.normal(ks[i], (B, S, H, hd)) for i in range(3))
    ig = jax.random.normal(ks[3], (B, S, H))
    fg = jax.random.normal(ks[4], (B, S, H)) + 1.0
    h_full, st_full = L.mlstm_chunkwise(q, k, v, ig, fg, chunk=8)
    _, st_a = L.mlstm_chunkwise(q[:, :10], k[:, :10], v[:, :10],
                                ig[:, :10], fg[:, :10], chunk=8)
    h_b, st_b = L.mlstm_chunkwise(q[:, 10:], k[:, 10:], v[:, 10:],
                                  ig[:, 10:], fg[:, 10:], state=st_a, chunk=8)
    np.testing.assert_allclose(np.asarray(h_b), np.asarray(h_full[:, 10:]),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_b[0]), np.asarray(st_full[0]),
                               atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(S=st.integers(1, 50), chunk=st.integers(1, 16))
def test_chunked_scan_property(S, chunk):
    def step(c, x):
        return c * 0.9 + x, c + x
    xs = jnp.arange(S, dtype=jnp.float32)
    c1, y1 = jax.lax.scan(step, jnp.float32(0), xs)
    c2, y2 = L.chunked_scan(step, jnp.float32(0), xs, chunk=chunk)
    np.testing.assert_allclose(float(c1), float(c2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6)


def test_moe_capacity_approximates_dense():
    """With generous capacity no token drops: capacity == dense routing."""
    ks = jax.random.split(KEY, 5)
    B, S, D, E, F = 2, 16, 32, 4, 64
    x = jax.random.normal(ks[0], (B, S, D), jnp.float32)
    router = jax.random.normal(ks[1], (D, E)) * 0.5
    wg = jax.random.normal(ks[2], (E, D, F)) * 0.1
    wu = jax.random.normal(ks[3], (E, D, F)) * 0.1
    wd = jax.random.normal(ks[4], (E, F, D)) * 0.1
    y1 = L.moe_dense(x, router, wg, wu, wd, top_k=2)
    y2 = L.moe_capacity(x, router, wg, wu, wd, top_k=2,
                        capacity_factor=4.0, token_chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_causal_conv_streaming():
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (2, 12, 8))
    w = jax.random.normal(ks[1], (4, 8))
    y_full, _ = L.causal_conv1d(x, w)
    y_a, st = L.causal_conv1d(x[:, :7], w)
    y_b, _ = L.causal_conv1d(x[:, 7:], w, st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y_a, y_b], 1)),
                               np.asarray(y_full), atol=1e-5)
