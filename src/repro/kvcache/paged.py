"""Paged KV block pool (vLLM-style, paper §2/§5.1: "RAGCache stores the
key-value tensors in non-contiguous memory blocks").

The pool owns a big (n_blocks, block_size, ...) buffer per tier; documents
hold block-id lists.  Ref-counting lets overlapping knowledge-tree paths
share blocks.  ``gather``/``scatter`` convert between paged storage and the
contiguous (B, S, KV, hd) layout the model functions consume.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

try:
    import jax
    import jax.numpy as jnp
except Exception:                                    # pragma: no cover
    jax = None


class OutOfBlocks(RuntimeError):
    pass


class BlockPool:
    """Fixed-capacity block allocator with refcounts."""

    def __init__(self, n_blocks: int, block_size: int):
        self.n_blocks = n_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(n_blocks - 1, -1, -1))
        self._ref = np.zeros(n_blocks, np.int32)

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def blocks_for_tokens(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise OutOfBlocks(f"need {n}, have {len(self._free)}")
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            self._ref[b] = 1
        return out

    def incref(self, blocks: Sequence[int]) -> None:
        for b in blocks:
            assert self._ref[b] > 0
            self._ref[b] += 1

    def decref(self, blocks: Sequence[int]) -> None:
        for b in blocks:
            assert self._ref[b] > 0, f"double free of block {b}"
            self._ref[b] -= 1
            if self._ref[b] == 0:
                self._free.append(b)

    def exclusive(self, blocks: Sequence[int]) -> int:
        """How many of ``blocks`` have refcount 1 — i.e. would actually
        return to the free list if their sole owner dropped them."""
        return int(sum(1 for b in blocks if self._ref[b] == 1))

    def check(self) -> None:
        live = int((self._ref > 0).sum())
        assert live + len(self._free) == self.n_blocks
        assert len(set(self._free)) == len(self._free)


class PagedKVStore:
    """Paged storage for per-document KV segments.

    Layout: k/v buffers of shape (L, n_blocks, block_size, KV, hd).  A stored
    segment is (block_ids, n_tokens).  numpy backing doubles as the host tier;
    jnp backing is the device tier.
    """

    def __init__(self, n_layers: int, n_blocks: int, block_size: int,
                 n_kv: int, head_dim: int, dtype=np.float32, device: bool = False):
        self.pool = BlockPool(n_blocks, block_size)
        self.block_size = block_size
        shape = (n_layers, n_blocks, block_size, n_kv, head_dim)
        self.device = device and jax is not None
        if self.device:
            self.k = jnp.zeros(shape, dtype)
            self.v = jnp.zeros(shape, dtype)
        else:
            self.k = np.zeros(shape, dtype)
            self.v = np.zeros(shape, dtype)

    def bytes_per_token(self) -> int:
        L, _, _, KV, hd = self.k.shape
        return int(2 * L * KV * hd * self.k.dtype.itemsize)

    def put(self, k_seg, v_seg, reserve_tokens: int = 0) -> "PagedSegment":
        """k_seg/v_seg: (L, 1, T, KV, hd) contiguous -> paged blocks.

        reserve_tokens: allocate capacity for this many *extra* tokens beyond
        T (the serving runtime's decode step writes appended tokens into the
        reserved tail slots through the request's block table)."""
        T = k_seg.shape[2]
        nb = self.pool.blocks_for_tokens(T + reserve_tokens)
        blocks = self.pool.alloc(nb)
        pad = nb * self.block_size - T
        if self.device:
            ks = jnp.pad(k_seg[:, 0], ((0, 0), (0, pad), (0, 0), (0, 0)))
            vs = jnp.pad(v_seg[:, 0], ((0, 0), (0, pad), (0, 0), (0, 0)))
            ks = ks.reshape(ks.shape[0], nb, self.block_size, *ks.shape[2:])
            vs = vs.reshape(vs.shape[0], nb, self.block_size, *vs.shape[2:])
            idx = jnp.asarray(blocks)
            self.k = self.k.at[:, idx].set(ks.astype(self.k.dtype))
            self.v = self.v.at[:, idx].set(vs.astype(self.v.dtype))
        else:
            k_seg = np.asarray(k_seg)
            v_seg = np.asarray(v_seg)
            for bi, b in enumerate(blocks):
                lo = bi * self.block_size
                hi = min(lo + self.block_size, T)
                if hi <= lo:            # reserve-only tail block
                    break
                self.k[:, b, : hi - lo] = k_seg[:, 0, lo:hi]
                self.v[:, b, : hi - lo] = v_seg[:, 0, lo:hi]
        return PagedSegment(self, blocks, T)

    def append(self, seg: "PagedSegment", k_new, v_new) -> "PagedSegment":
        """Extend an existing segment with more tokens (chunked-prefill
        continuation): fill the partially-used tail slots of the last block,
        then allocate additional blocks for the remainder.

        k_new/v_new: (L, 1, T, KV, hd) contiguous.  Mutates ``seg`` in place
        (blocks list + n_tokens) and returns it.  Raises ``OutOfBlocks``
        (leaving ``seg`` unchanged) if the pool cannot hold the extension.
        """
        T = int(k_new.shape[2])
        if T == 0:
            return seg
        capacity = len(seg.blocks) * self.block_size
        need = (seg.n_tokens + T) - capacity
        if need > 0:
            seg.blocks.extend(self.pool.alloc(self.pool.blocks_for_tokens(need)))
        # slot coordinates for the appended token positions
        pos = np.arange(seg.n_tokens, seg.n_tokens + T)
        blk = np.asarray(seg.blocks, np.int64)[pos // self.block_size]
        slot = pos % self.block_size
        if self.device:
            bi = jnp.asarray(blk)
            si = jnp.asarray(slot)
            self.k = self.k.at[:, bi, si].set(k_new[:, 0].astype(self.k.dtype))
            self.v = self.v.at[:, bi, si].set(v_new[:, 0].astype(self.v.dtype))
        else:
            k_new = np.asarray(k_new)
            v_new = np.asarray(v_new)
            for t in range(T):
                self.k[:, blk[t], slot[t]] = k_new[:, 0, t]
                self.v[:, blk[t], slot[t]] = v_new[:, 0, t]
        seg.n_tokens += T
        return seg

    def gather(self, seg: "PagedSegment"):
        """Paged -> contiguous (L, 1, T, KV, hd)."""
        idx = (jnp.asarray(seg.blocks) if self.device
               else np.asarray(seg.blocks, np.int64))
        k = self.k[:, idx]        # (L, nb, bs, KV, hd)
        v = self.v[:, idx]
        L, nb, bs, KV, hd = k.shape
        k = k.reshape(L, nb * bs, KV, hd)[:, : seg.n_tokens]
        v = v.reshape(L, nb * bs, KV, hd)[:, : seg.n_tokens]
        return k[:, None], v[:, None]

    def free(self, seg: "PagedSegment") -> None:
        self.pool.decref(seg.blocks)

    def share(self, seg: "PagedSegment") -> None:
        """Refcount a segment's blocks for an additional reader (e.g. a
        running request's block table pointing at knowledge-tree blocks)."""
        self.pool.incref(seg.blocks)

    def release(self, blocks: Sequence[int]) -> None:
        self.pool.decref(blocks)


@dataclasses.dataclass
class PagedSegment:
    store: PagedKVStore
    blocks: List[int]
    n_tokens: int

    @property
    def n_bytes(self) -> int:
        return len(self.blocks) * self.store.block_size * self.store.bytes_per_token()
