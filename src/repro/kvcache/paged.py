"""Paged KV block pool (vLLM-style, paper §2/§5.1: "RAGCache stores the
key-value tensors in non-contiguous memory blocks").

The pool owns a big (n_blocks, block_size, ...) buffer per tier; documents
hold block-id lists.  Ref-counting lets overlapping knowledge-tree paths
share blocks.  ``gather``/``scatter`` convert between paged storage and the
contiguous (B, S, KV, hd) layout the model functions consume.

``DiskSegmentStore`` is the third tier below the dense host copies: one
mmap file per knowledge-tree node (docs/ARCHITECTURE.md §2).  Segments are
written once on host->disk demotion and the file stays live until the disk
tier evicts the node, so repeated host demotions of the same node move zero
bytes ("spill-only-once", mirroring swap-out-only-once one tier up).
"""
from __future__ import annotations

import dataclasses
import itertools
import os
from typing import List, Optional, Sequence, Tuple

import numpy as np

try:
    import jax
    import jax.numpy as jnp
except Exception:                                    # pragma: no cover
    jax = None


class OutOfBlocks(RuntimeError):
    pass


class BlockPool:
    """Fixed-capacity block allocator with refcounts."""

    def __init__(self, n_blocks: int, block_size: int):
        self.n_blocks = n_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(n_blocks - 1, -1, -1))
        self._ref = np.zeros(n_blocks, np.int32)

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def blocks_for_tokens(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise OutOfBlocks(f"need {n}, have {len(self._free)}")
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            self._ref[b] = 1
        return out

    def incref(self, blocks: Sequence[int]) -> None:
        for b in blocks:
            assert self._ref[b] > 0
            self._ref[b] += 1

    def decref(self, blocks: Sequence[int]) -> None:
        for b in blocks:
            assert self._ref[b] > 0, f"double free of block {b}"
            self._ref[b] -= 1
            if self._ref[b] == 0:
                self._free.append(b)

    def exclusive(self, blocks: Sequence[int]) -> int:
        """How many of ``blocks`` have refcount 1 — i.e. would actually
        return to the free list if their sole owner dropped them."""
        return int(sum(1 for b in blocks if self._ref[b] == 1))

    def check(self) -> None:
        live = int((self._ref > 0).sum())
        assert live + len(self._free) == self.n_blocks
        assert len(set(self._free)) == len(self._free)


class PagedKVStore:
    """Paged storage for per-document KV segments.

    Layout: k/v buffers of shape (L, n_blocks, block_size, KV, hd).  A stored
    segment is (block_ids, n_tokens).  numpy backing doubles as the host tier;
    jnp backing is the device tier.
    """

    def __init__(self, n_layers: int, n_blocks: int, block_size: int,
                 n_kv: int, head_dim: int, dtype=np.float32,
                 device: bool = False, kv_sharding=None):
        self.pool = BlockPool(n_blocks, block_size)
        self.block_size = block_size
        shape = (n_layers, n_blocks, block_size, n_kv, head_dim)
        self.device = device and jax is not None
        # Tensor-parallel serving: a NamedSharding over the KV-head dim
        # (launch/sharding.py::pool_kv_spec) — the pool planes are created
        # sharded, and everything written into them (put/append) lands
        # shard-local, so no plane is ever materialized on one device.
        self.kv_sharding = kv_sharding if self.device else None
        if self.device:
            self.k = jnp.zeros(shape, dtype)
            self.v = jnp.zeros(shape, dtype)
            if self.kv_sharding is not None:
                self.k = jax.device_put(self.k, self.kv_sharding)
                self.v = jax.device_put(self.v, self.kv_sharding)
        else:
            self.k = np.zeros(shape, dtype)
            self.v = np.zeros(shape, dtype)

    def _shard_segment(self, k_seg, v_seg):
        """Promotion path of a sharded pool: place an incoming contiguous
        (L, B, T, KV, hd) segment with its KV heads split the same way the
        pool is, so the host->device copy is BATCHED per mesh-axis member —
        each device receives exactly its head slice, instead of a full
        replica that the next pool write would reshard collectively."""
        if self.kv_sharding is None or not self.device:
            return k_seg, v_seg
        if not isinstance(k_seg, np.ndarray):
            # device-computed segment (prefill cache slice): GSPMD already
            # placed its KV heads; the pool write reshards if needed
            return k_seg, v_seg
        seg_sh = jax.sharding.NamedSharding(
            self.kv_sharding.mesh,
            jax.sharding.PartitionSpec(None, None, None,
                                       *self.kv_sharding.spec[3:]))
        return jax.device_put(k_seg, seg_sh), jax.device_put(v_seg, seg_sh)

    def bytes_per_token(self) -> int:
        L, _, _, KV, hd = self.k.shape
        return int(2 * L * KV * hd * self.k.dtype.itemsize)

    def put(self, k_seg, v_seg, reserve_tokens: int = 0) -> "PagedSegment":
        """k_seg/v_seg: (L, 1, T, KV, hd) contiguous -> paged blocks.

        reserve_tokens: allocate capacity for this many *extra* tokens beyond
        T (the serving runtime's decode step writes appended tokens into the
        reserved tail slots through the request's block table)."""
        T = k_seg.shape[2]
        nb = self.pool.blocks_for_tokens(T + reserve_tokens)
        blocks = self.pool.alloc(nb)
        pad = nb * self.block_size - T
        if self.device:
            k_seg, v_seg = self._shard_segment(k_seg, v_seg)
            ks = jnp.pad(k_seg[:, 0], ((0, 0), (0, pad), (0, 0), (0, 0)))
            vs = jnp.pad(v_seg[:, 0], ((0, 0), (0, pad), (0, 0), (0, 0)))
            ks = ks.reshape(ks.shape[0], nb, self.block_size, *ks.shape[2:])
            vs = vs.reshape(vs.shape[0], nb, self.block_size, *vs.shape[2:])
            idx = jnp.asarray(blocks)
            self.k = self.k.at[:, idx].set(ks.astype(self.k.dtype))
            self.v = self.v.at[:, idx].set(vs.astype(self.v.dtype))
        else:
            k_seg = np.asarray(k_seg)
            v_seg = np.asarray(v_seg)
            for bi, b in enumerate(blocks):
                lo = bi * self.block_size
                hi = min(lo + self.block_size, T)
                if hi <= lo:            # reserve-only tail block
                    break
                self.k[:, b, : hi - lo] = k_seg[:, 0, lo:hi]
                self.v[:, b, : hi - lo] = v_seg[:, 0, lo:hi]
        return PagedSegment(self, blocks, T)

    def append(self, seg: "PagedSegment", k_new, v_new) -> "PagedSegment":
        """Extend an existing segment with more tokens (chunked-prefill
        continuation): fill the partially-used tail slots of the last block,
        then allocate additional blocks for the remainder.

        k_new/v_new: (L, 1, T, KV, hd) contiguous.  Mutates ``seg`` in place
        (blocks list + n_tokens) and returns it.  Raises ``OutOfBlocks``
        (leaving ``seg`` unchanged) if the pool cannot hold the extension.
        """
        T = int(k_new.shape[2])
        if T == 0:
            return seg
        capacity = len(seg.blocks) * self.block_size
        need = (seg.n_tokens + T) - capacity
        if need > 0:
            seg.blocks.extend(self.pool.alloc(self.pool.blocks_for_tokens(need)))
        # slot coordinates for the appended token positions
        pos = np.arange(seg.n_tokens, seg.n_tokens + T)
        blk = np.asarray(seg.blocks, np.int64)[pos // self.block_size]
        slot = pos % self.block_size
        if self.device:
            k_new, v_new = self._shard_segment(k_new, v_new)
            bi = jnp.asarray(blk)
            si = jnp.asarray(slot)
            self.k = self.k.at[:, bi, si].set(k_new[:, 0].astype(self.k.dtype))
            self.v = self.v.at[:, bi, si].set(v_new[:, 0].astype(self.v.dtype))
        else:
            k_new = np.asarray(k_new)
            v_new = np.asarray(v_new)
            for t in range(T):
                self.k[:, blk[t], slot[t]] = k_new[:, 0, t]
                self.v[:, blk[t], slot[t]] = v_new[:, 0, t]
        seg.n_tokens += T
        return seg

    def extend_alloc(self, seg: "PagedSegment",
                     n: int) -> Tuple[np.ndarray, np.ndarray]:
        """Reserve capacity for ``n`` more tokens WITHOUT writing data —
        the paged prefill kernel scatters KV into the pool in place, so the
        store only needs to hand out the (block, slot) coordinates.

        Mutates ``seg`` (blocks list + n_tokens) and returns int32
        ``(blk, slot)`` arrays of shape (n,) for the new token positions.
        Raises ``OutOfBlocks`` (leaving ``seg`` unchanged — ``alloc`` checks
        capacity before mutating anything) if the pool cannot hold them.
        """
        capacity = len(seg.blocks) * self.block_size
        need = (seg.n_tokens + n) - capacity
        if need > 0:
            seg.blocks.extend(self.pool.alloc(self.pool.blocks_for_tokens(need)))
        pos = np.arange(seg.n_tokens, seg.n_tokens + n)
        blk = np.asarray(seg.blocks, np.int64)[pos // self.block_size]
        slot = pos % self.block_size
        seg.n_tokens += n
        return blk.astype(np.int32), slot.astype(np.int32)

    def gather(self, seg: "PagedSegment"):
        """Paged -> contiguous (L, 1, T, KV, hd)."""
        idx = (jnp.asarray(seg.blocks) if self.device
               else np.asarray(seg.blocks, np.int64))
        k = self.k[:, idx]        # (L, nb, bs, KV, hd)
        v = self.v[:, idx]
        L, nb, bs, KV, hd = k.shape
        k = k.reshape(L, nb * bs, KV, hd)[:, : seg.n_tokens]
        v = v.reshape(L, nb * bs, KV, hd)[:, : seg.n_tokens]
        return k[:, None], v[:, None]

    def free(self, seg: "PagedSegment") -> None:
        self.pool.decref(seg.blocks)

    def share(self, seg: "PagedSegment") -> None:
        """Refcount a segment's blocks for an additional reader (e.g. a
        running request's block table pointing at knowledge-tree blocks)."""
        self.pool.incref(seg.blocks)

    def share_blocks(self, blocks: Sequence[int]) -> None:
        """Refcount a raw block list — the counterpart of ``release``.
        Chunk-cache relocated reuse shares only the page-aligned TAIL of a
        node's segment, so the reader never holds a ``PagedSegment``."""
        self.pool.incref(blocks)

    def release(self, blocks: Sequence[int]) -> None:
        self.pool.decref(blocks)


@dataclasses.dataclass
class PagedSegment:
    store: PagedKVStore
    blocks: List[int]
    n_tokens: int

    @property
    def n_bytes(self) -> int:
        return len(self.blocks) * self.store.block_size * self.store.bytes_per_token()


# --------------------------------------------------------------------------
# disk tier: one mmap file per knowledge-tree node
# --------------------------------------------------------------------------

@dataclasses.dataclass
class DiskSegment:
    """Handle to one node's on-disk KV: a (2, L, 1, T, KV, hd) mmap file
    (k stacked over v).  Shape/dtype live in the handle — the file is raw."""
    store: "DiskSegmentStore"
    path: str
    shape: Tuple[int, ...]          # (L, 1, T, KV, hd)
    dtype: np.dtype
    n_bytes: int


class DiskSegmentStore:
    """mmap-file-per-segment disk tier.

    ``write`` creates the file and flushes it (np.memmap w+ mode), ``read``
    maps it read-only and materialises numpy copies, ``delete`` reclaims the
    file.  Byte accounting (``used_bytes``/``n_files``) is exact — the file
    size is 2 * T * kv_bytes_per_token, no block padding — so tests and
    metrics can assert reclamation."""

    def __init__(self, root_dir: str, capacity_bytes: int = 0):
        self.root = root_dir
        self.capacity_bytes = capacity_bytes
        self.used_bytes = 0
        self.n_files = 0
        self._count = itertools.count()
        os.makedirs(root_dir, exist_ok=True)

    def write(self, k: np.ndarray, v: np.ndarray) -> DiskSegment:
        """k/v: (L, 1, T, KV, hd) host arrays -> one mmap'd file."""
        k = np.asarray(k)
        v = np.asarray(v)
        path = os.path.join(self.root, f"seg{next(self._count):08d}.kv")
        mm = np.memmap(path, dtype=k.dtype, mode="w+", shape=(2,) + k.shape)
        mm[0] = k
        mm[1] = v
        mm.flush()
        n_bytes = int(mm.nbytes)
        del mm                          # drop the mapping, keep the file
        self.used_bytes += n_bytes
        self.n_files += 1
        return DiskSegment(self, path, tuple(k.shape), k.dtype, n_bytes)

    def read(self, seg: DiskSegment) -> Tuple[np.ndarray, np.ndarray]:
        mm = np.memmap(seg.path, dtype=seg.dtype, mode="r",
                       shape=(2,) + seg.shape)
        k, v = np.array(mm[0]), np.array(mm[1])
        del mm
        return k, v

    def delete(self, seg: DiskSegment) -> None:
        os.remove(seg.path)
        self.used_bytes -= seg.n_bytes
        self.n_files -= 1

    def clear(self) -> None:
        """Best-effort removal of every segment file (shutdown path)."""
        for name in os.listdir(self.root):
            if name.endswith(".kv"):
                try:
                    os.remove(os.path.join(self.root, name))
                except OSError:
                    pass
        self.used_bytes = 0
        self.n_files = 0

    def close(self) -> None:
        """clear() plus removal of the (then-empty) segment directory."""
        self.clear()
        try:
            os.rmdir(self.root)
        except OSError:
            pass


def make_disk_store(root_dir: Optional[str],
                    capacity_bytes: int) -> Optional[DiskSegmentStore]:
    """Build the disk tier for a serving engine: a fresh subdirectory under
    ``root_dir`` (or under the system temp dir when None), so two engines
    pointed at the same directory — e.g. serve.py --check-tokens running
    both engines — never collide on segment file names.  None = disabled."""
    if capacity_bytes <= 0:
        return None
    import atexit
    import tempfile
    if root_dir is not None:
        os.makedirs(root_dir, exist_ok=True)
    path = tempfile.mkdtemp(prefix="ragcache-disk-", dir=root_dir)
    store = DiskSegmentStore(path, capacity_bytes)
    # the engine owns no shutdown hook; reclaim the segment files (up to the
    # whole disk budget) and the directory when the process exits
    atexit.register(store.close)
    return store
