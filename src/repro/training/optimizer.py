"""AdamW + cosine schedule, pure JAX (no optax in this environment).

Optimizer moments are fp32 regardless of param dtype (bf16 training).  Under
the production mesh the moments are sharded over BOTH the model and data
axes (ZeRO-1 style) — see launch/sharding.py — so 34B-param configs fit
v5e HBM during the train_4k dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    grad_clip: float = 1.0


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init_state(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: AdamWConfig, params, grads, state: AdamWState):
    step = state.step + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vh = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), gnorm
