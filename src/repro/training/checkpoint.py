"""Flat-npz checkpointing for arbitrary pytrees (no tensorstore offline)."""
from __future__ import annotations

from pathlib import Path
from typing import Any, Tuple

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz-portable
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def save(path: str, tree, step: int = 0) -> None:
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    np.savez(p, __step__=np.asarray(step), **flat)


def restore(path: str, like) -> Tuple[Any, int]:
    """Restore into the structure of `like` (shapes must match)."""
    data = np.load(path if str(path).endswith(".npz") else str(path) + ".npz")
    step = int(data["__step__"])
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for pth, leaf in flat_like:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in pth)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        import jax.numpy as jnp
        leaves.append(jnp.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves), step
