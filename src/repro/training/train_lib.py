"""Causal-LM training step shared by the train driver and the dry-run."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.training.optimizer import AdamWConfig, AdamWState, apply_updates


def loss_fn(cfg: ModelConfig, params, batch: Dict[str, jax.Array],
            seq_chunk: int = 512) -> jax.Array:
    """Causal-LM loss with *chunked* vocab projection: the (B, S, V) logits
    tensor is never materialized (gemma's V=262k x S=4k would be ~PB-scale);
    instead the LM head + softmax run per sequence chunk under remat."""
    x = M.forward_hidden(cfg, params, batch, remat=True)    # (B, S, D)
    labels = batch["labels"]
    if cfg.n_codebooks:
        labels = jnp.moveaxis(labels, 1, 2)                 # (B, S, K)
    mask = batch.get("loss_mask")
    if mask is not None and cfg.n_codebooks:
        mask = jnp.moveaxis(mask, 1, 2)

    B, S = x.shape[:2]
    chunk = min(seq_chunk, S)
    nc = S // chunk if S % chunk == 0 else -(-S // chunk)
    pad = nc * chunk - S
    xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, pad)) + ((0, 0),) * (labels.ndim - 2))
    mp = jnp.ones((B, S) + labels.shape[2:], jnp.float32) if mask is None \
        else mask.astype(jnp.float32)
    mp = jnp.pad(mp, ((0, 0), (0, pad)) + ((0, 0),) * (mp.ndim - 2))

    def chunk_loss(_, xs):
        xc, lc, mc = xs                                     # (B, C, ...)
        logits = M.lm_logits(cfg, params, xc)               # (B, C, [K,] V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # gold logit via masked reduction over the (model-sharded) vocab dim:
        # take_along_axis would all-gather the logits shard; this reduces to
        # a scalar psum instead.
        vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                              logits.ndim - 1)
        gold = jnp.where(vocab_iota == lc[..., None], logits, 0.0).sum(-1)
        nll = (lse - gold) * mc
        return None, (nll.sum(), mc.sum())

    resh = lambda a: jnp.moveaxis(
        a.reshape((B, nc, chunk) + a.shape[2:]), 1, 0)
    _, (nll_s, m_s) = jax.lax.scan(
        jax.checkpoint(chunk_loss), None, (resh(xp), resh(lp), resh(mp)))
    return nll_s.sum() / jnp.maximum(m_s.sum(), 1.0)


def make_train_step(cfg: ModelConfig, opt: AdamWConfig):
    def train_step(params, opt_state: AdamWState, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch))(params)
        params, opt_state, gnorm = apply_updates(opt, params, grads, opt_state)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}
    return train_step


def make_serve_step(cfg: ModelConfig):
    """One decode iteration over a preallocated cache (dry-run `serve_step`)."""
    def serve_step(params, tokens, cache, pos):
        return M.decode_step(cfg, params, tokens, cache, pos)
    return serve_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return M.prefill(cfg, params, batch)
    return prefill_step
