"""Data pipeline: deterministic synthetic corpus + file-backed token streams.

The synthetic stream produces structured (learnable) sequences so the
train-loop tests can assert loss *decreases*; the file loader memory-maps
token shards for real runs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class DataConfig:
    batch_size: int
    seq_len: int
    vocab_size: int
    seed: int = 0
    path: Optional[str] = None      # token shard (.npy) for file-backed mode


def synthetic_batches(cfg: DataConfig, model_cfg: Optional[ModelConfig] = None
                      ) -> Iterator[Dict[str, np.ndarray]]:
    """Markov-ish synthetic stream: next token = (a*tok + b) % V with noise,
    so a causal LM can reduce loss quickly."""
    rng = np.random.default_rng(cfg.seed)
    a = 31, 17
    K = model_cfg.n_codebooks if model_cfg and model_cfg.n_codebooks else 0
    while True:
        if K:
            toks = np.zeros((cfg.batch_size, K, cfg.seq_len + 1), np.int32)
            toks[:, :, 0] = rng.integers(0, cfg.vocab_size,
                                         (cfg.batch_size, K))
            for t in range(cfg.seq_len):
                nxt = (toks[:, :, t] * a[0] + a[1]) % cfg.vocab_size
                noise = rng.random((cfg.batch_size, K)) < 0.05
                nxt = np.where(noise, rng.integers(0, cfg.vocab_size,
                                                   (cfg.batch_size, K)), nxt)
                toks[:, :, t + 1] = nxt
            yield {"tokens": toks[:, :, :-1], "labels": toks[:, :, 1:]}
        else:
            toks = np.zeros((cfg.batch_size, cfg.seq_len + 1), np.int32)
            toks[:, 0] = rng.integers(0, cfg.vocab_size, cfg.batch_size)
            for t in range(cfg.seq_len):
                nxt = (toks[:, t] * a[0] + a[1]) % cfg.vocab_size
                noise = rng.random(cfg.batch_size) < 0.05
                nxt = np.where(noise,
                               rng.integers(0, cfg.vocab_size, cfg.batch_size),
                               nxt)
                toks[:, t + 1] = nxt
            yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def file_batches(cfg: DataConfig) -> Iterator[Dict[str, np.ndarray]]:
    """Memory-mapped token shard -> fixed-length LM batches."""
    data = np.load(cfg.path, mmap_mode="r")
    n = (len(data) - 1) // cfg.seq_len
    rng = np.random.default_rng(cfg.seed)
    while True:
        idx = rng.integers(0, n, cfg.batch_size)
        toks = np.stack([data[i * cfg.seq_len:(i + 1) * cfg.seq_len + 1]
                         for i in idx]).astype(np.int32)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_batches(cfg: DataConfig, model_cfg: Optional[ModelConfig] = None):
    if cfg.path:
        return file_batches(cfg)
    return synthetic_batches(cfg, model_cfg)
