"""Training driver (deliverable (b)): trains a ~100M-scale model for a few
hundred steps on the synthetic LM stream with AdamW + cosine schedule and
periodic checkpointing.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 300 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.models import model as M
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, make_batches
from repro.training.optimizer import AdamWConfig, init_state
from repro.training.train_lib import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (0 = reduced config default)")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    if args.d_model:
        cfg = dataclasses.replace(cfg, d_model=args.d_model)
    if args.layers:
        cfg = dataclasses.replace(cfg, n_layers=args.layers)
    n_params = M and sum(x.size for x in jax.tree.leaves(
        M.init_params(cfg, jax.random.PRNGKey(0))))
    print(f"model={cfg.name} params={n_params / 1e6:.1f}M")

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(10, args.steps // 20),
                      total_steps=args.steps)
    state = init_state(params)
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))
    data = make_batches(DataConfig(batch_size=args.batch, seq_len=args.seq,
                                   vocab_size=cfg.vocab_size), cfg)
    t0 = time.time()
    for step in range(1, args.steps + 1):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, state, metrics = step_fn(params, state, batch)
        if step % args.log_every == 0 or step == 1:
            tok_s = args.batch * args.seq * step / (time.time() - t0)
            print(f"step {step:>5}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"tok/s {tok_s:,.0f}", flush=True)
        if args.ckpt and step % args.ckpt_every == 0:
            ckpt.save(f"{args.ckpt}/step{step}", params, step=step)
            print(f"checkpointed -> {args.ckpt}/step{step}.npz")


if __name__ == "__main__":
    main()
