import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf probe: compile one (arch x shape) and print the top collective ops
(trip-count weighted) — the §Perf hypothesis-forming tool.

    PYTHONPATH=src python -m repro.launch.perf_probe --arch yi-34b \
        --shape prefill_32k [--opt attn-fallback] [--opt moe-capacity]
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.launch import sharding as SH
from repro.launch.hlo_analysis import analyze, top_collectives
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--opt", action="append", default=[])
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if "moe-capacity" in args.opt:
        cfg = dataclasses.replace(cfg, moe_impl="capacity")
    if "attn-fallback" in args.opt:
        SH.ATTN_REPLICATE_IF_RAGGED = True
    if "flat-gqa" in args.opt:
        from repro.models import layers as _L2
        _L2.FLAT_GQA = True
    if "seq-par" in args.opt:
        from repro.models import layers as _L
        _L.SEQ_PARALLEL_AXIS = "model"

    mesh = make_production_mesh(multi_pod=args.multi)
    with mesh:
        fn, specs, donate, out_sh = input_specs(cfg, args.shape, mesh)
        compiled = jax.jit(fn, donate_argnums=donate,
                           out_shardings=out_sh).lower(*specs).compile()
    txt = compiled.as_text()
    t = analyze(txt)
    print(f"flops/chip={t.flops:.3e}  dot_bytes={t.dot_bytes:.3e}  "
          f"coll_total={sum(t.coll.values()):.3e}")
    for k, v in t.coll.items():
        print(f"  {k:20s} {v:.3e}")
    print(f"peak={compiled.memory_analysis().peak_memory_in_bytes / 2**30:.2f} GiB")
    print("--- top collective ops (bytes x trips) ---")
    for nb, kind, meta in top_collectives(txt, args.top):
        print(f"{nb:12.3e}  {kind:18s} {meta}")


if __name__ == "__main__":
    main()
