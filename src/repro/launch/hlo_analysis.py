"""Trip-count-aware HLO cost extraction.

``compiled.cost_analysis()`` visits each while-loop body ONCE, so any scanned
program (scan-over-layers, flash chunk scans, chunkwise recurrences) is
undercounted by orders of magnitude.  The optimized HLO text, however, carries
``known_trip_count`` on every scan-derived while op.  This module parses the
module text into its computation graph and accumulates

  * matmul FLOPs (from ``dot`` ops: 2 x prod(output dims) x contracted size),
  * matmul memory traffic (lhs + rhs + out bytes per execution — an upper
    bound on HBM traffic that ignores fusion reuse; standard roofline proxy),
  * collective bytes by kind (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute),

multiplying through while-loop trip counts (nested loops compose) and taking
the max over conditional branches.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4,
    "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(
    r"(f64|f32|bf16|f16|f8e\w+|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128)"
    r"\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{\s*$")
_OPCODE_RE = re.compile(r"^((?:\([^)]*\)|[\w\[\]{},.\s])*?)\s([\w\-]+)\(")
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n"\s*:\s*"(\d+)"')
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def normalize_cost_analysis(ca) -> dict:
    """``compiled.cost_analysis()`` returns a dict on older jaxlib and a
    one-element list of dicts on newer jaxlib; normalize to a dict."""
    if isinstance(ca, (list, tuple)):
        return ca[0] if ca else {}
    return ca or {}


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        key = "f8" if dt.startswith("f8") else dt
        total += _shape_elems(dims) * _DTYPE_BYTES.get(key, 4)
    return total


def _type_elems(type_str: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(type_str):
        total += _shape_elems(dims)
    return total


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    dot_bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    coll_count: float = 0.0

    def add(self, other: "Totals", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.dot_bytes += other.dot_bytes * mult
        for k in COLLECTIVES:
            self.coll[k] += other.coll[k] * mult
        self.coll_count += other.coll_count * mult


class _Comp:
    def __init__(self, name: str):
        self.name = name
        self.shapes: Dict[str, str] = {}       # instr name -> type string
        self.own = Totals()
        self.whiles: List[Tuple[str, int]] = []     # (body comp, trips)
        self.calls: List[str] = []
        self.branches: List[List[str]] = []
        self.dots: List[str] = []              # raw dot lines (2nd pass)
        self.coll_ops: List[Tuple[str, int, str]] = []  # (kind, bytes, meta)


def parse_module(text: str) -> Dict[str, _Comp]:
    comps: Dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR_RE.match(line)
        if hdr and ("=" not in line.split("(")[0]):
            cur = _Comp(hdr.group(1))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        op_m = _OPCODE_RE.match(rhs)
        type_str = rhs.split("=", 1)[0]
        # type string is everything before the opcode
        if op_m:
            type_str, opcode = op_m.group(1), op_m.group(2)
        else:
            opcode = ""
        cur.shapes[name] = type_str
        if opcode == "dot":
            cur.dots.append(rhs)
        elif opcode in COLLECTIVES or opcode.rstrip("-start") in COLLECTIVES:
            base = opcode[:-6] if opcode.endswith("-start") else opcode
            if base in COLLECTIVES:
                nb = _type_bytes(type_str)
                cur.own.coll[base] += nb
                cur.own.coll_count += 1
                meta = ""
                mm = re.search(r'op_name="([^"]*)"', rhs)
                if mm:
                    meta = mm.group(1)[-120:]
                cur.coll_ops.append((base, nb, meta or type_str.strip()[:80]))
        elif opcode == "while":
            body = _BODY_RE.search(rhs)
            trip = _TRIP_RE.search(rhs)
            if body:
                cur.whiles.append(
                    (body.group(1), int(trip.group(1)) if trip else 1))
        elif opcode == "conditional":
            br = _BRANCH_RE.search(rhs)
            if br:
                names = [b.strip().lstrip("%") for b in br.group(1).split(",")]
                cur.branches.append(names)
        elif opcode in ("call", "fusion", "custom-call", "reduce",
                        "reduce-window", "sort", "scatter", "map", "select-and-scatter"):
            for cal in _CALLS_RE.findall(rhs):
                cur.calls.append(cal)
    # second pass: dot flops need operand shapes
    for comp in comps.values():
        for rhs in comp.dots:
            _account_dot(comp, rhs)
    return comps


def _account_dot(comp: _Comp, rhs: str) -> None:
    op_m = _OPCODE_RE.match(rhs)
    out_type = op_m.group(1)
    args_part = rhs.split("dot(", 1)[1].split(")")[0]
    operand_names = _OPERANDS_RE.findall(args_part)
    lhs_c = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
    contracted = 1
    lhs_type = comp.shapes.get(operand_names[0]) if operand_names else None
    if lhs_c and lhs_type:
        m = _SHAPE_RE.search(lhs_type)
        if m:
            dims = [int(d) for d in m.group(2).split(",") if d]
            for ci in lhs_c.group(1).split(","):
                if ci and int(ci) < len(dims):
                    contracted *= dims[int(ci)]
    out_elems = _type_elems(out_type)
    comp.own.flops += 2.0 * out_elems * contracted
    comp.own.dot_bytes += _type_bytes(out_type)
    for nm in operand_names[:2]:
        t = comp.shapes.get(nm)
        if t:
            comp.own.dot_bytes += _type_bytes(t)


def analyze(text: str, entry: Optional[str] = None) -> Totals:
    comps = parse_module(text)
    # find entry computation: the one declared with ENTRY, else "main*"
    entry_name = entry
    if entry_name is None:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
        entry_name = m.group(1) if m else next(iter(comps))
    memo: Dict[str, Totals] = {}

    def total(name: str, stack=()) -> Totals:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return Totals()
        c = comps[name]
        t = Totals()
        t.add(c.own)
        for body, trips in c.whiles:
            t.add(total(body, stack + (name,)), trips)
        for cal in c.calls:
            t.add(total(cal, stack + (name,)))
        for branch in c.branches:
            best = None
            for b in branch:
                bt = total(b, stack + (name,))
                if best is None or bt.flops > best.flops:
                    best = bt
            if best:
                t.add(best)
        memo[name] = t
        return t

    return total(entry_name)


def top_collectives(text: str, n: int = 20) -> List[Tuple[float, str, str]]:
    """Per-op collective contributions with trip multipliers applied:
    returns [(total_bytes, kind, op_name_metadata)] sorted descending."""
    comps = parse_module(text)
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
    entry_name = m.group(1) if m else next(iter(comps))
    out: List[Tuple[float, str, str]] = []

    def walk(name: str, mult: float, stack=()):
        if name not in comps or name in stack:
            return
        c = comps[name]
        for kind, nb, meta in c.coll_ops:
            out.append((nb * mult, kind, meta))
        for body, trips in c.whiles:
            walk(body, mult * trips, stack + (name,))
        for cal in c.calls:
            walk(cal, mult, stack + (name,))
        for branch in c.branches:
            for b in branch:
                walk(b, mult, stack + (name,))

    walk(entry_name, 1.0)
    out.sort(reverse=True)
    return out[:n]
