"""Production mesh construction (functions only — importing this module must
never touch jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e-256 pod: (data=16, model=16); two pods: (pod=2, data=16, model=16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for CI-scale sharding tests (8 forced host devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_serving_mesh(tp: int):
    """One serving replica's mesh: ``(data=1, model=tp)``.

    Serving replicas are data-parallel ACROSS replicas (PR 4's router owns
    that axis as whole processes), so within a replica only the model axis
    is real; the size-1 data axis keeps every ``data_axes``-consuming rule
    in ``launch/sharding.py`` well-defined.  Requires ``tp`` visible devices
    (on CPU: ``--xla_force_host_platform_device_count``)."""
    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    avail = jax.local_device_count()
    if avail < tp:
        raise ValueError(
            f"--tp {tp} needs {tp} devices but only {avail} are visible; "
            f"on CPU set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{tp} (before the first jax import)")
    return jax.make_mesh((1, tp), ("data", "model"))


def data_axes(mesh) -> tuple:
    """The batch-sharding axes of a mesh (pod included when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
