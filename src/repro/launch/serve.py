"""End-to-end RAG serving driver: builds a corpus + vector index,
instantiates a model, and serves a batched Poisson workload through the full
RAGCache pipeline (staged retrieval -> knowledge tree -> prefix prefill ->
decode), printing TTFT/TPOT percentiles and cache statistics.

Default mode is the continuous-batching runtime (iteration-level scheduling,
paged batched decode, retrieval/prefill overlap — ``serving.runtime``);
``--sequential`` serves through the old one-request-at-a-time ``RAGServer``
for A/B comparison, and ``--check-tokens`` runs BOTH and asserts the greedy
tokens are identical.  ``--reuse chunk`` switches the runtime from
prefix-only KV reuse to the per-doc chunk cache (docs/ARCHITECTURE.md §11):
cached docs are reused at ANY position with the first ``--recompute-tokens``
rows of each relocated chunk recomputed.  Relocated reuse is approximate,
so verify it with ``--check-tokens tol:<eps>`` (first-token logit L-inf
tolerance) instead of the default bit-exact mode.

``--replicas N`` serves through N independent continuous runtimes behind a
``ReplicaRouter`` (doc-affinity by default; ``--routing`` picks the policy
for A/B sweeps).  Routing never changes computation — a request's greedy
tokens are a pure function of (docs, question) — so ``--check-tokens``
stays bit-identical to the single sequential engine at any replica count.

``--tp N`` makes each continuous runtime span N devices: params are
sharded by the Megatron column/row rules (launch/sharding.py), the paged
pool's KV-head plane is sharded over the mesh's model axis, and the paged
decode kernel dispatches per shard with head-local block tables
(shard_map).  Tensor parallelism never changes greedy tokens either, so
``--check-tokens`` holds at tp x replicas (2D fleet).  On CPU, expose
devices with XLA_FLAGS=--xla_force_host_platform_device_count=N.

``--frontdoor`` puts the front-door request layer ahead of the router
(serving/frontdoor.py): a query-level cache (exact token-hash + cosine
similarity hits, TTL + LRU bounded), per-tenant SLO-aware admission
(degrade top-k, then shed), and an optional fleet autoscaler
(``--autoscale``) that grows/shrinks the router's active set within
[--autoscale-min, --replicas], warming joining replicas from their disk
tier.  ``--tenants N`` swaps the workload for the multi-tenant traffic
model (retrieval/traffic.py: canonical query pools, per-tenant Zipf +
SLOs, diurnal + Markov-modulated burst arrivals).  With --frontdoor,
``--check-tokens`` compares the front-door *misses* (with any top-k
degradation applied identically to both engines); hits are served from
cache and shed requests never execute, so both are excluded by
construction.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --requests 12 --docs 50 --top-k 2 [--policy lru] [--no-reorder] \
        [--sequential] [--check-tokens] \
        [--replicas N --routing {affinity,round_robin,least_loaded}] \
        [--gpu-cache-bytes N --host-cache-bytes N \
         --disk-cache-bytes N --disk-cache-dir DIR]

Uses the reduced config (CPU-sized); the production configs are exercised
through launch/dryrun.py.  SSM/hybrid families always use the sequential
engine (recurrent state cannot be paged per-block).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.launch.mesh import make_serving_mesh
from repro.launch.sharding import assert_tp_compatible, spec_summary
from repro.models import model as M
from repro.retrieval.corpus import make_corpus, make_workload
from repro.retrieval.traffic import make_default_workload
from repro.retrieval.vectordb import IVFIndex
from repro.serving.config import (EngineConfig, FleetConfig, FrontDoorConfig)
from repro.serving.engine import RAGServer
from repro.serving.frontdoor import (TenantSLO, attach_answers,
                                     frontdoor_partition, make_frontdoor)
from repro.serving.metrics import FleetMetrics
from repro.serving.router import (ROUTING_POLICIES, ReplicaRouter,
                                  partition_requests)
from repro.serving.runtime import ContinuousRuntime


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--docs", type=int, default=50)
    ap.add_argument("--doc-tokens", type=int, default=32)
    ap.add_argument("--top-k", type=int, default=2)
    ap.add_argument("--mode", default="rag", choices=["rag", "cag"],
                    help="workload mode (docs/ARCHITECTURE.md §12): 'rag' "
                         "runs staged retrieval per request; 'cag' "
                         "(cache-augmented generation) pre-inserts the FULL "
                         "corpus KV into the knowledge tree's disk tier at "
                         "startup and serves with zero retrieval stages — "
                         "docs resolve as tier hits promoted through the "
                         "PGDSF cascade.  Needs --disk-cache-bytes sized "
                         "for the whole corpus (0 = auto-size it)")
    ap.add_argument("--policy", default="pgdsf",
                    choices=["pgdsf", "gdsf", "lru", "lfu"])
    ap.add_argument("--gpu-cache-bytes", type=int, default=64 * 2**20,
                    help="knowledge-tree GPU tier budget (bytes)")
    ap.add_argument("--host-cache-bytes", type=int, default=512 * 2**20,
                    help="knowledge-tree host tier budget (bytes)")
    ap.add_argument("--disk-cache-bytes", type=int, default=0,
                    help="mmap'd disk tier budget below host memory "
                         "(0 = disabled); demotion cascades GPU->host->disk "
                         "under one PGDSF clock cascade")
    ap.add_argument("--disk-cache-dir", default=None,
                    help="directory for the disk tier's mmap segment files "
                         "(default: a fresh temp dir)")
    ap.add_argument("--no-reorder", action="store_true")
    ap.add_argument("--no-spec", action="store_true")
    ap.add_argument("--max-new-tokens", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=4,
                    help="decode-batch slots (continuous mode)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="independent continuous-runtime replicas behind "
                         "the doc-affinity router (each owns its own "
                         "knowledge tree / paged store / scheduler)")
    ap.add_argument("--routing", default="affinity",
                    choices=list(ROUTING_POLICIES),
                    help="replica routing policy (A/B-able; routing never "
                         "changes computation, so --check-tokens holds at "
                         "any replica count)")
    ap.add_argument("--max-queue-skew", type=int, default=4,
                    help="affinity escape hatch: max allowed max-min "
                         "per-replica queue-depth skew before a request "
                         "escapes to the least-loaded replica")
    ap.add_argument("--max-shadow-paths", type=int, default=4096,
                    help="bound on the router's shadow ledger of "
                         "per-replica routed doc-set paths (affinity "
                         "routing state, evicted LRU)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="tokens per prefill chunk (0 = unchunked); applies "
                         "to BOTH engines so --check-tokens compares "
                         "identically chunked computations")
    ap.add_argument("--max-prefill-bs", type=int, default=4,
                    help="row slots of the ragged paged-prefill batch "
                         "(continuous paged mode; jit retraces per "
                         "power-of-two chunk bucket above this floor)")
    ap.add_argument("--max-prefill-tokens", type=int, default=0,
                    help="ragged prefill-batch token budget per engine "
                         "iteration (0 = one request per iteration; "
                         "continuous mode)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged-KV block size in tokens (continuous mode)")
    ap.add_argument("--reuse", default="prefix",
                    choices=["prefix", "chunk"],
                    help="KV-reuse discipline (docs/ARCHITECTURE.md §11): "
                         "'prefix' reuses the longest cached doc-sequence "
                         "prefix (bit-identical); 'chunk' caches each doc "
                         "ONCE and reuses it at any position, recomputing "
                         "--recompute-tokens boundary rows per relocated "
                         "chunk (approximate — verify with "
                         "--check-tokens tol:<eps>; requires the paged "
                         "engine).  The sequential engine ignores this "
                         "(it stays the exact oracle)")
    ap.add_argument("--recompute-tokens", type=int, default=16,
                    help="boundary tokens recomputed per relocated chunk "
                         "(--reuse chunk); rounds UP to the block size so "
                         "the reused tail stays page-aligned, and clamps "
                         "to the chunk length (>= doc length degenerates "
                         "to an exact full recompute)")
    ap.add_argument("--attn", default="auto",
                    choices=["dense", "paged", "auto"],
                    help="continuous-mode attention engine for BOTH prefill "
                         "and decode: 'paged' computes straight against the "
                         "pool's page arrays (Pallas kernels on TPU, "
                         "per-page jnp online softmax on CPU) — prefill "
                         "scatters new KV into pages in place and decode "
                         "reads O(live tokens) per iteration, no dense KV "
                         "gather anywhere in steady state; 'dense' "
                         "re-materializes the full (L, B, S, KV, hd) "
                         "context every iteration (A/B baseline); 'auto' "
                         "= paged.  Greedy tokens are bit-identical across "
                         "modes; the sequential engine is always dense")
    ap.add_argument("--rate", type=float, default=100.0,
                    help="Poisson arrival rate (req/s)")
    # workload shape (single- and multi-tenant)
    ap.add_argument("--zipf-s", type=float, default=1.2,
                    help="Zipf doc-popularity skew of the workload")
    ap.add_argument("--drift", type=float, default=0.0,
                    help="fraction of popularity ranks reshuffled per "
                         "workload phase (non-stationary traffic; 0 = "
                         "stationary)")
    ap.add_argument("--n-phases", type=int, default=8,
                    help="workload phases for --drift")
    ap.add_argument("--output-len-mean", type=int, default=1,
                    help="mean decode length (1 = MMLU-like; ~6 = "
                         "NaturalQuestions-like)")
    # multi-tenant traffic model (retrieval/traffic.py)
    ap.add_argument("--tenants", type=int, default=0,
                    help="generate the workload from N tenants with "
                         "per-tenant Zipf skew, canonical query pools "
                         "(repeats -> front-door hits) and SLOs "
                         "(0 = single-tenant make_workload)")
    ap.add_argument("--slo-ttft-ms", type=float, default=500.0,
                    help="base per-tenant TTFT SLO target (tenant i gets "
                         "base * (1 + 0.5 i)); also the default SLO for "
                         "single-tenant --frontdoor runs")
    ap.add_argument("--tenant-queries", type=int, default=16,
                    help="canonical query pool size per tenant (smaller = "
                         "more repeats = higher front-door hit rate)")
    ap.add_argument("--diurnal-amplitude", type=float, default=0.0,
                    help="sinusoidal arrival-rate modulation depth (0..1)")
    ap.add_argument("--burst-rate-mult", type=float, default=1.0,
                    help="Markov-modulated burst-state rate multiplier "
                         "(1 = bursts off)")
    # front-door request layer (serving/frontdoor.py)
    ap.add_argument("--frontdoor", action="store_true",
                    help="serve through the front-door layer: query-level "
                         "cache (exact + similarity) -> per-tenant SLO "
                         "admission -> autoscaler -> replica router; "
                         "cache hits never reach an engine")
    ap.add_argument("--frontdoor-ttl", type=float, default=60.0,
                    help="query-cache TTL in seconds (entries expire TTL "
                         "after insertion regardless of use)")
    ap.add_argument("--frontdoor-sim-threshold", type=float, default=0.98,
                    help="cosine threshold for similarity hits against "
                         "cached query vectors (>= 1.0 disables the "
                         "similarity probe)")
    ap.add_argument("--frontdoor-capacity", type=int, default=512,
                    help="query-cache LRU capacity bound (entries)")
    ap.add_argument("--autoscale", action="store_true",
                    help="enable the fleet autoscaler: replicas in "
                         "[--autoscale-min, --replicas] against backlog "
                         "signals; scale-ups warm the joining replica's "
                         "tree from its disk tier")
    ap.add_argument("--autoscale-min", type=int, default=1,
                    help="autoscaler floor (active replicas never below)")
    ap.add_argument("--scale-up-backlog", type=float, default=8.0,
                    help="backlog per active replica above which the "
                         "fleet grows")
    ap.add_argument("--scale-down-backlog", type=float, default=2.0,
                    help="backlog per active replica below which the "
                         "fleet shrinks")
    ap.add_argument("--autoscale-cooldown", type=float, default=2.0,
                    help="seconds between autoscale events")
    ap.add_argument("--search-scale", type=float, default=1.0,
                    help="scale staged-search stage durations (emulate "
                         "paper-scale 78-446 ms searches on a tiny corpus)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree per replica: shard params "
                         "(Megatron col/row), paged-pool KV-head planes and "
                         "decode kernels over a (1, tp) device mesh.  "
                         "Requires tp visible devices (on CPU: "
                         "XLA_FLAGS=--xla_force_host_platform_device_count"
                         "=N).  Greedy tokens stay bit-identical to --tp 1, "
                         "so --check-tokens holds at any tp; composes with "
                         "--replicas into a 2D fleet (tp within a replica, "
                         "affinity routing across replicas)")
    ap.add_argument("--sequential", action="store_true",
                    help="serve through the old one-at-a-time RAGServer")
    ap.add_argument("--check-tokens", nargs="?", const="exact", default=None,
                    metavar="MODE",
                    help="run both engines and verify outputs.  Bare flag "
                         "or 'exact': greedy tokens must be bit-identical. "
                         "'tol:<eps>': tokens must match OR the first-token "
                         "logits must agree within L-inf <= eps — the "
                         "verification mode for --reuse chunk, whose "
                         "relocated chunks are approximate by construction")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def make_setup(args):
    """Build (cfg, params, corpus, idx, workload, tenants).  ``tenants`` is
    the TenantSpec list when --tenants > 0 (multi-tenant traffic model),
    else None (single-tenant stationary make_workload)."""
    cfg = get_reduced(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    corpus = make_corpus(args.docs, mean_doc_tokens=args.doc_tokens,
                         vocab=cfg.vocab_size, seed=args.seed)
    idx = IVFIndex(corpus.doc_vectors, n_clusters=min(16, args.docs),
                   nprobe=8)
    if args.tenants > 0:
        tenants, wl = make_default_workload(
            corpus, n_tenants=args.tenants, n_requests=args.requests,
            rate=args.rate, slo_ttft_ms=args.slo_ttft_ms,
            zipf_s=args.zipf_s, n_queries=args.tenant_queries,
            seed=args.seed + 1, drift=args.drift, n_phases=args.n_phases,
            diurnal_amplitude=args.diurnal_amplitude,
            burst_rate_mult=args.burst_rate_mult, vocab=cfg.vocab_size,
            question_tokens=8, output_len_mean=args.output_len_mean)
        return cfg, params, corpus, idx, wl, tenants
    wl = make_workload(corpus, n_requests=args.requests, rate=args.rate,
                       question_tokens=8, vocab=cfg.vocab_size,
                       zipf_s=args.zipf_s, seed=args.seed + 1,
                       drift=args.drift, n_phases=args.n_phases,
                       output_len_mean=args.output_len_mean)
    return cfg, params, corpus, idx, wl, None


def tier_hit_line(tree) -> str:
    s = tree.stats
    return (f"tier hits (tokens): gpu {s['hit_tokens_gpu']} / "
            f"host {s['hit_tokens_host']} / disk {s['hit_tokens_disk']}  "
            f"(spilled {s['spill_bytes']} B, fetched {s['fetch_bytes']} B)")


def parse_check_mode(value):
    """--check-tokens MODE -> ("exact", 0.0) or ("tol", eps).

    'exact' (or the bare flag) keeps the bit-identical contract; 'tol:<eps>'
    accepts token divergence when the first-token logits agree within
    L-inf <= eps — the only honest check for --reuse chunk, whose relocated
    chunks keep their original RoPE rotations (approximate by design)."""
    if value is None or value == "exact":
        return "exact", 0.0
    if isinstance(value, str) and value.startswith("tol:"):
        try:
            eps = float(value[len("tol:"):])
        except ValueError:
            raise SystemExit(f"--check-tokens: bad tolerance {value!r}")
        if eps < 0 or not np.isfinite(eps):
            raise SystemExit(f"--check-tokens: tolerance must be a finite "
                             f"non-negative number, got {value!r}")
        return "tol", eps
    raise SystemExit(f"--check-tokens: unknown mode {value!r} "
                     f"(use 'exact' or 'tol:<eps>')")


def token_mismatches(pairs, mode, eps):
    """Compare (continuous, sequential) result pairs under a check mode.

    exact: greedy tokens must be bit-identical.  tol: tokens may diverge iff
    both sides carry first-token logits within L-inf <= eps.  Returns the
    offending (req_id, tokens_a, tokens_b[, linf]) tuples."""
    bad = []
    for a, b in pairs:
        if list(a.tokens) == list(b.tokens):
            continue
        if mode == "tol" and a.first_logits is not None \
                and b.first_logits is not None:
            linf = float(np.max(np.abs(
                np.asarray(a.first_logits, np.float64)
                - np.asarray(b.first_logits, np.float64))))
            if linf <= eps:
                continue
            bad.append((a.req_id, list(a.tokens), list(b.tokens), linf))
        else:
            bad.append((a.req_id, list(a.tokens), list(b.tokens)))
    return bad


def serve_sequential(cfg, params, corpus, idx, wl, args, econf=None):
    # The sequential engine is the single-device token oracle: it takes the
    # same EngineConfig but deliberately ignores config.mesh, so
    # --check-tokens compares sharded continuous vs unsharded sequential.
    econf = econf if econf is not None else EngineConfig.from_args(args)
    srv = RAGServer(cfg, params, corpus, idx, config=econf)
    _print_preload(srv)
    t0 = time.time()
    results = srv.serve(wl, max_new_tokens=args.max_new_tokens)
    wall = time.time() - t0
    results = sorted(results, key=lambda r: r.req_id)
    print(f"\n[sequential] served {len(results)} requests in {wall:.1f}s "
          f"(incl. jit compiles)")
    print(f"{'req':>4} {'docs':>12} {'alpha':>6} {'beta':>5} "
          f"{'ttft_ms':>8}  tokens")
    for r in results:
        print(f"{r.req_id:>4} {str(r.docs):>12} {r.alpha:>6} {r.beta:>5} "
              f"{r.ttft * 1000:>8.1f}  {r.tokens}")
    ttfts = np.asarray([r.ttft for r in results])
    print(f"mean TTFT {ttfts.mean() * 1e3:.1f} ms  "
          f"(search+transfer+prefill summed serially)")
    print(f"doc hit rate: {srv.controller.doc_hit_rate:.2%}")
    print(tier_hit_line(srv.tree))
    print(f"tree stats: {srv.tree.stats}")
    return results


def _print_preload(engine, n_replicas: int = 1) -> None:
    """One-line CAG corpus-preload summary (docs/ARCHITECTURE.md §12)."""
    ps = getattr(engine, "preload_stats", None)
    if ps:
        per = f" per replica x{n_replicas}" if n_replicas > 1 else ""
        print(f"[cag] preloaded {ps['docs']} docs / {ps['tokens']} tokens "
              f"({ps['bytes']} B) into the disk tier in "
              f"{ps['seconds']:.2f}s{per}")


def make_runtimes(cfg, params, corpus, idx, args, n, econf=None):
    econf = econf if econf is not None else EngineConfig.from_args(args)
    return [ContinuousRuntime(cfg, params, corpus, idx, config=econf)
            for _ in range(n)]


def serve_continuous(cfg, params, corpus, idx, wl, args, econf=None,
                     fleet_conf=None):
    n = max(1, args.replicas)
    fleet_conf = (fleet_conf if fleet_conf is not None
                  else FleetConfig.from_args(args))
    rts = make_runtimes(cfg, params, corpus, idx, args, n, econf=econf)
    _print_preload(rts[0], n)
    router = ReplicaRouter(rts, config=fleet_conf)
    # partition the trace in arrival order by the request's retrieved docs
    # (deterministic, equal to the runtime's final staged-search result);
    # the in-flight window models per-replica backlog draining while the
    # trace arrives (each replica decodes max_batch requests concurrently)
    shares = partition_requests(
        router, wl,
        docs_of=lambda r: idx.search(r.query_vec, args.top_k),
        doc_tokens_of=lambda docs: [int(corpus.doc_lengths[d])
                                    for d in docs],
        context_of=lambda r, docs, toks: sum(toks) + len(r.question_tokens),
        window=2 * args.max_batch * n)
    t0 = time.time()
    results = []
    for rt, share in zip(rts, shares):
        if share:
            results.extend(rt.serve(share,
                                    max_new_tokens=args.max_new_tokens))
    wall = time.time() - t0
    results.sort(key=lambda r: r.req_id)
    label = "continuous" if n == 1 else f"continuous x{n} ({args.routing})"
    print(f"\n[{label}] served {len(results)} requests in {wall:.1f}s "
          f"wall (incl. jit compiles)")
    print(f"{'req':>4} {'docs':>12} {'alpha':>6} {'beta':>5} "
          f"{'ttft_ms':>8} {'spec':>5}  tokens")
    for r in results:
        print(f"{r.req_id:>4} {str(r.docs):>12} {r.alpha:>6} {r.beta:>5} "
              f"{r.ttft * 1000:>8.1f} {'hit' if r.speculative_hit else '':>5}"
              f"  {r.tokens}")
    print()
    if n == 1:
        print(rts[0].metrics.format_report())
        print(tier_hit_line(rts[0].tree))
        print(f"tree stats: {rts[0].tree.stats}")
    else:
        fleet = FleetMetrics(router.stats())
        for i, rt in enumerate(rts):
            fleet.add_replica(f"replica{i}", rt.metrics)
        print(fleet.format_report())
        for i, rt in enumerate(rts):
            print(f"replica{i} {tier_hit_line(rt.tree)}")
    return results


def build_frontdoor(args, tenants, fdc=None):
    """Assemble the FrontDoor policy stack from CLI flags (via
    FrontDoorConfig).  The SAME constructor path the simulator benchmarks
    use (make_frontdoor), so every driver assembles the identical policy
    objects."""
    fdc = fdc if fdc is not None else FrontDoorConfig.from_args(args)
    slos = {}
    if tenants:
        slos = {t.name: TenantSLO(ttft_target=t.slo_ttft_ms / 1e3,
                                  min_top_k=t.min_top_k) for t in tenants}
    n = max(1, args.replicas)
    return make_frontdoor(
        capacity=fdc.capacity, ttl=fdc.ttl,
        sim_threshold=fdc.sim_threshold, slos=slos,
        default_slo_ttft=fdc.slo_ttft_ms / 1e3, top_k=args.top_k,
        min_replicas=min(max(1, fdc.autoscale_min), n), max_replicas=n,
        autoscale=fdc.autoscale,
        scale_up_backlog=fdc.scale_up_backlog,
        scale_down_backlog=fdc.scale_down_backlog,
        cooldown=fdc.cooldown)


def serve_frontdoor(cfg, params, corpus, idx, wl, tenants, args, econf=None,
                    fleet_conf=None, fdc=None):
    """Serve through front door -> router -> N continuous runtimes.

    Returns (miss_results, part): engine results for admitted misses (the
    --check-tokens comparison set; hits are served from cache and shed
    requests never execute, so both are excluded by construction)."""
    n = max(1, args.replicas)
    fleet_conf = (fleet_conf if fleet_conf is not None
                  else FleetConfig.from_args(args))
    rts = make_runtimes(cfg, params, corpus, idx, args, n, econf=econf)
    _print_preload(rts[0], n)
    router = ReplicaRouter(rts, config=fleet_conf)
    fd = build_frontdoor(args, tenants, fdc=fdc)
    part = frontdoor_partition(
        fd, router, wl,
        docs_of=lambda r: idx.search(r.query_vec,
                                     r.top_k if r.top_k > 0 else args.top_k),
        doc_tokens_of=lambda docs: [int(corpus.doc_lengths[d])
                                    for d in docs],
        context_of=lambda r, docs, toks: sum(toks) + len(r.question_tokens),
        window=2 * args.max_batch * n)
    t0 = time.time()
    results = []
    for rt, share in zip(rts, part.shares):
        if share:
            results.extend(rt.serve(share,
                                    max_new_tokens=args.max_new_tokens))
    wall = time.time() - t0
    results.sort(key=lambda r: r.req_id)
    # answers only exist after serving: fill the cache entries (hits share
    # the entry object, so the cached answer reaches them too)
    attach_answers(part, {r.req_id: r.tokens for r in results})
    label = f"frontdoor x{n} ({args.routing})"
    print(f"\n[{label}] {len(wl)} requests -> {len(part.hits)} cache hits, "
          f"{len(part.shed)} shed, {len(results)} engine-served in "
          f"{wall:.1f}s wall (incl. jit compiles)")
    for r, dec in part.hits:
        src = dec.entry.answer if dec.entry is not None else []
        print(f"{r.req_id:>4} {dec.kind:<11} <- req {dec.entry.source_req_id}"
              f"  tokens {src}")
    fleet = FleetMetrics(router.stats(), fd.stats())
    for i, rt in enumerate(rts):
        fleet.add_replica(f"replica{i}", rt.metrics)
    print(fleet.format_report())
    if part.warmed:
        for i, b in sorted(part.warmed.items()):
            print(f"scale-up warmed replica{i}: {b} B from disk tier")
    return results, part


def main() -> None:
    args = build_parser().parse_args()
    # the config dataclasses are built ONCE from argparse here and threaded
    # through every constructor below — config= is the SOLE constructor
    # API; loose kwargs raise TypeError (serving/config.py,
    # docs/ARCHITECTURE.md §10)
    econf = EngineConfig.from_args(args)
    fleet_conf = FleetConfig.from_args(args)
    fdc = FrontDoorConfig.from_args(args)
    if econf.mesh.tp > 1:
        # validate head divisibility BEFORE any device work or device-count
        # check, so a bad --arch/--tp pair fails fast on any machine
        try:
            assert_tp_compatible(get_reduced(args.arch), econf.mesh.tp)
        except ValueError as e:
            raise SystemExit(f"--tp {econf.mesh.tp}: {e}")
    cfg, params, corpus, idx, wl, tenants = make_setup(args)
    print(f"model={cfg.name} family={cfg.family} layers={cfg.n_layers} "
          f"d_model={cfg.d_model}")
    if args.mode == "cag" and econf.disk_cache_bytes == 0 \
            and cfg.family not in ("ssm", "hybrid"):
        # auto-size the disk tier to hold the whole corpus KV exactly
        kv_bytes = max(1, 2 * cfg.n_layers * cfg.n_kv_heads * cfg.hd
                       * np.dtype(cfg.jdtype).itemsize)
        need = int(corpus.doc_lengths.sum()) * kv_bytes
        econf = dataclasses.replace(econf, disk_cache_bytes=need)
        print(f"[cag] --disk-cache-bytes 0 -> auto-sized to {need} B "
              f"({len(corpus.doc_lengths)} docs, {kv_bytes} B/token)")
    if econf.mesh.tp > 1:
        print(f"tensor parallel: tp={econf.mesh.tp} over a "
              f"(1, {econf.mesh.tp}) mesh "
              f"({jax.local_device_count()} devices visible)")
        try:
            smesh = make_serving_mesh(econf.mesh.tp)
        except RuntimeError as e:  # not enough devices: clean one-liner
            raise SystemExit(str(e))
        print(spec_summary(cfg, smesh, params))

    recurrent = cfg.family in ("ssm", "hybrid")
    if recurrent and not args.sequential:
        print("note: recurrent-state family -> sequential engine")
    if args.replicas > 1 and (recurrent or args.sequential):
        print("note: --replicas applies to the continuous engine only; "
              "the sequential A/B side stays a single engine")
    if recurrent and args.check_tokens:
        print("note: --check-tokens unavailable for recurrent families "
              "(no continuous engine to compare against); NOT checked")
    if args.frontdoor and (recurrent or args.sequential):
        print("note: --frontdoor requires the continuous engine; ignored")
    if econf.mesh.tp > 1 and (recurrent or args.sequential):
        print("note: --tp applies to the continuous engine only; the "
              "sequential engine is the single-device token oracle")
    if args.frontdoor and not recurrent and not args.sequential:
        miss_results, part = serve_frontdoor(cfg, params, corpus, idx, wl,
                                             tenants, args, econf=econf,
                                             fleet_conf=fleet_conf, fdc=fdc)
        if args.check_tokens:
            # compare ONLY admitted misses (the requests an engine actually
            # served, with the front door's top_k rewrites applied); hits
            # are answered from cache and shed requests never execute
            mode, eps = parse_check_mode(args.check_tokens)
            seq = serve_sequential(cfg, params, corpus, idx,
                                   list(part.misses), args, econf=econf)
            seq_by_id = {r.req_id: r for r in seq}
            mismatches = token_mismatches(
                [(a, seq_by_id[a.req_id]) for a in miss_results], mode, eps)
            if mismatches:
                raise SystemExit(f"token mismatch: {mismatches}")
            what = ("identical" if mode == "exact"
                    else f"within tol {eps:g}")
            print(f"\ntoken check: all {len(miss_results)} front-door miss "
                  f"requests {what} (continuous vs sequential; "
                  f"{len(part.hits)} hits + {len(part.shed)} shed excluded "
                  f"by construction)")
        return
    if args.check_tokens and not recurrent:
        mode, eps = parse_check_mode(args.check_tokens)
        cont = serve_continuous(cfg, params, corpus, idx, wl, args,
                                econf=econf, fleet_conf=fleet_conf)
        seq = serve_sequential(cfg, params, corpus, idx, wl, args,
                               econf=econf)
        mismatches = token_mismatches(
            zip(cont, sorted(seq, key=lambda r: r.req_id)), mode, eps)
        if mismatches:
            raise SystemExit(f"token mismatch: {mismatches}")
        what = "identical" if mode == "exact" else f"within tol {eps:g}"
        print(f"\ntoken check: all {len(cont)} requests {what} "
              f"(continuous vs sequential)")
    elif args.sequential or recurrent:
        serve_sequential(cfg, params, corpus, idx, wl, args, econf=econf)
    else:
        serve_continuous(cfg, params, corpus, idx, wl, args,
                         econf=econf, fleet_conf=fleet_conf)


if __name__ == "__main__":
    main()
