"""End-to-end RAG serving driver (deliverable (b)): builds a corpus + vector
index, instantiates a model, and serves a batched Poisson workload through
the full RAGCache pipeline (staged retrieval -> knowledge tree -> prefix
prefill -> decode), printing per-request TTFT and cache statistics.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --requests 12 --docs 50 --top-k 2 [--policy lru] [--no-reorder]

Uses the reduced config (CPU-sized); the production configs are exercised
through launch/dryrun.py.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models import model as M
from repro.retrieval.corpus import make_corpus, make_workload
from repro.retrieval.vectordb import IVFIndex
from repro.serving.engine import RAGServer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--docs", type=int, default=50)
    ap.add_argument("--doc-tokens", type=int, default=32)
    ap.add_argument("--top-k", type=int, default=2)
    ap.add_argument("--policy", default="pgdsf",
                    choices=["pgdsf", "gdsf", "lru", "lfu"])
    ap.add_argument("--no-reorder", action="store_true")
    ap.add_argument("--no-spec", action="store_true")
    ap.add_argument("--max-new-tokens", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    print(f"model={cfg.name} family={cfg.family} layers={cfg.n_layers} "
          f"d_model={cfg.d_model}")
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    corpus = make_corpus(args.docs, mean_doc_tokens=args.doc_tokens,
                         vocab=cfg.vocab_size, seed=args.seed)
    idx = IVFIndex(corpus.doc_vectors, n_clusters=min(16, args.docs),
                   nprobe=8)
    srv = RAGServer(cfg, params, corpus, idx, top_k=args.top_k,
                    policy=args.policy, reorder=not args.no_reorder,
                    speculative=not args.no_spec)
    wl = make_workload(corpus, n_requests=args.requests, rate=100.0,
                       question_tokens=8, vocab=cfg.vocab_size,
                       zipf_s=1.2, seed=args.seed + 1)
    t0 = time.time()
    results = srv.serve(wl, max_new_tokens=args.max_new_tokens)
    wall = time.time() - t0
    print(f"\nserved {len(results)} requests in {wall:.1f}s "
          f"(incl. jit compiles)")
    print(f"{'req':>4} {'docs':>12} {'alpha':>6} {'beta':>5} "
          f"{'ttft_ms':>8}  tokens")
    for r in results:
        print(f"{r.req_id:>4} {str(r.docs):>12} {r.alpha:>6} {r.beta:>5} "
              f"{r.ttft * 1000:>8.1f}  {r.tokens}")
    print(f"\ndoc hit rate: {srv.controller.doc_hit_rate:.2%}")
    print(f"tree stats: {srv.tree.stats}")


if __name__ == "__main__":
    main()
