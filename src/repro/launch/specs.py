"""Assigned input shapes + ShapeDtypeStruct stand-ins for the dry-run.

Every model input is a ShapeDtypeStruct with a NamedSharding attached —
weak-type-correct, shardable, zero allocation.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch import sharding as SH
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.training import optimizer as OPT


SHAPES: Dict[str, Dict[str, Any]] = {
    "train_4k":    dict(kind="train",  seq=4_096,   batch=256),
    "prefill_32k": dict(kind="prefill", seq=32_768, batch=32),
    "decode_32k":  dict(kind="decode", seq=32_768,  batch=128),
    "long_500k":   dict(kind="decode", seq=524_288, batch=1),
}


def supports_long_context(cfg: ModelConfig) -> bool:
    """long_500k runs only for sub-quadratic/bounded-KV archs (docs/ARCHITECTURE.md §4):
    SSM/hybrid (O(1)/windowed state) and dense archs with sliding windows."""
    if cfg.family in ("ssm", "hybrid"):
        return True
    return cfg.sliding_window > 0


def shape_applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and not supports_long_context(cfg):
        return False, ("pure full-attention arch: 500k decode skipped per "
                       "assignment rule (docs/ARCHITECTURE.md §4)")
    return True, ""


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _tok_struct(cfg: ModelConfig, batch: int, seq: int, mesh) -> Dict[str, Any]:
    """Token-side input structs for forward/prefill (no labels)."""
    bax, _ = SH.batch_spec(cfg, batch, mesh)
    nsh = lambda *spec: NamedSharding(mesh, P(*spec))
    if cfg.n_codebooks:
        return {"tokens": _sds((batch, cfg.n_codebooks, seq), jnp.int32,
                               nsh(bax, None, None))}
    if cfg.family == "vlm":
        vt = cfg.vision_tokens
        return {
            "tokens": _sds((batch, seq - vt), jnp.int32, nsh(bax, None)),
            "patch_embeds": _sds((batch, vt, cfg.d_model), jnp.float32,
                                 nsh(bax, None, None)),
        }
    return {"tokens": _sds((batch, seq), jnp.int32, nsh(bax, None))}


def input_specs(cfg: ModelConfig, shape_name: str, mesh):
    """Returns (step_fn, args tuple of ShapeDtypeStructs, donate_argnums,
    out_shardings or None)."""
    info = SHAPES[shape_name]
    kind, seq, batch = info["kind"], info["seq"], info["batch"]
    nsh = lambda *spec: NamedSharding(mesh, P(*spec))
    bax, _ = SH.batch_spec(cfg, batch, mesh)

    params_shape = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    p_sh = SH.param_shardings(cfg, params_shape, mesh)
    params_s = jax.tree.map(
        lambda l, s: _sds(l.shape, l.dtype, s), params_shape, p_sh)

    if kind == "train":
        from repro.training.train_lib import make_train_step
        opt_cfg = OPT.AdamWConfig()
        opt_shape = jax.eval_shape(lambda: OPT.init_state(params_shape))
        o_sh = SH.opt_shardings(cfg, params_shape, opt_shape, mesh)
        opt_s = jax.tree.map(lambda l, s: _sds(l.shape, l.dtype, s),
                             opt_shape, o_sh,
                             is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        batch_d = _tok_struct(cfg, batch, seq, mesh)
        batch_d["labels"] = jax.tree.map(
            lambda t: t, batch_d["tokens"])  # same shape/sharding as tokens
        if cfg.family == "vlm":
            batch_d["labels"] = _sds((batch, seq), jnp.int32, nsh(bax, None))
            batch_d["loss_mask"] = _sds((batch, seq), jnp.float32,
                                        nsh(bax, None))
        step = make_train_step(cfg, opt_cfg)
        metrics_sh = {"loss": nsh(), "grad_norm": nsh()}
        return (step, (params_s, opt_s, batch_d), (0, 1),
                (p_sh, o_sh, metrics_sh))

    if kind == "prefill":
        def prefill_step(params, batch):
            return M.prefill(cfg, params, batch)
        batch_d = _tok_struct(cfg, batch, seq, mesh)
        if SH.ATTN_REPLICATE_IF_RAGGED:
            # under the ZeRO-attention/seq-parallel config the inferred cache
            # sharding degrades to batch-only and overflows HBM at 32k —
            # pin it (batch over data, hd over model)
            cache_shape = jax.eval_shape(
                lambda p, b: M.prefill(cfg, p, b)[1], params_shape, batch_d)
            pc_sh = SH.cache_shardings(cfg, cache_shape, mesh, batch)
            logits_sh = NamedSharding(mesh, P(bax, None, None))
            return prefill_step, (params_s, batch_d), (), (logits_sh, pc_sh)
        return prefill_step, (params_s, batch_d), (), None

    # decode
    cache_shape = jax.eval_shape(
        lambda: M.init_decode_cache(cfg, batch, seq))
    c_sh = SH.cache_shardings(cfg, cache_shape, mesh, batch)
    cache_s = jax.tree.map(lambda l, s: _sds(l.shape, l.dtype, s),
                           cache_shape, c_sh)
    if cfg.n_codebooks:
        tok = _sds((batch, cfg.n_codebooks, 1), jnp.int32,
                   nsh(bax, None, None))
    else:
        tok = _sds((batch, 1), jnp.int32, nsh(bax, None))
    pos = _sds((batch,), jnp.int32, nsh(bax))

    def serve_step(params, tokens, cache, pos):
        return M.decode_step(cfg, params, tokens, cache, pos)

    return serve_step, (params_s, tok, cache_s, pos), (2,), (None, c_sh)
