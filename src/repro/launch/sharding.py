"""Partition rules: params, optimizer state, inputs, and decode caches.

Megatron-style tensor parallelism over the ``model`` axis:
  column-parallel: wq/wk/wv (fused head dim), wg/wu (d_ff), router, embeddings
  row-parallel:    wo, wd (contracting dim)
  expert-parallel: MoE expert stacks shard their expert dim over ``model``
                   when divisible, else fall back to d_ff sharding.
Optimizer moments additionally shard one more dim over the data axes
(ZeRO-1), which is what lets 34B-params x fp32 x 2 moments fit v5e HBM.

Every rule checks divisibility and falls back to replication — the dry-run
must lower for all 10 architectures x 4 shapes, including awkward head
counts (qwen2's 14 heads, hymba's 25).
"""
from __future__ import annotations

from typing import Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes
from repro.models.config import ModelConfig

# param-name -> (shard_dim_from_end, kind)
#   kind "col": shard the output dim; "row": shard the contracting dim.
_COL = {"wq", "wk", "wv", "wg", "wu", "w_up", "w_x", "router",
        "ssm_in", "lm_head", "vision_proj"}
_ROW = {"wo", "wd", "w_down", "ssm_out"}
_ATTN = {"wq", "wk", "wv", "wo"}

# §Perf optimization: when a head count doesn't tile the model axis (yi's 56
# heads / qwen2's 14 / hymba's 25 over 16), GSPMD falls back to sharding the
# *contracting* hd dim of attention, turning every flash-chunk score matmul
# into an all-reduce (measured: 93% of yi-34b prefill collective bytes).
# With this flag, such archs replicate attention weights over `model` and run
# attention purely data-parallel; FFN/vocab stay tensor-parallel.
ATTN_REPLICATE_IF_RAGGED = False


def _heads_tile_cleanly(cfg: ModelConfig, msize: int) -> bool:
    """True if a fused (H*hd) sharding is expressible as whole heads or an
    even intra-head split (GSPMD can propagate through the reshape)."""
    for heads in (cfg.n_heads, cfg.n_kv_heads):
        per_shard = heads * cfg.hd // msize
        if per_shard == 0:
            return False
        if per_shard % cfg.hd != 0 and cfg.hd % per_shard != 0:
            return False
    return True


def kv_heads_shardable(cfg: ModelConfig, tp: int) -> bool:
    """True when a ``tp``-way model axis splits attention into WHOLE heads:
    the paged pool's ``(L, n_blocks, block, KV, hd)`` planes shard dim 3, so
    a KV head split *across* devices would tear a page's head tile apart
    (and break the per-shard kernel dispatch's head-local block tables)."""
    return (tp >= 1 and cfg.n_kv_heads % tp == 0 and cfg.n_heads % tp == 0)


def assert_tp_compatible(cfg: ModelConfig, tp: int) -> None:
    """Error EARLY (before any mesh/device work) on a mesh/model pair that
    would shard a KV head across devices.  ``param_spec`` itself falls back
    to replication for awkward head counts — silently correct for dense
    training, but the serving pool cannot fall back: its layout IS the head
    dim.  Raising here turns a latent wrong-layout run into a one-line
    ``serve.py --tp`` error."""
    if tp > 1 and not kv_heads_shardable(cfg, tp):
        raise ValueError(
            f"--tp {tp} would shard a KV head across devices: {cfg.name} has "
            f"{cfg.n_heads} query / {cfg.n_kv_heads} KV heads, and the paged "
            f"pool shards whole KV heads over the model axis.  Pick tp "
            f"dividing both head counts "
            f"(e.g. {_clean_tps(cfg)}).")


def _clean_tps(cfg: ModelConfig, limit: int = 8) -> list:
    return [t for t in range(1, limit + 1)
            if kv_heads_shardable(cfg, t)]


def spec_summary(cfg: ModelConfig, mesh: Mesh, params_shape) -> str:
    """One-line-per-rule summary of the CHOSEN partition specs — surfaces
    the silent ``param_spec`` fallbacks (ragged heads, non-divisible d_ff /
    experts) that otherwise only show up as replicated HLO.  Printed by
    ``launch/dryrun.py`` and by ``serve.py --tp`` so the operator sees what
    actually sharded."""
    msize = _axis_size(mesh, "model")
    lines = [f"partition specs over model={msize} "
             f"(fused heads tile cleanly: "
             f"{_heads_tile_cleanly(cfg, msize)}; whole-KV-head serving "
             f"split: {kv_heads_shardable(cfg, msize)}):"]
    seen = {}
    leaves = jax.tree_util.tree_leaves_with_path(params_shape)
    for path, leaf in leaves:
        keys = tuple(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path)
        spec = param_spec(keys, leaf, cfg, mesh)
        name = keys[-1]
        sharded = any(s is not None for s in spec)
        label = f"{spec}" if sharded else "replicated"
        if name not in seen:
            seen[name] = label
        elif seen[name] != label:
            seen[name] += f" | {label}"
    for name in sorted(seen):
        lines.append(f"  {name:12s} -> {seen[name]}")
    return "\n".join(lines)


def pool_kv_spec() -> P:
    """Paged-pool partition spec: ``(L, n_blocks, block, KV, hd)`` shards
    whole KV heads over ``model``; block geometry stays replicated (block
    tables / slot mappings are identical on every shard)."""
    return P(None, None, None, "model", None)


def serving_param_shardings(cfg: ModelConfig, params_shape, mesh: Mesh):
    """``param_shardings`` minus row parallelism: the serving engine's
    deterministic-TP mode (models/layers.py::tp_deterministic).

    ``wo``/``wd`` REPLICATE instead of sharding their contraction rows.
    Row-parallel matmuls lower to per-device partial sums + all-reduce,
    whose float accumulation order differs from the single-device matmul —
    logits then drift a few ulps per layer and near-tie argmaxes flip
    greedy tokens between mesh sizes.  With the row matrices replicated
    AND ``dense_rowsum`` gathering the sharded activations first, every
    contraction is computed whole on each device: serving stays
    bit-identical at tp 1/2/4 (the --check-tokens contract) at the cost of
    not sharding the two down-projections.  Training keeps full Megatron
    row parallelism via ``param_shardings``."""
    def spec(path, leaf):
        if path and path[-1] in ("wo", "wd"):
            return P()
        return param_spec(path, leaf, cfg, mesh)
    return tree_shardings(params_shape, spec, mesh)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def _fits(dim: int, size: int) -> bool:
    return dim % size == 0 and dim >= size


def param_spec(path: Tuple[str, ...], leaf, cfg: ModelConfig, mesh: Mesh) -> P:
    name = path[-1]
    ndim = leaf.ndim
    msize = _axis_size(mesh, "model")
    spec = [None] * ndim

    is_expert = (cfg.moe_experts > 0 and name in ("wg", "wu", "wd")
                 and "blocks" in path)
    if is_expert:
        # (L, E, D, F) / (L, E, F, D): expert-parallel when E % model == 0
        if _fits(cfg.moe_experts, msize):
            spec[1] = "model"
        else:
            d = ndim - 1 if name in ("wg", "wu") else ndim - 2
            if _fits(leaf.shape[d], msize):
                spec[d] = "model"
        return P(*spec)

    if (ATTN_REPLICATE_IF_RAGGED and name in _ATTN
            and cfg.family != "ssm"
            and not _heads_tile_cleanly(cfg, msize)):
        # ragged heads: attention runs data-parallel (+ seq-parallel flash);
        # its weights shard over the *data* axes (ZeRO-style) and are
        # gathered once per layer — 16x less HBM than replication, and far
        # cheaper than the per-chunk score all-reduces of hd-sharding.
        daxes = data_axes(mesh)
        dsize = int(np.prod([_axis_size(mesh, a) for a in daxes]))
        ax = daxes if len(daxes) > 1 else daxes[0]
        d = ndim - 1 if name != "wo" else ndim - 2
        if _fits(leaf.shape[d], dsize):
            spec[d] = ax
        return P(*spec)

    if name == "embed":
        # (V, D) or (K, V, D): shard vocab
        d = ndim - 2
        if _fits(leaf.shape[d], msize):
            spec[d] = "model"
        return P(*spec)
    if name in _COL:
        d = ndim - 1
        if _fits(leaf.shape[d], msize):
            spec[d] = "model"
        return P(*spec)
    if name in _ROW:
        d = ndim - 2
        if _fits(leaf.shape[d], msize):
            spec[d] = "model"
        return P(*spec)
    return P()  # norms, biases, gates, conv, recurrent mats: replicate


def opt_spec(pspec: P, leaf, mesh: Mesh) -> P:
    """ZeRO-1: moments take the param spec + one extra dim over data axes."""
    daxes = data_axes(mesh)
    dsize = int(np.prod([_axis_size(mesh, a) for a in daxes]))
    spec = list(pspec) + [None] * (leaf.ndim - len(pspec))
    for d in range(leaf.ndim):
        if spec[d] is None and _fits(leaf.shape[d], dsize):
            spec[d] = daxes if len(daxes) > 1 else daxes[0]
            break
    return P(*spec)


def tree_shardings(tree, spec_fn, mesh: Mesh):
    def one(path, leaf):
        keys = tuple(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path)
        return NamedSharding(mesh, spec_fn(keys, leaf))
    return jax.tree_util.tree_map_with_path(one, tree)


def param_shardings(cfg: ModelConfig, params_shape, mesh: Mesh):
    return tree_shardings(
        params_shape, lambda p, l: param_spec(p, l, cfg, mesh), mesh)


def opt_shardings(cfg: ModelConfig, params_shape, opt_shape, mesh: Mesh):
    """AdamWState(step, m, v) shardings."""
    def m_spec(path, leaf):
        # path starts with 'm'/'v' field then mirrors param path
        ps = param_spec(path, leaf, cfg, mesh)
        return opt_spec(ps, leaf, mesh)
    step_sh = NamedSharding(mesh, P())
    m_sh = tree_shardings(opt_shape.m, m_spec, mesh)
    v_sh = tree_shardings(opt_shape.v, m_spec, mesh)
    return type(opt_shape)(step=step_sh, m=m_sh, v=v_sh)


# --------------------------------------------------------------------------
# activations / inputs / caches
# --------------------------------------------------------------------------

def batch_spec(cfg: ModelConfig, batch: int, mesh: Mesh) -> Tuple:
    daxes = data_axes(mesh)
    dsize = int(np.prod([_axis_size(mesh, a) for a in daxes]))
    ax = daxes if len(daxes) > 1 else daxes[0]
    return (ax if _fits(batch, dsize) else None), dsize


def input_shardings(cfg: ModelConfig, inputs, mesh: Mesh):
    def spec(path, leaf):
        if leaf.ndim == 0:
            return P()
        bax, _ = batch_spec(cfg, leaf.shape[0], mesh)
        return P(bax, *([None] * (leaf.ndim - 1)))
    return tree_shardings(inputs, lambda p, l: spec(p, l), mesh)


def cache_shardings(cfg: ModelConfig, cache, mesh: Mesh, batch: int):
    """Decode-cache shardings.

    KV cache (L, B, S, KV, hd): batch over data axes when divisible; for
    batch=1 long-context decode, the *sequence* dim shards over the data axes
    instead (distributed-context decode); hd over model (hd % 16 == 0 for
    every assigned arch).
    """
    daxes = data_axes(mesh)
    dsize = int(np.prod([_axis_size(mesh, a) for a in daxes]))
    msize = _axis_size(mesh, "model")
    ax = daxes if len(daxes) > 1 else daxes[0]
    batch_ok = _fits(batch, dsize)

    def spec(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        nd = leaf.ndim
        if name in ("k", "v"):                    # (L, B, S, KV, hd)
            s = [None] * nd
            if batch_ok:
                s[1] = ax
            elif _fits(leaf.shape[2], dsize):
                s[2] = ax                          # shard sequence
            if _fits(leaf.shape[4], msize):
                s[4] = "model"
            return P(*s)
        if name == "ssm":                          # (L, B, H, hd, N)
            s = [None] * nd
            if batch_ok:
                s[1] = ax
            if _fits(leaf.shape[3], msize):
                s[3] = "model"
            return P(*s)
        # xLSTM states: (..., B, H, hd[, hd]) / conv (..., B, K-1, Dp)
        s = [None] * nd
        for d in range(nd):
            if batch_ok and leaf.shape[d] == batch and s[d] is None:
                s[d] = ax
                break
        # shard the largest remaining dim over model if divisible
        order = sorted(range(nd), key=lambda d: -leaf.shape[d])
        for d in order:
            if s[d] is None and _fits(leaf.shape[d], msize) \
                    and leaf.shape[d] >= 4 * msize:
                s[d] = "model"
                break
        return P(*s)

    return tree_shardings(cache, lambda p, l: spec(p, l), mesh)
