import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh, extract memory/cost/collective roofline terms.

MUST be run as its own process (the XLA_FLAGS line above executes before any
jax import, and jax locks device count on first init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi

Results are appended as JSON files under experiments/dryrun/ (skip-if-exists,
so the sweep is resumable).
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ASSIGNED, get_config
from repro.launch.hlo_analysis import (COLLECTIVES, analyze,
                                       normalize_cost_analysis)
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import spec_summary
from repro.launch.specs import SHAPES, input_specs, shape_applicable
from repro.models import model as M

# ---- hardware constants (TPU v5e) ----------------------------------------
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4,
    "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"(\w+)\[([\d,]*)\][^=]*?\s(all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([\d,]*)\]")


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes of every collective op in the (SPMD-partitioned) HLO."""
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0, "count": 0}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # e.g.:  %ag = bf16[8,128]{1,0} all-gather(...)  or tuple variants
        m = re.search(r"\s(all-gather|all-reduce|reduce-scatter|all-to-all|"
                      r"collective-permute)(-start|-done)?\(", stripped)
        if not m or "-done(" in stripped:
            continue
        kind = m.group(1)
        lhs = stripped.split(" = ", 1)
        if len(lhs) != 2:
            continue
        shapes = _SHAPE_RE.findall(lhs[1].split(kind)[0])
        nbytes = 0
        for dt, dims in shapes:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            key = "f8" if dt.startswith("f8") else dt
            nbytes += n * _DTYPE_BYTES.get(key, 4)
        out[kind] += nbytes
        out["count"] += 1
    return out


def roofline(totals, raw_cost: dict, n_chips: int, cfg, shape_name: str) -> dict:
    """Three-term roofline from the trip-count-aware HLO analysis.

    NOTE: raw ``cost_analysis()`` visits while bodies once and is therefore
    useless for scanned programs; ``totals`` comes from
    ``hlo_analysis.analyze`` which multiplies through known_trip_counts.
    flops = matmul (dot) flops; bytes = dot operand+output traffic (HBM
    upper bound ignoring fusion reuse); both per-chip (post-SPMD program).
    """
    flops = totals.flops
    nbytes = totals.dot_bytes
    coll_b = sum(totals.coll[k] for k in COLLECTIVES)
    t_compute = flops / PEAK_FLOPS
    t_memory = nbytes / HBM_BW
    t_coll = coll_b / ICI_BW
    dominant = max((t_compute, "compute"), (t_memory, "memory"),
                   (t_coll, "collective"))[1]
    info = SHAPES[shape_name]
    tokens = info["batch"] * (info["seq"] if info["kind"] != "decode" else 1)
    model_flops = 6.0 * cfg.n_active_params() * tokens if info["kind"] == "train" \
        else 2.0 * cfg.n_active_params() * tokens
    total_flops = flops * n_chips
    return {
        "hlo_flops_per_chip": flops,
        "hlo_dot_bytes_per_chip": nbytes,
        "collective_bytes_per_chip": coll_b,
        "raw_cost_analysis_flops": float(raw_cost.get("flops", 0.0)),
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_ratio": model_flops / total_flops if total_flops else 0.0,
        "collectives": dict(totals.coll, count=totals.coll_count),
    }


def apply_opts(cfg, opts):
    """§Perf optimization toggles (see EXPERIMENTS.md §Perf)."""
    import dataclasses
    from repro.launch import sharding as SH
    from repro.models import layers as L
    if "moe-capacity" in opts:
        cfg = dataclasses.replace(cfg, moe_impl="capacity")
    if "attn-fallback" in opts:
        SH.ATTN_REPLICATE_IF_RAGGED = True
    if "seq-par" in opts:
        L.SEQ_PARALLEL_AXIS = "model"
    if "flat-gqa" in opts:
        L.FLAT_GQA = True
    return cfg


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
            force: bool = False, opts=()) -> dict:
    mesh_name = "multi" if multi_pod else "single"
    tag = ("__" + "+".join(sorted(opts))) if opts else ""
    out_file = out_dir / f"{arch}__{shape_name}__{mesh_name}{tag}.json"
    if out_file.exists() and not force:
        return json.loads(out_file.read_text())
    cfg = apply_opts(get_config(arch), opts)
    ok, reason = shape_applicable(cfg, shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "opts": sorted(opts)}
    if not ok:
        rec.update(status="skipped", reason=reason)
        out_file.write_text(json.dumps(rec, indent=2))
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_chips = mesh.devices.size
        # surface the CHOSEN partition specs (incl. silent replication
        # fallbacks for ragged head counts) next to the roofline numbers
        params_shape = jax.eval_shape(
            lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
        spec_text = spec_summary(cfg, mesh, params_shape)
        print(spec_text, flush=True)
        rec["partition_specs"] = spec_text.splitlines()
        with mesh:
            fn, args, donate, out_sh = input_specs(cfg, shape_name, mesh)
            jitted = jax.jit(fn, donate_argnums=donate, out_shardings=out_sh)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = normalize_cost_analysis(compiled.cost_analysis())
            totals = analyze(compiled.as_text())
        rl = roofline(totals, cost or {}, n_chips, cfg, shape_name)
        rec.update(
            status="ok",
            n_chips=int(n_chips),
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            },
            roofline=rl,
        )
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    out_file.write_text(json.dumps(rec, indent=2))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--opt", action="append", default=[])
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    archs = ASSIGNED if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.mesh == "both" else [args.mesh == "multi"]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_one(arch, shape, mp, out_dir, force=args.force,
                              opts=tuple(args.opt))
                status = rec["status"]
                extra = ""
                if status == "ok":
                    rl = rec["roofline"]
                    extra = (f"dom={rl['dominant']} "
                             f"tc={rl['t_compute_s']:.3e} "
                             f"tm={rl['t_memory_s']:.3e} "
                             f"tx={rl['t_collective_s']:.3e} "
                             f"peak={_gb(rec['memory']['peak_bytes'])}")
                elif status == "error":
                    failures += 1
                    extra = rec["error"][:120]
                else:
                    extra = rec.get("reason", "")[:60]
                print(f"[{status:7s}] {arch:24s} {shape:12s} "
                      f"{'multi' if mp else 'single':6s} {extra}", flush=True)
    sys.exit(1 if failures else 0)


def _gb(x):
    return f"{x / 2**30:.2f}GiB" if isinstance(x, (int, float)) and x else "?"


if __name__ == "__main__":
    main()
