"""Vector database with staged (pipelined) search — the substrate for
dynamic speculative pipelining (paper §5.3 / §6 "Pipelined vector search").

Two ANN indexes, as in the paper's implementation:

  * FlatL2  — exact scan; staged by splitting the database into shards.
  * IVF     — k-means clusters (Lloyd iterations in JAX); search probes the
              ``nprobe`` closest clusters, staged cluster-by-cluster so each
              stage returns the provisional top-k (the paper splits IVF
              search into multiple stages the same way).

Each stage reports an analytic CPU cost (bytes scanned / scan bandwidth) used
by the simulator; real wall-clock is also measured for the on-CPU benches.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# effective CPU scan bandwidth for the analytic retrieval cost model
SCAN_BYTES_PER_S = 4e9


@dataclasses.dataclass
class SearchStage:
    topk: Tuple[int, ...]          # provisional top-k doc ids
    seconds: float                 # analytic stage cost
    fraction_searched: float       # cumulative fraction of vectors scanned
    is_final: bool


def _l2_topk(q: np.ndarray, vecs: np.ndarray, ids: np.ndarray, k: int):
    d = ((vecs - q[None]) ** 2).sum(axis=1)
    order = np.argsort(d)[:k]
    return [(float(d[i]), int(ids[i])) for i in order]


class FlatIndex:
    """Exact L2 scan, staged over equal shards of the database.

    ``scan_bytes_per_s`` calibrates the *analytic* stage cost the simulator
    consumes — lower values model higher-accuracy / larger-corpus searches
    (the paper's 78-446 ms regime, Table 3)."""

    def __init__(self, vectors: np.ndarray, n_stages: int = 4,
                 scan_bytes_per_s: float = SCAN_BYTES_PER_S):
        self.vectors = np.asarray(vectors, np.float32)
        self.n = self.vectors.shape[0]
        self.n_stages = max(1, n_stages)
        self.scan_bytes_per_s = scan_bytes_per_s

    def search(self, q: np.ndarray, k: int) -> List[int]:
        return [d for _, d in _l2_topk(q, self.vectors,
                                       np.arange(self.n), k)]

    def staged_search(self, q: np.ndarray, k: int,
                      fraction: float = 1.0) -> Iterator[SearchStage]:
        limit = max(1, int(self.n * fraction))
        bounds = np.linspace(0, limit, self.n_stages + 1).astype(int)
        pool: List[Tuple[float, int]] = []
        for si in range(self.n_stages):
            lo, hi = bounds[si], bounds[si + 1]
            if hi > lo:
                pool.extend(_l2_topk(q, self.vectors[lo:hi],
                                     np.arange(lo, hi), k))
                pool.sort()
                pool = pool[:k]
            sec = (hi - lo) * self.vectors.shape[1] * 4 / self.scan_bytes_per_s
            yield SearchStage(
                topk=tuple(d for _, d in pool),
                seconds=sec + 1e-4,
                fraction_searched=hi / self.n,
                is_final=(si == self.n_stages - 1),
            )


class IVFIndex:
    """Inverted-file index with k-means centroids, staged by probed cluster."""

    def __init__(self, vectors: np.ndarray, n_clusters: int = 64,
                 nprobe: int = 8, kmeans_iters: int = 8, seed: int = 0,
                 scan_bytes_per_s: float = SCAN_BYTES_PER_S):
        self.scan_bytes_per_s = scan_bytes_per_s
        self.vectors = np.asarray(vectors, np.float32)
        self.n, self.d = self.vectors.shape
        self.n_clusters = min(n_clusters, self.n)
        self.nprobe = min(nprobe, self.n_clusters)
        self.centroids, self.assign = self._kmeans(kmeans_iters, seed)
        self.lists = [np.nonzero(self.assign == c)[0]
                      for c in range(self.n_clusters)]

    def _kmeans(self, iters: int, seed: int):
        key = jax.random.PRNGKey(seed)
        x = jnp.asarray(self.vectors)
        idx = jax.random.choice(key, self.n, (self.n_clusters,), replace=False)
        cent = x[idx]

        @jax.jit
        def step(cent):
            d = ((x[:, None] - cent[None]) ** 2).sum(-1)
            a = jnp.argmin(d, axis=1)
            oh = jax.nn.one_hot(a, self.n_clusters, dtype=jnp.float32)
            counts = oh.sum(0)[:, None]
            new = (oh.T @ x) / jnp.maximum(counts, 1.0)
            new = jnp.where(counts > 0, new, cent)
            return new, a

        a = None
        for _ in range(iters):
            cent, a = step(cent)
        return np.asarray(cent), np.asarray(a)

    def _probe_order(self, q: np.ndarray, fraction: float) -> List[int]:
        d = ((self.centroids - q[None]) ** 2).sum(axis=1)
        nprobe = max(1, int(round(self.nprobe * fraction)))
        return list(np.argsort(d)[:nprobe])

    def search(self, q: np.ndarray, k: int, fraction: float = 1.0) -> List[int]:
        out = []
        for st in self.staged_search(q, k, fraction):
            out = list(st.topk)
        return out

    def staged_search(self, q: np.ndarray, k: int,
                      fraction: float = 1.0) -> Iterator[SearchStage]:
        """One stage per probed cluster (closest centroid first)."""
        probe = self._probe_order(q, fraction)
        pool: List[Tuple[float, int]] = []
        scanned = 0
        for si, c in enumerate(probe):
            ids = self.lists[c]
            if len(ids):
                pool.extend(_l2_topk(q, self.vectors[ids], ids, k))
                pool.sort()
                pool = pool[:k]
            scanned += len(ids)
            sec = len(ids) * self.d * 4 / self.scan_bytes_per_s
            yield SearchStage(
                topk=tuple(d for _, d in pool),
                seconds=sec + 1e-4,
                fraction_searched=scanned / max(self.n, 1),
                is_final=(si == len(probe) - 1),
            )
