"""Synthetic knowledge corpus + QA workloads reproducing the paper's
characterization (§3.2): document lengths follow the Wikipedia-like long
distribution (mean ~3718 tokens in the paper; scaled down for CPU runs) and
the retrieval pattern is Zipf-skewed (top 3% of docs ≈ 60% of requests on
MMLU).  Queries embed as their target document's vector + noise, so ANN
retrieval reproduces the skew end-to-end rather than by construction.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class Corpus:
    doc_vectors: np.ndarray        # (N, d) unit vectors
    doc_tokens: List[np.ndarray]   # token ids per document
    doc_lengths: np.ndarray        # (N,)


@dataclasses.dataclass
class Request:
    req_id: int
    arrival: float                 # seconds
    query_vec: np.ndarray
    question_tokens: np.ndarray
    target_doc: int
    output_len: int
    # multi-tenant traffic model (retrieval/traffic.py); engines ignore these
    tenant: str = ""               # tenant name ("" = single-tenant workload)
    query_id: int = -1             # canonical query id (repeats share one id)
    top_k: int = 0                 # per-request retrieval depth override
    #                                (0 = engine default; the front door's
    #                                SLO admission degrades requests by
    #                                lowering this, serving/frontdoor.py)


def make_corpus(
    n_docs: int,
    embed_dim: int = 32,
    mean_doc_tokens: int = 192,
    vocab: int = 32000,
    seed: int = 0,
) -> Corpus:
    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(n_docs, embed_dim)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    # long-ish lognormal doc lengths (paper Fig. 3: docs >> questions)
    lens = np.clip(
        rng.lognormal(np.log(mean_doc_tokens), 0.4, n_docs), 16, 8 * mean_doc_tokens
    ).astype(int)
    toks = [rng.integers(0, vocab, size=l).astype(np.int32) for l in lens]
    return Corpus(vecs, toks, lens)


def zipf_popularity(n_docs: int, s: float = 1.0, seed: int = 0) -> np.ndarray:
    """Zipf document popularity with a random rank permutation (the popular
    docs are arbitrary ids, as in real corpora)."""
    rng = np.random.default_rng(seed)
    ranks = rng.permutation(n_docs) + 1
    p = 1.0 / ranks.astype(np.float64) ** s
    return p / p.sum()


def make_workload(
    corpus: Corpus,
    *,
    n_requests: int,
    rate: float,                   # Poisson arrival rate (req/s)
    zipf_s: float = 1.0,
    question_tokens: int = 32,
    output_len_mean: int = 1,      # 1 => MMLU-like; ~6 => NaturalQuestions-like
    query_noise: float = 0.05,
    vocab: int = 32000,
    seed: int = 1,
    drift: float = 0.0,            # fraction of popularity ranks reshuffled
                                   # per workload phase (temporal locality;
                                   # real QA traffic is non-stationary)
    n_phases: int = 8,
) -> List[Request]:
    rng = np.random.default_rng(seed)
    n_docs = len(corpus.doc_lengths)
    if drift > 0.0:
        ranks = rng.permutation(n_docs) + 1
        targets = np.empty(n_requests, np.int64)
        bounds = np.linspace(0, n_requests, n_phases + 1).astype(int)
        for ph in range(n_phases):
            if ph:
                k = max(2, int(drift * n_docs))
                idx = rng.choice(n_docs, size=k, replace=False)
                ranks[idx] = ranks[rng.permutation(idx)]
            p = 1.0 / ranks.astype(np.float64) ** zipf_s
            p /= p.sum()
            lo, hi = bounds[ph], bounds[ph + 1]
            targets[lo:hi] = rng.choice(n_docs, size=hi - lo, p=p)
    else:
        pop = zipf_popularity(n_docs, zipf_s, seed)
        targets = rng.choice(n_docs, size=n_requests, p=pop)
    gaps = rng.exponential(1.0 / rate, size=n_requests)
    arrivals = np.cumsum(gaps)
    out = []
    for i in range(n_requests):
        t = targets[i]
        q = corpus.doc_vectors[t] + rng.normal(
            scale=query_noise, size=corpus.doc_vectors.shape[1]
        ).astype(np.float32)
        if output_len_mean <= 1:
            olen = 1
        else:
            olen = int(np.clip(rng.geometric(1.0 / output_len_mean), 1, 32))
        out.append(Request(
            req_id=i,
            arrival=float(arrivals[i]),
            query_vec=q,
            question_tokens=rng.integers(0, vocab, question_tokens).astype(np.int32),
            target_doc=int(t),
            output_len=olen,
        ))
    return out


def access_cdf(doc_ids: Sequence[int], n_docs: int) -> Tuple[np.ndarray, np.ndarray]:
    """CDF of accesses vs fraction of (sorted-by-popularity) documents —
    reproduces paper Fig. 5."""
    counts = np.bincount(np.asarray(doc_ids), minlength=n_docs).astype(np.float64)
    counts[::-1].sort()
    cdf = np.cumsum(counts) / max(counts.sum(), 1)
    frac_docs = np.arange(1, n_docs + 1) / n_docs
    return frac_docs, cdf
