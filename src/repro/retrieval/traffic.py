"""Production traffic model: multi-tenant, non-stationary request streams.

``corpus.make_workload`` is a single-tenant stationary Poisson/Zipf stream;
the RAG systems trade-offs study (arXiv 2412.11854) shows that the request
*mix* — tenant skew, burstiness, output-length distribution — dominates
end-to-end behavior, and none of it is measurable on a stationary stream.
This module generates the load the front-door subsystem
(``serving/frontdoor.py``) is built to absorb:

  * **multi-tenant corpora** — each ``TenantSpec`` owns a slice of the
    corpus, its own Zipf doc-popularity skew, question/output-length shape,
    and a TTFT SLO the admission layer enforces;
  * **canonical query pools** — real users repeat themselves: each tenant
    draws from a finite pool of canonical queries (Zipf-skewed by query
    rank), so repeated queries carry *identical* question tokens and query
    vectors (exact front-door hits) and near-duplicates carry jittered
    vectors with mutated tokens (similarity hits);
  * **diurnal rate modulation** — a sinusoid over the arrival rate
    (``diurnal_amplitude``/``diurnal_period``);
  * **Markov-modulated bursts** — a two-state (calm/burst) modulated
    Poisson process: in the burst state the instantaneous rate is
    multiplied by ``burst_rate_mult``; state transitions are sampled per
    arrival.

The generator emits the existing ``retrieval.corpus.Request`` type (with
the optional ``tenant``/``query_id`` fields filled in), so the sequential
engine, the continuous runtime and the simulator all consume the stream
unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.retrieval.corpus import Corpus, Request


@dataclasses.dataclass
class TenantSpec:
    """One tenant's traffic shape and service-level objective."""
    name: str
    weight: float = 1.0            # share of fleet traffic (normalized)
    zipf_s: float = 1.0            # doc-popularity skew in the tenant slice
    slo_ttft_ms: float = 500.0     # TTFT target the admission layer enforces
    n_queries: int = 64            # canonical query pool size (smaller =
    #                                more repeats = higher front-door hit rate)
    query_zipf_s: float = 1.0      # query-popularity skew within the pool
    near_dup_prob: float = 0.0     # prob a repeat is a near-duplicate
    #                                (jittered vector + mutated tokens —
    #                                similarity hit, never an exact hit)
    question_tokens: int = 32
    output_len_mean: int = 1
    doc_lo: float = 0.0            # tenant's corpus slice [doc_lo, doc_hi)
    doc_hi: float = 1.0            # as fractions of the doc-id space
    min_top_k: int = 1             # degrade floor for SLO admission


def default_tenants(n: int, *, slo_ttft_ms: float = 500.0,
                    zipf_s: float = 1.2,
                    n_queries: int = 64) -> List[TenantSpec]:
    """N tenants with the canonical production shape: a heavy head tenant
    and a tail of lighter ones (weights 1/rank), disjoint corpus slices,
    tighter SLOs for the head (paying) tenants."""
    out = []
    for i in range(max(1, n)):
        lo = i / max(1, n)
        hi = (i + 1) / max(1, n)
        out.append(TenantSpec(
            name=f"tenant{i}",
            weight=1.0 / (i + 1),
            zipf_s=zipf_s,
            slo_ttft_ms=slo_ttft_ms * (1.0 + 0.5 * i),
            n_queries=n_queries,
            doc_lo=lo, doc_hi=hi,
        ))
    return out


@dataclasses.dataclass
class TrafficConfig:
    n_requests: int
    base_rate: float               # mean arrival rate (req/s) before modulation
    diurnal_amplitude: float = 0.0  # 0..1: rate swings base*(1 +/- amplitude)
    diurnal_period: float = 60.0   # seconds per simulated "day"
    burst_rate_mult: float = 1.0   # burst-state rate multiplier (1 = off)
    burst_on_prob: float = 0.05    # calm->burst transition prob per arrival
    burst_off_prob: float = 0.3    # burst->calm transition prob per arrival
    query_noise: float = 0.05      # canonical query vec = doc vec + this
    near_dup_noise: float = 0.02   # extra jitter on near-duplicate vectors
    vocab: int = 32000
    seed: int = 1
    drift: float = 0.0             # fraction of each tenant's doc ranks
    #                                reshuffled per phase (non-stationarity)
    n_phases: int = 8


@dataclasses.dataclass
class _QueryPool:
    """A tenant's canonical queries: repeated draws of query ``q`` emit the
    exact same vector + tokens, so the front door's exact cache can hit."""
    vecs: np.ndarray               # (n_queries, d)
    tokens: List[np.ndarray]
    targets: np.ndarray            # (n_queries,) target doc per query
    probs: np.ndarray              # (n_queries,) Zipf query popularity


def _zipf(n: int, s: float, rng: np.random.Generator) -> np.ndarray:
    ranks = rng.permutation(n) + 1
    p = 1.0 / ranks.astype(np.float64) ** s
    return p / p.sum()


def _build_pool(corpus: Corpus, t: TenantSpec, cfg: TrafficConfig,
                rng: np.random.Generator) -> _QueryPool:
    n_docs = len(corpus.doc_lengths)
    lo = int(t.doc_lo * n_docs)
    hi = max(lo + 1, int(t.doc_hi * n_docs))
    slice_ids = np.arange(lo, hi)
    doc_p = _zipf(len(slice_ids), t.zipf_s, rng)
    n_q = max(1, t.n_queries)
    targets = slice_ids[rng.choice(len(slice_ids), size=n_q, p=doc_p)]
    d = corpus.doc_vectors.shape[1]
    vecs = (corpus.doc_vectors[targets]
            + rng.normal(scale=cfg.query_noise, size=(n_q, d))
            ).astype(np.float32)
    toks = [rng.integers(0, cfg.vocab, t.question_tokens).astype(np.int32)
            for _ in range(n_q)]
    return _QueryPool(vecs=vecs, tokens=toks, targets=targets,
                      probs=_zipf(n_q, t.query_zipf_s, rng))


def make_tenant_workload(corpus: Corpus, tenants: Sequence[TenantSpec],
                         cfg: TrafficConfig) -> List[Request]:
    """Generate the multi-tenant trace.  Deterministic per ``cfg.seed``."""
    if not tenants:
        raise ValueError("need at least one TenantSpec")
    rng = np.random.default_rng(cfg.seed)
    pools = [_build_pool(corpus, t, cfg, rng) for t in tenants]
    weights = np.asarray([max(t.weight, 1e-9) for t in tenants], np.float64)
    weights /= weights.sum()

    # non-stationary phases: reshuffle a fraction of each pool's query
    # popularity ranks at phase boundaries (same knob as make_workload's
    # drift, applied to the query pool so repeats stay exact)
    bounds = np.linspace(0, cfg.n_requests, max(1, cfg.n_phases) + 1)
    bounds = bounds.astype(int)

    out: List[Request] = []
    t_now = 0.0
    burst = False
    phase = 0
    for i in range(cfg.n_requests):
        while phase + 1 < len(bounds) - 1 and i >= bounds[phase + 1]:
            phase += 1
            if cfg.drift > 0.0:
                for pool in pools:
                    n_q = len(pool.probs)
                    k = max(2, int(cfg.drift * n_q))
                    if k <= n_q:
                        idx = rng.choice(n_q, size=k, replace=False)
                        pool.probs[idx] = pool.probs[rng.permutation(idx)]
                        pool.probs /= pool.probs.sum()
        # Markov-modulated Poisson: transition, then draw the gap at the
        # current instantaneous rate (diurnal x burst modulation)
        if burst:
            if rng.random() < cfg.burst_off_prob:
                burst = False
        elif rng.random() < cfg.burst_on_prob and cfg.burst_rate_mult > 1.0:
            burst = True
        rate = cfg.base_rate
        if cfg.diurnal_amplitude > 0.0:
            rate *= 1.0 + cfg.diurnal_amplitude * np.sin(
                2.0 * np.pi * t_now / max(cfg.diurnal_period, 1e-9))
        if burst:
            rate *= cfg.burst_rate_mult
        t_now += rng.exponential(1.0 / max(rate, 1e-9))

        ti = int(rng.choice(len(tenants), p=weights))
        tenant, pool = tenants[ti], pools[ti]
        q = int(rng.choice(len(pool.probs), p=pool.probs))
        vec = pool.vecs[q]
        toks = pool.tokens[q]
        if tenant.near_dup_prob > 0.0 and rng.random() < tenant.near_dup_prob:
            # near-duplicate: semantically the same query, phrased slightly
            # differently — the exact hash misses, the similarity probe hits
            vec = (vec + rng.normal(scale=cfg.near_dup_noise,
                                    size=vec.shape).astype(np.float32))
            toks = toks.copy()
            toks[rng.integers(0, len(toks))] = rng.integers(0, cfg.vocab)
        if tenant.output_len_mean <= 1:
            olen = 1
        else:
            olen = int(np.clip(rng.geometric(1.0 / tenant.output_len_mean),
                               1, 32))
        out.append(Request(
            req_id=i,
            arrival=float(t_now),
            query_vec=np.asarray(vec, np.float32),
            question_tokens=np.asarray(toks, np.int32),
            target_doc=int(pool.targets[q]),
            output_len=olen,
            tenant=tenant.name,
            query_id=q + 100000 * ti,   # globally unique per (tenant, query)
        ))
    return out


def tenant_slos(tenants: Sequence[TenantSpec]) -> Dict[str, float]:
    """name -> TTFT target in SECONDS (what SloAdmission consumes)."""
    return {t.name: t.slo_ttft_ms / 1e3 for t in tenants}


def repeat_rate(requests: Sequence[Request]) -> float:
    """Fraction of requests whose (tenant, query_id) was seen before — the
    exact-hit ceiling for an infinite, never-expiring front-door cache."""
    seen: set = set()
    repeats = 0
    for r in requests:
        key = (r.tenant, r.query_id)
        if key in seen:
            repeats += 1
        seen.add(key)
    return repeats / max(len(requests), 1)


def split_by_tenant(requests: Sequence[Request]
                    ) -> Dict[str, List[Request]]:
    out: Dict[str, List[Request]] = {}
    for r in requests:
        out.setdefault(r.tenant, []).append(r)
    return out


def make_default_workload(corpus: Corpus, *, n_tenants: int = 2,
                          n_requests: int = 64, rate: float = 10.0,
                          slo_ttft_ms: float = 500.0, zipf_s: float = 1.2,
                          n_queries: int = 16, seed: int = 1,
                          drift: float = 0.0, n_phases: int = 8,
                          diurnal_amplitude: float = 0.0,
                          burst_rate_mult: float = 1.0,
                          vocab: int = 32000,
                          question_tokens: Optional[int] = None,
                          output_len_mean: int = 1,
                          ) -> tuple:
    """One-call setup for drivers: (tenants, requests).  Used by
    ``launch/serve.py --frontdoor/--tenants`` and the benchmarks."""
    tenants = default_tenants(n_tenants, slo_ttft_ms=slo_ttft_ms,
                              zipf_s=zipf_s, n_queries=n_queries)
    for t in tenants:
        if question_tokens is not None:
            t.question_tokens = question_tokens
        t.output_len_mean = output_len_mean
    cfg = TrafficConfig(n_requests=n_requests, base_rate=rate, seed=seed,
                        drift=drift, n_phases=n_phases,
                        diurnal_amplitude=diurnal_amplitude,
                        burst_rate_mult=burst_rate_mult, vocab=vocab)
    return tenants, make_tenant_workload(corpus, tenants, cfg)
