"""Jit'd public wrappers for the Pallas kernels.

On a TPU runtime these dispatch to the compiled kernels; on CPU (this
container) they run in interpret mode, which executes the kernel body in
Python and validates the BlockSpec/grid logic bit-for-bit.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import prefix_attention as _pa
from repro.kernels import paged_attention as _pg


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("prefix_len", "window",
                                             "block_q", "block_k",
                                             "interpret"))
def prefix_attention(q, k, v, *, prefix_len: int, window: int = 0,
                     block_q: int = 128, block_k: int = 128,
                     interpret: bool | None = None):
    """Flash prefill over [cached prefix ‖ new] KV. Layouts:
    q: (B, H, Sq, hd); k/v: (B, KV, prefix_len + Sq, hd)."""
    interp = (not _on_tpu()) if interpret is None else interpret
    return _pa.prefix_attention(q, k, v, prefix_len=prefix_len,
                                window=window, block_q=block_q,
                                block_k=block_k, interpret=interp)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, block_tables, lengths,
                    *, interpret: bool | None = None):
    """Decode attention over paged KV. q: (B, H, hd)."""
    interp = (not _on_tpu()) if interpret is None else interpret
    return _pg.paged_attention(q, k_pages, v_pages, block_tables, lengths,
                               interpret=interp)


def paged_decode_attention(q, k_pages, v_pages, tables, counts, starts, qpos,
                           layer, window, *, logit_cap: float = 0.0,
                           impl: str | None = None):
    """Decode attention straight from the pool's layer-major page arrays
    (the serving runtime's steady-state hot path; see paged_attention.py for
    the run/slot-mapping contract).  Dispatch:

      impl=None        -> compiled Pallas kernel on TPU, pure-jnp per-page
                          online softmax elsewhere (the CPU execution path)
      impl="pallas"    -> force the compiled kernel
      impl="interpret" -> Pallas kernel body in interpret mode (tests: runs
                          the BlockSpec/grid logic bit-for-bit on CPU)
      impl="jnp"       -> force the jnp path

    Not jit-wrapped: this is called per-layer inside the (already jitted)
    decode step's layer scan, where ``layer``/``window`` are traced values.
    """
    if impl is None:
        impl = "pallas" if _on_tpu() else "jnp"
    if impl == "jnp":
        return _pg.paged_decode_jnp(q, k_pages, v_pages, tables, counts,
                                    starts, qpos, layer, window,
                                    logit_cap=logit_cap)
    if impl not in ("pallas", "interpret"):
        raise ValueError(f"unknown paged-attention impl {impl!r}")
    return _pg.paged_decode_attention(q, k_pages, v_pages, tables, counts,
                                      starts, qpos, layer, window,
                                      logit_cap=logit_cap,
                                      interpret=impl == "interpret")
