"""Jit'd public wrappers for the Pallas kernels.

On a TPU runtime these dispatch to the compiled kernels; on CPU (this
container) they run in interpret mode, which executes the kernel body in
Python and validates the BlockSpec/grid logic bit-for-bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import prefix_attention as _pa
from repro.kernels import paged_attention as _pg
from repro.kernels import paged_prefill as _pp


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("prefix_len", "window",
                                             "block_q", "block_k",
                                             "interpret"))
def prefix_attention(q, k, v, *, prefix_len: int, window: int = 0,
                     block_q: int = 128, block_k: int = 128,
                     interpret: bool | None = None):
    """Flash prefill over dense [cached prefix ‖ new] KV (the A/B baseline;
    the paged engine uses ``paged_prefill_attention``). Layouts:
    q: (B, H, Sq, hd); k/v: (B, KV, prefix_len + Sq, hd)."""
    interp = (not _on_tpu()) if interpret is None else interpret
    return _pa.prefix_flash_attention(q, k, v, prefix_len=prefix_len,
                                      window=window, block_q=block_q,
                                      block_k=block_k, interpret=interp)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, block_tables, lengths,
                    *, interpret: bool | None = None):
    """Decode attention over paged KV. q: (B, H, hd)."""
    interp = (not _on_tpu()) if interpret is None else interpret
    return _pg.paged_attention(q, k_pages, v_pages, block_tables, lengths,
                               interpret=interp)


def paged_decode_attention(q, k_pages, v_pages, tables, counts, starts, qpos,
                           layer, window, *, logit_cap: float = 0.0,
                           impl: str | None = None, mesh=None,
                           axis: str = "model"):
    """Decode attention straight from the pool's layer-major page arrays
    (the serving runtime's steady-state hot path; see paged_attention.py for
    the run/slot-mapping contract).  Dispatch:

      impl=None        -> compiled Pallas kernel on TPU, pure-jnp per-page
                          online softmax elsewhere (the CPU execution path)
      impl="pallas"    -> force the compiled kernel
      impl="interpret" -> Pallas kernel body in interpret mode (tests: runs
                          the BlockSpec/grid logic bit-for-bit on CPU)
      impl="jnp"       -> force the jnp path

    Not jit-wrapped: this is called per-layer inside the (already jitted)
    decode step's layer scan, where ``layer``/``window`` are traced values.

    ``mesh``: tensor-parallel serving (serving/runtime.py ``--tp N``).  The
    jnp path ignores it — GSPMD partitions the per-head einsums along the
    sharded KV dim on its own.  The Pallas kernel cannot be auto-partitioned
    (pallas_call is opaque to the SPMD partitioner), so the pallas/interpret
    paths dispatch the kernel PER SHARD via ``shard_map``: each device runs
    the unchanged kernel over its local head tile — q sharded on heads, the
    pool planes on KV heads — with head-local block tables (the run tables
    are head-independent, hence replicated verbatim onto every shard).
    """
    if impl is None:
        impl = "pallas" if _on_tpu() else "jnp"
    if impl == "jnp":
        return _pg.paged_decode_jnp(q, k_pages, v_pages, tables, counts,
                                    starts, qpos, layer, window,
                                    logit_cap=logit_cap)
    if impl not in ("pallas", "interpret"):
        raise ValueError(f"unknown paged-attention impl {impl!r}")
    if mesh is not None and mesh.shape.get(axis, 1) > 1:
        return _paged_decode_sharded(q, k_pages, v_pages, tables, counts,
                                     starts, qpos, layer, window,
                                     logit_cap=logit_cap,
                                     interpret=impl == "interpret",
                                     mesh=mesh, axis=axis)
    return _pg.paged_decode_attention(q, k_pages, v_pages, tables, counts,
                                      starts, qpos, layer, window,
                                      logit_cap=logit_cap,
                                      interpret=impl == "interpret")


def _paged_decode_sharded(q, k_pages, v_pages, tables, counts, starts, qpos,
                          layer, window, *, logit_cap: float, interpret: bool,
                          mesh, axis: str):
    """Per-shard Pallas dispatch: grid shrinks to the shard's H/tp heads and
    the shard's (KV/tp)-head pool plane; no collectives — decode attention
    is embarrassingly parallel over heads (the later wo matmul's all-reduce
    belongs to the surrounding GSPMD program)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def local(q_l, kp_l, vp_l, tb, cn, st, qp, li, w):
        return _pg.paged_decode_attention(q_l, kp_l, vp_l, tb, cn, st, qp,
                                          li, w, logit_cap=logit_cap,
                                          interpret=interpret)

    rep2 = P(None, None)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(None, axis, None),
                  P(None, None, None, axis, None),
                  P(None, None, None, axis, None),
                  rep2, rep2, rep2, P(None), P(), P()),
        out_specs=P(None, axis, None), check_rep=False)
    return fn(q, k_pages, v_pages, tables, counts, starts, qpos,
              jnp.asarray(layer, jnp.int32), jnp.asarray(window, jnp.int32))


def paged_prefill_attention(q, k_pages, v_pages, tables, counts, starts,
                            q_start, q_len, layer, window, *,
                            logit_cap: float = 0.0, impl: str | None = None,
                            mesh=None, axis: str = "model"):
    """Ragged prefill attention straight from the pool's layer-major page
    arrays — the prefill twin of ``paged_decode_attention``, same dispatch
    table (None -> pallas on TPU / jnp on CPU; "pallas" / "interpret" /
    "jnp" to force), same run-table contract, plus the per-request
    ``q_start``/``q_len`` query-row contract (see paged_prefill.py).

    Not jit-wrapped: called per-layer inside the (already jitted) prefill
    step's layer scan, where ``layer``/``window`` are traced values.

    ``mesh``: as for decode — jnp partitions via GSPMD on its own; the
    pallas/interpret paths dispatch the kernel per shard over head-local
    tiles with replicated run tables.
    """
    if impl is None:
        impl = "pallas" if _on_tpu() else "jnp"
    if impl == "jnp":
        return _pp.paged_prefill_jnp(q, k_pages, v_pages, tables, counts,
                                     starts, q_start, q_len, layer, window,
                                     logit_cap=logit_cap)
    if impl not in ("pallas", "interpret"):
        raise ValueError(f"unknown paged-attention impl {impl!r}")
    if mesh is not None and mesh.shape.get(axis, 1) > 1:
        return _paged_prefill_sharded(q, k_pages, v_pages, tables, counts,
                                      starts, q_start, q_len, layer, window,
                                      logit_cap=logit_cap,
                                      interpret=impl == "interpret",
                                      mesh=mesh, axis=axis)
    return _pp.paged_prefill_attention(q, k_pages, v_pages, tables, counts,
                                       starts, q_start, q_len, layer, window,
                                       logit_cap=logit_cap,
                                       interpret=impl == "interpret")


def _paged_prefill_sharded(q, k_pages, v_pages, tables, counts, starts,
                           q_start, q_len, layer, window, *, logit_cap: float,
                           interpret: bool, mesh, axis: str):
    """Per-shard Pallas dispatch for prefill: identical scheme to
    ``_paged_decode_sharded`` with the extra Sq query axis riding along
    unsharded — prefill attention is embarrassingly parallel over heads."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def local(q_l, kp_l, vp_l, tb, cn, st, qs, ql, li, w):
        return _pp.paged_prefill_attention(q_l, kp_l, vp_l, tb, cn, st, qs,
                                           ql, li, w, logit_cap=logit_cap,
                                           interpret=interpret)

    rep2 = P(None, None)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(None, axis, None, None),
                  P(None, None, None, axis, None),
                  P(None, None, None, axis, None),
                  rep2, rep2, rep2, P(None), P(None), P(), P()),
        out_specs=P(None, axis, None, None), check_rep=False)
    return fn(q, k_pages, v_pages, tables, counts, starts, q_start, q_len,
              jnp.asarray(layer, jnp.int32), jnp.asarray(window, jnp.int32))
