"""Paged ragged prefill attention straight from the serving pool
(docs/ARCHITECTURE.md §3) — the prefill twin of ``paged_attention.py``.

A prefill chunk's queries attend over (a) the cached prefix pages already
resident in the ``PagedKVStore``'s layer-major ``(L, n_pages, page, KV, hd)``
planes and (b) the chunk's own new KV, which the model step scatters into its
freshly allocated pages *before* calling attention.  Both live behind the
same run-table slot-mapping contract as paged decode (``tables/counts/
starts``: page ``tables[b, j]`` holds ``counts[b, j]`` consecutive tokens
starting at absolute position ``starts[b, j]``, always from slot 0;
``counts == 0`` marks an unused entry pointed at a scratch page), so cached
document tails ending mid-block need no re-copy — their dead slots mask.

What prefill adds over decode is a *block of query rows per request* instead
of one token: query row ``i`` of request ``b`` sits at absolute position
``q_start[b] + i`` and is valid iff ``i < q_len[b]``.  Invalid rows (ragged
batch padding) are fully masked and produce exact zeros — not NaN, not an
average of garbage pages — which makes a padded batched call row-independent:
each request's outputs are identical whatever else shares the batch, the
property the any-chunk-size token-identity guarantee rests on.

grid = (batch, head, q_block, n_table_slots) with the KV slot innermost:
online-softmax accumulator tiles (block_q, hd) in VMEM scratch, initialized
at slot 0 and finalized at the last slot, exactly the decode kernel's scheme
lifted from one query row to ``block_q``.  GQA rides the index_map
(``h // (H // KV)``); sliding windows and the logit softcap match decode
(cap applied pre-mask, window on absolute positions).

``paged_prefill_jnp`` is the same computation as a per-page gather + online
softmax ``lax.scan`` — the production CPU path, identical masking semantics.
``kernels/ops.py`` dispatches between them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _prefill_kernel(meta_ref, tables_ref, counts_ref, starts_ref, qstart_ref,
                    qlen_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                    l_ref, *, page: int, n_slots: int, block_q: int,
                    scale: float, logit_cap: float):
    b = pl.program_id(0)
    iq = pl.program_id(2)
    ib = pl.program_id(3)

    @pl.when(ib == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (block_q, hd)
    k = k_ref[0, 0, :, 0].astype(jnp.float32)    # (page, hd)
    v = v_ref[0, 0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if logit_cap:
        s = logit_cap * jnp.tanh(s / logit_cap)
    qrow = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, page), 0)
    qpos = qstart_ref[b] + qrow
    slot = jax.lax.broadcasted_iota(jnp.int32, (block_q, page), 1)
    kpos = starts_ref[b, ib] + slot
    live = slot < counts_ref[b, ib]
    live &= kpos <= qpos                         # causal, absolute positions
    live &= qrow < qlen_ref[b]                   # ragged-padding query rows
    win = meta_ref[1]
    live &= jnp.where(win > 0, kpos > qpos - win, True)
    s = jnp.where(live, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    # explicit zeroing of masked probabilities: a fully-masked row (padding,
    # or a valid row whose visible set is still empty) has m_new == NEG_INF
    # and exp(s - m_new) == 1 — without the where it would average garbage
    p = jnp.where(live, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ib == n_slots - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def paged_prefill_attention(
    q: jax.Array,              # (B, H, Sq, hd) — one prefill chunk per row
    k_pages: jax.Array,        # (L, n_pages, page, KV, hd) — the pool arrays
    v_pages: jax.Array,
    tables: jax.Array,         # (B, n_slots) int32 page ids (runs, in order)
    counts: jax.Array,         # (B, n_slots) live tokens per run (0 = unused)
    starts: jax.Array,         # (B, n_slots) absolute position of run start
    q_start: jax.Array,        # (B,) absolute position of query row 0
    q_len: jax.Array,          # (B,) valid query rows (rest are padding)
    layer,                     # int32 scalar — which layer plane to read
    window,                    # int32 scalar — sliding window (0 = global)
    *,
    logit_cap: float = 0.0,
    block_q: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, hd = q.shape
    _, _, page, KV, _ = k_pages.shape
    R = H // KV
    n_slots = tables.shape[1]
    scale = hd ** -0.5

    block_q = min(block_q, max(Sq, 8))
    pad_q = (-Sq) % block_q
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    nq = (Sq + pad_q) // block_q

    meta = jnp.stack([jnp.asarray(layer, jnp.int32),
                      jnp.asarray(window, jnp.int32)])
    kernel = functools.partial(_prefill_kernel, page=page, n_slots=n_slots,
                               block_q=block_q, scale=scale,
                               logit_cap=logit_cap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,    # meta, tables, counts, starts, q_start, q_len
        grid=(B, H, nq, n_slots),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, iq, ib, meta, tbl, cnt, st, qs, ql:
                         (b, h, iq, 0)),
            pl.BlockSpec((1, 1, page, 1, hd),
                         lambda b, h, iq, ib, meta, tbl, cnt, st, qs, ql:
                         (meta[0], tbl[b, ib], 0, h // R, 0)),
            pl.BlockSpec((1, 1, page, 1, hd),
                         lambda b, h, iq, ib, meta, tbl, cnt, st, qs, ql:
                         (meta[0], tbl[b, ib], 0, h // R, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, iq, ib, meta, tbl, cnt, st, qs, ql:
                               (b, h, iq, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, Sq + pad_q, hd), q.dtype),
        interpret=interpret,
    )(meta, tables, counts, starts, q_start, q_len, q, k_pages, v_pages)
    return out[:, :, :Sq]


def paged_prefill_jnp(
    q: jax.Array,              # (B, H, Sq, hd)
    k_pages: jax.Array,        # (L, n_pages, page, KV, hd)
    v_pages: jax.Array,
    tables: jax.Array,         # (B, n_slots)
    counts: jax.Array,
    starts: jax.Array,
    q_start: jax.Array,        # (B,)
    q_len: jax.Array,          # (B,)
    layer,
    window,
    *,
    logit_cap: float = 0.0,
) -> jax.Array:
    """Per-page gather + online softmax, pure jnp (the CPU execution path).

    Peak live memory per step is one (B, page, KV, hd) KV tile plus the
    (B, H, Sq, page) score tile — never the dense (B, S, KV, hd) context,
    let alone all L layers of it.
    """
    B, H, Sq, hd = q.shape
    page, KV = k_pages.shape[2], k_pages.shape[3]
    R = H // KV
    scale = hd ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, KV, R, Sq, hd)
    n_slots = tables.shape[1]
    win = jnp.asarray(window, jnp.int32)
    slot = jnp.arange(page, dtype=jnp.int32)
    qrow = jnp.arange(Sq, dtype=jnp.int32)
    qpos = q_start[:, None] + qrow[None]                   # (B, Sq)
    qvalid = qrow[None] < q_len[:, None]                   # (B, Sq)

    def body(carry, j):
        m, l, acc = carry
        pid = tables[:, j]                                 # (B,)
        k = k_pages[layer, pid].astype(jnp.float32)        # (B, page, KV, hd)
        v = v_pages[layer, pid].astype(jnp.float32)
        s = jnp.einsum("bgrqd,bpgd->bgrqp", qf, k)
        if logit_cap:
            s = logit_cap * jnp.tanh(s / logit_cap)
        kpos = starts[:, j, None] + slot[None]             # (B, page)
        live = slot[None] < counts[:, j, None]             # (B, page)
        mask = live[:, None] & (kpos[:, None] <= qpos[..., None])
        mask &= qvalid[..., None]                          # (B, Sq, page)
        mask &= jnp.where(win > 0, kpos[:, None] > qpos[..., None] - win,
                          True)
        mb = mask[:, None, None]                           # (B,1,1,Sq,page)
        s = jnp.where(mb, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.where(mb, jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bgrqp,bpgd->bgrqd", p, v)
        return (m_new, l, acc), None

    init = (jnp.full((B, KV, R, Sq), NEG_INF, jnp.float32),
            jnp.zeros((B, KV, R, Sq), jnp.float32),
            jnp.zeros((B, KV, R, Sq, hd), jnp.float32))
    (m, l, acc), _ = lax.scan(body, init, jnp.arange(n_slots))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, H, Sq, hd).astype(q.dtype)
