"""Pure-jnp oracles for the Pallas kernels (tests assert_allclose vs these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def reference_prefix_attention(q, k, v, *, prefix_len: int, window: int = 0,
                               logit_cap: float = 0.0):
    """q: (B, H, Sq, hd); k/v: (B, KV, Skv, hd) with Skv = prefix_len + Sq."""
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    R = H // KV
    kf = jnp.repeat(k, R, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, R, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf) * hd ** -0.5
    if logit_cap:
        s = logit_cap * jnp.tanh(s / logit_cap)
    q_pos = prefix_len + jnp.arange(Sq)
    k_pos = jnp.arange(Skv)
    mask = k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)


def reference_paged_prefill(q, k_pages, v_pages, tables, counts, starts,
                            q_start, q_len, layer, window=0, logit_cap=0.0):
    """Dense oracle for the layer-major paged prefill kernel.

    q: (B, H, Sq, hd); k/v_pages: (L, n_pages, page, KV, hd); tables/counts/
    starts: (B, n_slots) run descriptors (see paged_attention.py docstring);
    q_start: (B,) absolute position of query row 0; q_len: (B,) valid query
    rows — invalid (ragged-padding) rows return exact zeros.
    """
    B, H, Sq, hd = q.shape
    page, KV = k_pages.shape[2], k_pages.shape[3]
    R = H // KV
    nb = tables.shape[1]
    k = k_pages[layer][tables]           # (B, nb, page, KV, hd)
    v = v_pages[layer][tables]
    k = k.reshape(B, nb * page, KV, hd)
    v = v.reshape(B, nb * page, KV, hd)
    kf = jnp.repeat(k, R, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v, R, axis=2).astype(jnp.float32)
    s = jnp.einsum("bhqd,bkhd->bhqk", q.astype(jnp.float32), kf) * hd ** -0.5
    if logit_cap:
        s = logit_cap * jnp.tanh(s / logit_cap)
    slot = jnp.arange(page)
    live = slot[None, None] < counts[..., None]              # (B, nb, page)
    kpos = starts[..., None] + slot[None, None]
    live = live.reshape(B, nb * page)
    kpos = kpos.reshape(B, nb * page)
    qpos = q_start[:, None] + jnp.arange(Sq)[None]           # (B, Sq)
    mask = live[:, None] & (kpos[:, None] <= qpos[..., None])
    mask &= (jnp.arange(Sq)[None] < q_len[:, None])[..., None]
    if window:
        mask &= kpos[:, None] > qpos[..., None] - window
    s = jnp.where(mask[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[:, None], p, 0.0)     # all-masked row -> 0, not NaN
    return jnp.einsum("bhqk,bkhd->bhqd", p, vf).astype(q.dtype)


def reference_paged_decode(q, k_pages, v_pages, tables, counts, starts, qpos,
                           layer, window=0, logit_cap=0.0):
    """Dense oracle for the layer-major paged decode kernel.

    q: (B, H, hd); k/v_pages: (L, n_pages, page, KV, hd); tables/counts/
    starts: (B, n_slots) run descriptors (see paged_attention.py docstring);
    qpos: (B,) absolute query position; layer selects the page plane.
    """
    B, H, hd = q.shape
    page, KV = k_pages.shape[2], k_pages.shape[3]
    R = H // KV
    nb = tables.shape[1]
    k = k_pages[layer][tables]           # (B, nb, page, KV, hd)
    v = v_pages[layer][tables]
    k = k.reshape(B, nb * page, KV, hd)
    v = v.reshape(B, nb * page, KV, hd)
    kf = jnp.repeat(k, R, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v, R, axis=2).astype(jnp.float32)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32), kf) * hd ** -0.5
    if logit_cap:
        s = logit_cap * jnp.tanh(s / logit_cap)
    slot = jnp.arange(page)
    live = slot[None, None] < counts[..., None]              # (B, nb, page)
    pos = starts[..., None] + slot[None, None]
    if window:
        live &= pos > qpos[:, None, None] - window
    live = live.reshape(B, nb * page)
    s = jnp.where(live[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(live[:, None], p, 0.0)     # all-masked row -> 0, not NaN/avg
    return jnp.einsum("bhk,bkhd->bhd", p, vf).astype(q.dtype)


def reference_paged_attention(q, k_pages, v_pages, block_tables, lengths):
    """q: (B, H, hd); k/v_pages: (n_pages, page, KV, hd);
    block_tables: (B, n_blocks_max) int32; lengths: (B,) valid tokens."""
    B, H, hd = q.shape
    n_pages, page, KV, _ = k_pages.shape
    R = H // KV
    nb = block_tables.shape[1]
    # gather per-request contiguous KV
    k = k_pages[block_tables]            # (B, nb, page, KV, hd)
    v = v_pages[block_tables]
    k = k.reshape(B, nb * page, KV, hd)
    v = v.reshape(B, nb * page, KV, hd)
    kf = jnp.repeat(k, R, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v, R, axis=2).astype(jnp.float32)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32), kf) * hd ** -0.5
    mask = jnp.arange(nb * page)[None] < lengths[:, None]
    s = jnp.where(mask[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bkhd->bhd", p, vf).astype(q.dtype)
