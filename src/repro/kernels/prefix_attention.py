"""Pallas TPU kernel: prefix-cached prefill attention over dense [prefix ‖ new].

This was the TPU-native replacement for RAGCache's Triton prefill-kernel
extension of vLLM (paper §6): queries of the *new* tokens (question + fresh
documents) attend over the concatenation [cached document KV ‖ new KV].
Since the paged ragged prefill kernel (``paged_prefill.py``) landed, the
serving runtime no longer gathers that dense concatenation — this kernel
remains as the dense A/B baseline (``--attn dense``) and a parity oracle.

Design (docs/ARCHITECTURE.md §3, hardware adaptation):
  * grid = (batch, q_head, q_blocks, kv_blocks), kv innermost; the online-
    softmax accumulator lives in VMEM scratch and is finalized on the last
    kv step (flash-attention schedule, one output write per q block);
  * BlockSpec tiles are MXU-aligned (block_q x head_dim and block_k x
    head_dim, multiples of 128 at production sizes);
  * GQA is native: the kv-head index in the BlockSpec index_map is
    ``h // (H // KV)`` — the repeated KV stream is never materialized;
  * causal masking applies only past the prefix boundary: every kv position
    < prefix_len is unmasked by construction (q positions start at
    prefix_len), so a kv block that is *entirely* at-or-before the q block's
    first position — the whole cached prefix, plus the already-seen bulk of
    the new tokens — takes a ``pl.when`` fast path that skips mask
    construction; only diagonal blocks (and window-edge blocks) pay for the
    iota/compare/select.  The two branches are bitwise-equivalent on full
    blocks (a mask of all-True selects ``s`` unchanged), pinned by
    ``tests/test_paged_prefill.py``.

Validated against ``ref.reference_prefix_attention`` in interpret mode
(CPU); compiled path targets TPU v5e.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _accumulate(s, v, acc_ref, m_ref, l_ref):
    """One online-softmax update of the VMEM accumulator with scores ``s``."""
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            q_offset: int, block_q: int, block_k: int, n_kv_blocks: int,
            window: int, scale: float):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (block_q, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (block_k, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale      # (bq, bk)

    iq = pl.program_id(2)
    # a kv block is mask-free iff its LAST position is causally visible to
    # the q block's FIRST row (which also bounds it inside the un-padded kv
    # range: q_offset + iq*block_q <= Skv - 1) and, under a sliding window,
    # its FIRST position is inside the window of the q block's LAST row
    full = (ik + 1) * block_k - 1 <= q_offset + iq * block_q
    if window > 0:
        full &= ik * block_k > q_offset + iq * block_q + block_q - 1 - window

    @pl.when(full)
    def _unmasked():
        # prefix fast path: no iota, no compare, no select
        _accumulate(s, v, acc_ref, m_ref, l_ref)

    @pl.when(jnp.logical_not(full))
    def _masked():
        q_pos = q_offset + iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        _accumulate(jnp.where(mask, s, NEG_INF), v, acc_ref, m_ref, l_ref)

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def prefix_flash_attention(
    q: jax.Array,              # (B, H, Sq, hd)  — new tokens
    k: jax.Array,              # (B, KV, Skv, hd) — [prefix ‖ new] keys
    v: jax.Array,              # (B, KV, Skv, hd)
    *,
    prefix_len: int,           # == Skv - Sq; q[i] sits at prefix_len + i
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    R = H // KV
    assert Skv == prefix_len + Sq, (Skv, prefix_len, Sq)
    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Skv, 8))

    pad_q = (-Sq) % block_q
    pad_k = (-Skv) % block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    # padded kv columns must never win the max: they sit past every valid
    # q position, so causal masking kills them — and the fast path never
    # fires on a block containing them (its predicate bounds the block's
    # last position by a valid q position < Skv)
    nq = qp.shape[2] // block_q
    nk = kp.shape[2] // block_k

    kernel = functools.partial(
        _kernel, q_offset=prefix_len, block_q=block_q, block_k=block_k,
        n_kv_blocks=nk, window=window, scale=hd ** -0.5)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik: (b, h // R, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik: (b, h // R, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * block_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),   # online-softmax acc
            pltpu.VMEM((block_q,), jnp.float32),      # running max
            pltpu.VMEM((block_q,), jnp.float32),      # running denominator
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :Sq]


def prefix_attention(q, k, v, *, prefix_len: int, window: int = 0,
                     block_q: int = 128, block_k: int = 128,
                     interpret: bool = False) -> jax.Array:
    """Deprecated name — use :func:`prefix_flash_attention` (same signature,
    same semantics).  Kept as a thin forwarder so external callers of the
    pre-PR-8 API keep working; scheduled for removal once the dense A/B
    baseline goes."""
    return prefix_flash_attention(q, k, v, prefix_len=prefix_len,
                                  window=window, block_q=block_q,
                                  block_k=block_k, interpret=interpret)
