"""Paged decode attention straight from the serving pool (docs/ARCHITECTURE.md §3).

vLLM's PagedAttention reads KV from non-contiguous pages via per-SM gathers;
the TPU-native adaptation prefetches the request's block table into SMEM
(``PrefetchScalarGridSpec``) so the page index feeds the BlockSpec index_map,
and the DMA engine streams one (page x hd) KV tile HBM->VMEM per grid step
while the VPU/MXU consumes the previous one.

The kernel operates on the ``PagedKVStore``'s own layer-major layout —
``k_pages/v_pages: (L, n_pages, page, KV, hd)`` — selecting the layer through
a prefetched scalar, so the serving runtime's decode step attends IN PLACE:
no per-iteration dense re-materialization of the cached context.

Token-level slot-mapping contract (what PR 4's unaligned sharing produces,
see ``serving/runtime.py::_paginate``): a request's sequence is a list of
*runs*, one per table entry ``j`` — page ``tables[b, j]`` holds the
``counts[b, j]`` consecutive tokens starting at absolute position
``starts[b, j]``, always beginning at slot 0.  A shared knowledge-tree
segment whose document ends mid-block therefore contributes a tail run with
``counts < page``; the dead tail slots are masked, and the next document's
run starts in a fresh page.  ``counts[b, j] == 0`` marks an unused table
entry (its DMA still streams page ``tables[b, j]`` — point padding entries
at a valid scratch page).

grid = (batch, head, n_table_slots); online-softmax accumulator in VMEM
scratch, finalized at the last table slot.  GQA rides the index_map
(``h // (H // KV)``) so the repeated KV stream never materializes.  A row
whose runs are ALL empty (a padding decode slot) produces a zero output
vector rather than NaN.

``paged_decode_jnp`` is the same computation as a pure-jnp per-page gather +
online softmax ``lax.scan`` — the production CPU path (interpret-mode Pallas
is a correctness tool, not an execution engine), with identical masking
semantics.  ``kernels/ops.py`` dispatches between them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(meta_ref, tables_ref, counts_ref, starts_ref, qpos_ref,
                   q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                   page: int, n_slots: int, scale: float, logit_cap: float):
    b = pl.program_id(0)
    ib = pl.program_id(2)

    @pl.when(ib == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (1, hd) — one token
    k = k_ref[0, 0, :, 0].astype(jnp.float32)    # (page, hd)
    v = v_ref[0, 0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if logit_cap:
        s = logit_cap * jnp.tanh(s / logit_cap)
    slot = jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    live = slot < counts_ref[b, ib]
    win = meta_ref[1]
    pos = starts_ref[b, ib] + slot
    live &= jnp.where(win > 0, pos > qpos_ref[b] - win, True)
    s = jnp.where(live, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    # explicit zeroing of masked probabilities: when every slot so far is
    # masked, m_new == NEG_INF and exp(s - m_new) == 1 — without the where a
    # length-0 row would average the garbage pages instead of returning 0
    p = jnp.where(live, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ib == n_slots - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def paged_decode_attention(
    q: jax.Array,              # (B, H, hd) — one decode token per sequence
    k_pages: jax.Array,        # (L, n_pages, page, KV, hd) — the pool arrays
    v_pages: jax.Array,
    tables: jax.Array,         # (B, n_slots) int32 page ids (runs, in order)
    counts: jax.Array,         # (B, n_slots) live tokens per run (0 = unused)
    starts: jax.Array,         # (B, n_slots) absolute position of run start
    qpos: jax.Array,           # (B,) absolute position of the query token
    layer,                     # int32 scalar — which layer plane to read
    window,                    # int32 scalar — sliding window (0 = global)
    *,
    logit_cap: float = 0.0,
    interpret: bool = False,
) -> jax.Array:
    B, H, hd = q.shape
    _, _, page, KV, _ = k_pages.shape
    R = H // KV
    n_slots = tables.shape[1]
    scale = hd ** -0.5

    meta = jnp.stack([jnp.asarray(layer, jnp.int32),
                      jnp.asarray(window, jnp.int32)])
    kernel = functools.partial(_decode_kernel, page=page, n_slots=n_slots,
                               scale=scale, logit_cap=logit_cap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,    # meta, tables, counts, starts, qpos
        grid=(B, H, n_slots),
        in_specs=[
            pl.BlockSpec((1, 1, 1, hd),
                         lambda b, h, ib, meta, tbl, cnt, st, qp:
                         (b, h, 0, 0)),
            pl.BlockSpec((1, 1, page, 1, hd),
                         lambda b, h, ib, meta, tbl, cnt, st, qp:
                         (meta[0], tbl[b, ib], 0, h // R, 0)),
            pl.BlockSpec((1, 1, page, 1, hd),
                         lambda b, h, ib, meta, tbl, cnt, st, qp:
                         (meta[0], tbl[b, ib], 0, h // R, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd),
                               lambda b, h, ib, meta, tbl, cnt, st, qp:
                               (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, 1, hd), q.dtype),
        interpret=interpret,
    )(meta, tables, counts, starts, qpos, q[:, :, None], k_pages, v_pages)
    return out[:, :, 0]


def paged_decode_jnp(
    q: jax.Array,              # (B, H, hd)
    k_pages: jax.Array,        # (L, n_pages, page, KV, hd)
    v_pages: jax.Array,
    tables: jax.Array,         # (B, n_slots)
    counts: jax.Array,
    starts: jax.Array,
    qpos: jax.Array,           # (B,)
    layer,
    window,
    *,
    logit_cap: float = 0.0,
) -> jax.Array:
    """Per-page gather + online softmax, pure jnp (the CPU execution path).

    Peak live memory per step is one (B, page, KV, hd) tile — never the
    dense (B, S, KV, hd) context, let alone all L layers of it.
    """
    B, H, hd = q.shape
    page, KV = k_pages.shape[2], k_pages.shape[3]
    R = H // KV
    scale = hd ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, KV, R, hd)
    n_slots = tables.shape[1]
    win = jnp.asarray(window, jnp.int32)
    slot = jnp.arange(page, dtype=jnp.int32)

    def body(carry, j):
        m, l, acc = carry
        pid = tables[:, j]                                 # (B,)
        k = k_pages[layer, pid].astype(jnp.float32)        # (B, page, KV, hd)
        v = v_pages[layer, pid].astype(jnp.float32)
        s = jnp.einsum("bgrd,bpgd->bgrp", qf, k)
        if logit_cap:
            s = logit_cap * jnp.tanh(s / logit_cap)
        live = slot[None, :] < counts[:, j, None]          # (B, page)
        pos = starts[:, j, None] + slot[None, :]
        live &= jnp.where(win > 0, pos > qpos[:, None] - win, True)
        lb = live[:, None, None, :]
        s = jnp.where(lb, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.where(lb, jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bgrp,bpgd->bgrd", p, v)
        return (m_new, l, acc), None

    init = (jnp.full((B, KV, R), NEG_INF, jnp.float32),
            jnp.zeros((B, KV, R), jnp.float32),
            jnp.zeros((B, KV, R, hd), jnp.float32))
    (m, l, acc), _ = lax.scan(body, init, jnp.arange(n_slots))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, H, hd).astype(q.dtype)


def paged_attention(
    q: jax.Array,              # (B, H, hd)
    k_pages: jax.Array,        # (n_pages, page, KV, hd) — single-layer view
    v_pages: jax.Array,
    block_tables: jax.Array,   # (B, n_slots) int32 page ids
    lengths: jax.Array,        # (B,) valid token counts
    *,
    interpret: bool = False,
) -> jax.Array:
    """Single-layer convenience wrapper over the layer-major kernel:
    contiguous semantics (page ``j`` holds positions ``[j*page, ...)`` up to
    ``lengths[b]``), kept for the kernel parity sweep and benches."""
    page = k_pages.shape[1]
    n_slots = block_tables.shape[1]
    off = jnp.arange(n_slots, dtype=jnp.int32)[None] * page      # (1, n_slots)
    counts = jnp.clip(lengths[:, None] - off, 0, page).astype(jnp.int32)
    starts = jnp.broadcast_to(off, block_tables.shape).astype(jnp.int32)
    return paged_decode_attention(
        q, k_pages[None], v_pages[None], block_tables, counts, starts,
        jnp.maximum(lengths - 1, 0).astype(jnp.int32),
        jnp.int32(0), jnp.int32(0), interpret=interpret)
