"""Pallas TPU kernel: paged decode attention.

vLLM's PagedAttention reads KV from non-contiguous pages via per-SM gathers;
the TPU-native adaptation (docs/ARCHITECTURE.md §3) prefetches the block table into
SMEM (``PrefetchScalarGridSpec``) so the page index feeds the BlockSpec
index_map, and the DMA engine streams one (page x hd) KV tile HBM->VMEM per
grid step while the VPU/MXU consumes the previous one.

grid = (batch, head, n_page_slots); online-softmax accumulator in VMEM
scratch, finalized at the last page slot.  Pages past ``lengths[b]`` are
masked (and their DMA is index-clamped to page 0 — harmless, masked out).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, page: int, n_slots: int, scale: float):
    b = pl.program_id(0)
    ib = pl.program_id(2)

    @pl.when(ib == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (1, hd) — one token
    k = k_ref[0, :, 0].astype(jnp.float32)       # (page, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = ib * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    s = jnp.where(pos < lengths_ref[b], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ib == n_slots - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def paged_attention(
    q: jax.Array,              # (B, H, hd) — one decode token per sequence
    k_pages: jax.Array,        # (n_pages, page, KV, hd)
    v_pages: jax.Array,
    block_tables: jax.Array,   # (B, n_slots) int32 page ids
    lengths: jax.Array,        # (B,) valid token counts
    *,
    interpret: bool = False,
) -> jax.Array:
    B, H, hd = q.shape
    n_pages, page, KV, _ = k_pages.shape
    R = H // KV
    n_slots = block_tables.shape[1]
    scale = hd ** -0.5

    kernel = functools.partial(_kernel, page=page, n_slots=n_slots,
                               scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,        # block_tables, lengths
        grid=(B, H, n_slots),
        in_specs=[
            pl.BlockSpec((1, 1, 1, hd),
                         lambda b, h, ib, tables, lengths: (b, h, 0, 0)),
            pl.BlockSpec((1, page, 1, hd),
                         lambda b, h, ib, tables, lengths:
                         (tables[b, ib], 0, h // R, 0)),
            pl.BlockSpec((1, page, 1, hd),
                         lambda b, h, ib, tables, lengths:
                         (tables[b, ib], 0, h // R, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd),
                               lambda b, h, ib, tables, lengths: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, 1, hd), q.dtype),
        interpret=interpret,
    )(block_tables, lengths, q[:, :, None], k_pages, v_pages)
    return out[:, :, 0]
