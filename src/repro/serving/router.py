"""Doc-affinity request routing over N engine replicas.

RAGCache's knowledge tree only pays off when a request lands on a replica
where its document prefix is already resident: chunk-level KV reuse
collapses when requests scatter across workers (Cache-Craft, arXiv
2502.15734), and the placement of retrieval state is a first-order
RAG-serving trade-off (arXiv 2412.11854).  ``ReplicaRouter`` therefore
fronts N *independent* engine replicas — each with its own
``KnowledgeTree``, ``PagedKVStore``, scheduler and three-tier cache; trees
NEVER share state across replicas, so there is no cross-replica
coherence/invalidation protocol to get wrong and a replica loss costs only
recompute — and routes each request by doc affinity:

  1. **Prefix overlap** — score every replica by the token length of the
     longest cached prefix of the request's retrieved doc-ID sequence,
     matched against both the replica's live tree (``tree.match_prefix``)
     and the router's shadow ledger of paths it already routed there
     (in-flight requests have not committed yet, but their KV is coming —
     ignoring them would scatter a burst for one document across replicas).
  2. **Affinity hash** — ties and cold paths fall back to a stable FNV-1a
     hash of the highest-order (leading) retrieved doc IDs, so the same
     document set keeps landing on the same replica before any cache state
     exists.  Fully cold decisions (no docs at all) go to the least-loaded
     replica.
  3. **Escape hatch** — affinity must not melt one replica: if routing to
     the affinity choice would push its queue depth more than
     ``max_queue_skew`` above the least-loaded replica, the request escapes
     to a least-loaded replica instead — preferring one that already holds
     part of the path — bounding routing-induced queue skew at the cost of
     at most one extra prefill of the path there.
  4. **Admission consult** — the router checks the chosen replica's
     ``PagedAdmission`` (when it exposes one) before dispatch and falls
     through to the next-least-loaded admissible replica; if *no* replica
     can admit, the decision comes back ``admitted=False`` and the caller
     queues the request — the router never admits past a replica's pin
     budget.

Routing never changes computation: a request's greedy tokens are a pure
function of (docs, question) regardless of which replica serves it or what
its cache holds, so ``--check-tokens`` stays bit-identical to the single
sequential engine at any replica count.

The router is an engine-agnostic policy object, shared the same way the
``ContinuousBatchScheduler`` is: ``launch/serve.py`` drives it over real
``ContinuousRuntime`` replicas and ``serving/simulator.py``
(``simulate_replicas``) drives the identical object over ``RAGSimulator``
replicas, so simulated and real routing cannot drift.  A replica handle is
any object; ``tree`` and ``admission`` attributes are consulted when
present (docs/ARCHITECTURE.md §8).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.config import FleetConfig, reject_legacy_kwargs

AFFINITY = "affinity"
ROUND_ROBIN = "round_robin"
LEAST_LOADED = "least_loaded"
ROUTING_POLICIES = (AFFINITY, ROUND_ROBIN, LEAST_LOADED)

# decision kinds (RouteDecision.kind) — why a request landed where it did
KIND_AFFINITY = "affinity"        # prefix overlap won
KIND_HASH = "hash"                # cold path, affinity hash of the doc IDs
KIND_ESCAPE = "escape"            # load-imbalance escape hatch fired
KIND_ADMISSION = "admission"      # preferred replica could not admit
KIND_COLD = "cold"                # no docs: least-loaded fallback
KIND_POLICY = "policy"            # non-affinity baseline policy pick


def stable_doc_hash(doc_ids: Sequence[int]) -> int:
    """FNV-1a over the doc-ID sequence: deterministic across processes and
    runs (unlike salted ``hash``), so replica placement is reproducible."""
    h = 0xcbf29ce484222325
    for d in doc_ids:
        h ^= (int(d) + 1) & 0xffffffffffffffff
        h = (h * 0x100000001b3) & 0xffffffffffffffff
    return h


@dataclasses.dataclass
class RouteDecision:
    index: int                     # chosen replica
    replica: object
    kind: str                      # KIND_* above
    admitted: bool                 # False: no replica could admit (caller
    #                                queues; router state NOT charged)
    overlap_tokens: int = 0        # prefix-overlap score of the chosen replica


@dataclasses.dataclass
class _ShadowNode:
    refs: int = 0                  # registered paths passing through here
    children: Dict[int, "_ShadowNode"] = dataclasses.field(
        default_factory=dict)


class ReplicaRouter:
    """Routes requests over independent replicas; see module docstring.

    replicas: handles of any type.  ``handle.tree`` (a ``KnowledgeTree``)
    and ``handle.admission`` (a ``PagedAdmission``) are consulted when
    present, so ``ContinuousRuntime``, ``RAGSimulator`` and bare mock
    objects all work unchanged.
    """

    def __init__(self, replicas: Sequence[object], *,
                 config=None, **legacy):
        # ``config=FleetConfig(...)`` is the SOLE constructor API: the
        # replica *count* stays the caller's job (it owns the engine list);
        # the router takes policy / max_queue_skew / max_shadow_paths from
        # the config.  Pre-PR 7 loose kwargs raise a TypeError naming the
        # FleetConfig field that replaced them.
        reject_legacy_kwargs("ReplicaRouter", legacy, FleetConfig,
                             aliases={"policy": "routing"})
        config = config if config is not None else FleetConfig()
        policy = config.routing
        max_queue_skew = config.max_queue_skew
        max_shadow_paths = config.max_shadow_paths
        if not replicas:
            raise ValueError("router needs at least one replica")
        if policy not in ROUTING_POLICIES:
            raise ValueError(f"unknown routing policy {policy!r}; "
                             f"expected one of {ROUTING_POLICIES}")
        if max_queue_skew < 1:
            raise ValueError("max_queue_skew must be >= 1")
        self.replicas = list(replicas)
        self.policy = policy
        self.max_queue_skew = max_queue_skew
        self.max_shadow_paths = max_shadow_paths
        n = len(self.replicas)
        # ACTIVE set: the front-door autoscaler (serving/frontdoor.py) may
        # restrict routing to replicas[:active]; inactive replicas keep
        # their trees/shadow state warm and still drain in-flight work, but
        # receive no new dispatches until reactivated.
        self.active = n
        self.depth = [0] * n           # in-flight (routed - completed)
        self.routed = [0] * n          # total dispatched per replica
        self.kind_counts: Dict[str, int] = {}
        self.max_skew_observed = 0
        self._rr_next = 0
        self._shadow = [_ShadowNode() for _ in range(n)]
        # FIFO of registered (replica, path) for bounded shadow size: the
        # ledger is a routing hint, not ground truth (the live tree is),
        # so aging out the oldest paths merely degrades a cold decision
        self._shadow_fifo: List[Tuple[int, Tuple[int, ...]]] = []

    # ---- introspection ----------------------------------------------------

    def __len__(self) -> int:
        return len(self.replicas)

    def set_active(self, n: int) -> None:
        """Restrict routing to ``replicas[:n]`` (autoscaler hook).  Shrinking
        never touches a replica's state — its tree stays warm for the next
        scale-up and its in-flight requests drain normally."""
        if not 1 <= n <= len(self.replicas):
            raise ValueError(
                f"active count {n} outside [1, {len(self.replicas)}]")
        self.active = n

    def skew(self) -> int:
        d = self.depth[:self.active]
        return max(d) - min(d)

    @property
    def escaped(self) -> int:
        return self.kind_counts.get(KIND_ESCAPE, 0)

    def stats(self) -> Dict[str, object]:
        return {
            "policy": self.policy,
            "active": self.active,
            "routed": list(self.routed),
            "depth": list(self.depth),
            "kind_counts": dict(self.kind_counts),
            "escaped": self.escaped,
            "max_skew_observed": self.max_skew_observed,
            "max_queue_skew": self.max_queue_skew,
        }

    # ---- scoring ----------------------------------------------------------

    def _overlap(self, i: int, docs: Sequence[int],
                 doc_tokens: Sequence[int]) -> int:
        """Prefix-overlap score (tokens) of replica ``i`` for ``docs``: the
        longer of the live-tree match and the shadow-ledger match.  Both are
        prefix matches, so the max is the honest "KV that is or will be
        resident there" estimate."""
        live = 0
        tree = getattr(self.replicas[i], "tree", None)
        if tree is not None:
            live = sum(n.n_tokens for n in tree.match_prefix(docs))
        shadow = 0
        node = self._shadow[i]
        for d, t in zip(docs, doc_tokens):
            node = node.children.get(int(d))
            if node is None:
                break
            shadow += t
        return max(live, shadow)

    def _register(self, i: int, docs: Tuple[int, ...]) -> None:
        node = self._shadow[i]
        for d in docs:
            node = node.children.setdefault(int(d), _ShadowNode())
            node.refs += 1
        self._shadow_fifo.append((i, docs))
        if len(self._shadow_fifo) > self.max_shadow_paths:
            j, old = self._shadow_fifo.pop(0)
            self._unregister(j, old)

    def _unregister(self, i: int, docs: Tuple[int, ...]) -> None:
        node = self._shadow[i]
        for d in docs:
            child = node.children[int(d)]
            child.refs -= 1
            if child.refs == 0:
                del node.children[int(d)]
                return                 # descendants die with it (refs were
                                       # contributed by this path alone)
            node = child

    def _least_loaded(self) -> int:
        return min(range(self.active), key=lambda i: (self.depth[i], i))

    # ---- the decision -----------------------------------------------------

    def route(self, docs: Sequence[int],
              doc_tokens: Optional[Sequence[int]] = None,
              *, context_tokens: int = 0) -> RouteDecision:
        """Pick a replica for a request retrieving ``docs``.

        doc_tokens: per-doc token counts (defaults to 1 each — affinity
        still works, scores are just doc counts).  context_tokens: the
        full sequence (docs + question) the request will hold; when > 0
        and a candidate replica exposes an ``admission``, the router
        derives that replica's beta/promote tokens from ITS OWN tree
        (cached prefix shrinks beta; cold-tier hits count as promote,
        exactly like the runtime's ``_job_ctx_beta``) and consults the
        budget before dispatching.  Leave it 0 to skip budget enforcement
        (e.g. simulator replicas are unbounded).
        """
        docs = tuple(int(d) for d in docs)
        if doc_tokens is None:
            doc_tokens = (1,) * len(docs)
        chosen, kind, overlap = self._prefer(docs, doc_tokens)
        # load-imbalance escape hatch: bound max/min queue skew.  Among the
        # least-loaded replicas, prefer one that already holds (or was
        # already routed) part of this doc path — once a hot document has
        # been replicated by an earlier escape, later escapes ride the
        # existing copy instead of paying a third cold prefill.
        if self.policy == AFFINITY and docs:
            floor = min(self.depth[:self.active])
            if self.depth[chosen] + 1 - floor > self.max_queue_skew:
                cands = [i for i in range(self.active)
                         if self.depth[i] == floor]
                chosen = max(cands,
                             key=lambda i: (self._overlap(i, docs,
                                                          doc_tokens), -i))
                kind = KIND_ESCAPE
                overlap = self._overlap(chosen, docs, doc_tokens)
        # admission consult: chosen first, then the others least-loaded
        # first; a replica without an admission attribute is unbounded
        order = [chosen] + sorted(
            (i for i in range(self.active) if i != chosen),
            key=lambda i: (self.depth[i], i))
        for i in order:
            if self._admissible(i, docs, context_tokens):
                if i != chosen:
                    kind = KIND_ADMISSION
                    overlap = self._overlap(i, docs, doc_tokens)
                return self._commit(i, kind, docs, overlap)
        # nobody can admit: the caller must queue and retry — charging
        # router state now would skew load accounting for ghost requests
        return RouteDecision(index=chosen, replica=self.replicas[chosen],
                             kind=kind, admitted=False,
                             overlap_tokens=overlap)

    def _prefer(self, docs: Tuple[int, ...],
                doc_tokens: Sequence[int]) -> Tuple[int, str, int]:
        n = self.active
        if self.policy == ROUND_ROBIN:
            i = self._rr_next % n
            self._rr_next += 1
            return i, KIND_POLICY, 0
        if self.policy == LEAST_LOADED:
            return self._least_loaded(), KIND_POLICY, 0
        if not docs:
            return self._least_loaded(), KIND_COLD, 0
        home = stable_doc_hash(docs) % n
        scores = [self._overlap(i, docs, doc_tokens) for i in range(n)]
        best = max(scores)
        if best > 0:
            cands = [i for i, s in enumerate(scores) if s == best]
            chosen = home if home in cands else cands[0]
            return chosen, KIND_AFFINITY, best
        return home, KIND_HASH, 0

    def _admissible(self, i: int, docs: Tuple[int, ...], ctx: int) -> bool:
        """Consult replica ``i``'s admission for a ``ctx``-token request:
        beta (to-compute) and promote (cold-tier hit) tokens are derived
        from THIS replica's live tree, mirroring the engine's own
        ``_job_ctx_beta`` — the same docs cost different budgets on a
        replica that already caches their prefix."""
        adm = getattr(self.replicas[i], "admission", None)
        if adm is None or ctx <= 0:
            return True
        cached = promote = 0
        tree = getattr(self.replicas[i], "tree", None)
        if tree is not None:
            hit = tree.match_prefix(docs)
            cached = sum(n.n_tokens for n in hit)
            promote = sum(n.n_tokens for n in hit if not n.in_gpu)
        if hasattr(adm, "invalidate"):
            adm.invalidate()           # fresh resource snapshot per consult
        return bool(adm.admissible(ctx, max(ctx - cached, 1), promote))

    def _commit(self, i: int, kind: str, docs: Tuple[int, ...],
                overlap: int) -> RouteDecision:
        self.depth[i] += 1
        self.routed[i] += 1
        self.kind_counts[kind] = self.kind_counts.get(kind, 0) + 1
        # routing-induced skew: how far above the least-loaded replica this
        # dispatch pushed its target.  This is what the escape hatch bounds
        # (<= max_queue_skew, always).  Global max-min depth additionally
        # stays within the bound while requests only arrive; a completion
        # draining the floor under an old peak can exceed it transiently,
        # which no admission-time rule can prevent.
        self.max_skew_observed = max(
            self.max_skew_observed,
            self.depth[i] - min(self.depth[:self.active]))
        if docs:
            self._register(i, docs)
        return RouteDecision(index=i, replica=self.replicas[i], kind=kind,
                             admitted=True, overlap_tokens=overlap)

    def note_complete(self, index: int) -> None:
        """A routed request finished on ``index`` (its queue slot freed)."""
        if self.depth[index] <= 0:
            raise ValueError(
                f"replica {index} completion without a matching route")
        self.depth[index] -= 1


def partition_requests(router: ReplicaRouter, requests, docs_of,
                       doc_tokens_of=None, context_of=None,
                       window: int = 0) -> List[List[object]]:
    """Route a whole trace (arrival order) into per-replica shares.

    docs_of(request) -> doc-ID tuple; doc_tokens_of(docs) -> per-doc token
    counts (optional); context_of(request, docs, doc_tokens) -> full
    sequence token count (optional — enables the router's per-replica
    admission consult).  Shared by ``launch/serve.py`` (real runtimes) and
    ``serving/simulator.py::simulate_replicas`` so both partition a batch
    trace through the identical code path.

    A refused decision (``admitted=False``: no replica can admit right
    now) still assigns the request to the router's preferred replica —
    batch partitioning has no later retry, and the engine's OWN admission
    control queues the request once it serves — but charges no router
    depth, exactly like the decision says.

    window: how many of the most recently routed requests count as
    in-flight for the router's queue-depth/escape-hatch accounting (0 =
    all of them).  Replicas drain their queues while later requests are
    still arriving, so a Poisson trace's instantaneous backlog is a
    sliding window, not the cumulative assignment — without this, the
    escape hatch reads total assignment skew and scatters exactly the hot
    documents affinity exists to keep together.  All in-flight depth is
    drained before returning (``router.depth`` ends at zero;
    ``router.routed`` keeps the per-replica assignment).
    """
    shares: List[List[object]] = [[] for _ in router.replicas]
    in_flight: List[int] = []
    for r in requests:
        docs = tuple(docs_of(r))
        toks = None if doc_tokens_of is None else doc_tokens_of(docs)
        ctx = 0 if context_of is None else int(context_of(r, docs, toks))
        dec = router.route(docs, toks, context_tokens=ctx)
        shares[dec.index].append(r)
        if dec.admitted:
            in_flight.append(dec.index)
            if window > 0 and len(in_flight) > window:
                router.note_complete(in_flight.pop(0))
    for i in in_flight:
        router.note_complete(i)
    return shares
