"""Discrete-event RAG serving simulator.

Executes the *identical* controller / knowledge-tree / PGDSF / reorder /
speculative-pipelining code as the real JAX engine, against an analytic
hardware profile (A10G, H800, TPU v5e) — this is how the paper-scale TTFT /
throughput claims are validated on a CPU-only container (docs/ARCHITECTURE.md §7).

Engine model (matches the paper's testbed semantics):
  * vector search runs on host CPUs, staged, one lane per request;
  * the LLM engine serves one iteration at a time: a chunked, ragged-batched
    prefill iteration (pieces of ``prefill_chunk`` tokens packed up to
    ``max_prefill_tokens``) or one decode step for the whole running batch;
  * prefill latency = host->GPU promotion transfer + T(alpha, beta),
    apportioned per chunk token;
  * a speculative prefill whose documents go stale is cancelled if still
    queued, and cancelled *between chunks* if running (the paper cancels
    "after the current iteration"); the remaining chunk tokens are saved.

The per-iteration decision (prefill vs decode, chunk packing, cache-aware
job pick) is NOT local code: it is the shared
``serving.scheduler.ContinuousBatchScheduler`` and its chunk protocol, the
same policy object the real JAX runtime (``serving.runtime``) executes, so
simulated and real scheduling cannot drift — including chunk boundaries,
which come from the shared ``prefill_piece_sizes`` splitter.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import random
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.controller import (RAGController, RequestPlan,
                                   effective_recompute)
from repro.core.knowledge_tree import CacheBackend, KnowledgeTree
from repro.core.profiler import CostProfiler, HardwareProfile
from repro.core.speculative import SpecState, SpeculativeController
from repro.retrieval.corpus import Corpus, Request
from repro.serving.config import FleetConfig
from repro.serving.router import AFFINITY, ReplicaRouter, partition_requests
from repro.serving.scheduler import (DECODE, PREFILL,
                                     ContinuousBatchScheduler,
                                     SchedulerConfig, prefill_piece_sizes)


@dataclasses.dataclass
class SimConfig:
    profile: HardwareProfile
    gpu_cache_bytes: float = 8 * 2**30
    host_cache_bytes: float = 192 * 2**30
    disk_cache_bytes: float = 0.0  # third tier below host (0 = disabled)
    max_batch: int = 4
    max_prefill_bs: int = 4
    top_k: int = 2
    policy: str = "pgdsf"
    reorder: bool = True
    reorder_window: int = 32
    speculative: bool = True
    search_fraction: float = 1.0
    system_prompt_tokens: int = 0
    cache_top_k: int = 0           # paper §8 "Large top-k": cache only the
                                   # first k docs of each request's sequence
                                   # (0 = cache all retrieved docs)
    prefill_chunk: int = 512       # tokens per prefill iteration (vLLM-style
                                   # iteration-level scheduling; stale
                                   # speculation cancels between iterations)
    max_prefill_tokens: int = 0    # ragged prefill-batch token budget per
                                   # iteration (0 = one request per iteration)
    seed: int = 0                  # seeds the simulator's own RNG (a
                                   # ``random.Random`` instance — NO
                                   # module-level global state), so two runs
                                   # with the same config+workload produce
                                   # identical SimMetrics by construction
    latency_jitter: float = 0.0    # +/- fractional noise on engine
                                   # iteration times drawn from the seeded
                                   # RNG (real accelerators are not
                                   # constant-latency; 0 = analytic times)
    tp: int = 1                    # tensor-parallel degree of the replica:
                                   # service times come from
                                   # profile.with_tp(tp) (compute/bandwidth
                                   # scale by tp, each forward pays a ring
                                   # all-reduce term); mirrors serve.py --tp
    reuse: str = "prefix"          # "prefix" = longest-cached-prefix reuse;
                                   # "chunk" = per-doc chunk cache reused at
                                   # any position with boundary recompute
                                   # (docs/ARCHITECTURE.md §11)
    recompute_tokens: int = 16     # boundary rows recomputed per relocated
                                   # chunk (page-aligned up via
                                   # effective_recompute — same widths as
                                   # the real runtime)
    block_size: int = 16           # KV page size effective_recompute aligns
                                   # to; mirrors the runtime's paged pool
    mode: str = "rag"              # "rag" = staged retrieval per request;
                                   # "cag" = full corpus KV preloaded into
                                   # the disk tier at startup, zero
                                   # retrieval stages per request (mirrors
                                   # EngineConfig.mode; ARCHITECTURE §12)

    def __post_init__(self):
        if self.reuse not in ("prefix", "chunk"):
            raise ValueError(f"SimConfig.reuse must be 'prefix' or 'chunk', "
                             f"got {self.reuse!r}")
        if self.mode not in ("rag", "cag"):
            raise ValueError(f"SimConfig.mode must be 'rag' or 'cag', "
                             f"got {self.mode!r}")
        if self.mode == "cag" and self.disk_cache_bytes <= 0:
            raise ValueError("SimConfig.mode='cag' preloads the corpus into "
                             "the disk tier and needs disk_cache_bytes > 0")


@dataclasses.dataclass
class SimMetrics:
    avg_ttft: float
    p50_ttft: float
    p99_ttft: float
    avg_tpot: float                # paper §8: time per output token
    doc_hit_rate: float
    completed: int
    duration: float
    throughput_rps: float
    avg_non_overlap_search: float
    wasted_prefills: int
    gpu_evictions: int
    swap_out_bytes: int
    disk_evictions: int = 0
    spill_bytes: int = 0               # host->disk bytes written (once/node)
    fetch_bytes: int = 0               # disk->host bytes read on promotion
    hit_tokens_gpu: int = 0            # alpha tokens by residency tier at
    hit_tokens_host: int = 0           # plan time (three-clock PGDSF)
    hit_tokens_disk: int = 0
    chunks_cancelled: int = 0          # prefills aborted at a chunk boundary
    chunk_tokens_saved: int = 0        # prefill tokens never computed thanks
                                       # to mid-prefill cancellation
    prefill_iterations: int = 0
    avg_prefill_batch: float = 0.0     # chunks packed per prefill iteration
    retrieval_stages: int = 0          # staged-search events processed
                                       # (CAG invariant: exactly 0)
    ttfts: List[float] = dataclasses.field(default_factory=list, repr=False)
    # TTFTs of requests whose final plan hit at least one disk-resident
    # node — the tiered-cache benchmark's headline population
    disk_hit_ttfts: List[float] = dataclasses.field(default_factory=list,
                                                    repr=False)


class _SimBackend(CacheBackend):
    """Payloads are byte counts; GPU<->host hops cost PCIe time, host<->disk
    hops cost NVMe sequential-bandwidth time."""

    def __init__(self, profile: HardwareProfile):
        self.profile = profile

    def swap_out(self, node):
        node.payload_host = node.payload_gpu
        return self.profile.transfer_time(node.bytes_)

    def load(self, node):
        node.payload_gpu = node.payload_host
        return self.profile.transfer_time(node.bytes_)

    def spill(self, node):
        node.payload_disk = node.payload_host
        return self.profile.disk_transfer_time(node.bytes_)

    def fetch(self, node):
        node.payload_host = node.payload_disk
        return self.profile.disk_transfer_time(node.bytes_)


@dataclasses.dataclass
class _Job:
    req: "_ReqState"
    docs: Tuple[int, ...]
    speculative: bool
    cancelled: bool = False
    plan: Optional[RequestPlan] = None
    started: float = -1.0
    start_candidate: Optional[float] = None
    # chunked-prefill state (set when the first chunk executes)
    pending: List[int] = dataclasses.field(default_factory=list)
    sec_per_token: float = 0.0


@dataclasses.dataclass
class _ReqState:
    r: Request
    spec: SpecState
    stages: List = dataclasses.field(default_factory=list)
    search_start: float = 0.0
    search_end: float = -1.0
    final_docs: Optional[Tuple[int, ...]] = None
    final_prefill_first_start: float = -1.0   # for non-overlap metric
    prefill_done: float = -1.0
    prefill_docs: Optional[Tuple[int, ...]] = None
    ttft: float = -1.0
    remaining_out: int = 0
    context: int = 0
    # (gpu, host, disk) hit tokens of the final plan — per-request tier
    # attribution for the tiered-cache benchmark
    hit_tier_tokens: Tuple[int, int, int] = (0, 0, 0)
    done: bool = False
    finish_time: float = -1.0
    token_times: List[float] = dataclasses.field(default_factory=list)
    queued_jobs: List[_Job] = dataclasses.field(default_factory=list)
    spec_start_by_docs: Dict[Tuple[int, ...], float] = dataclasses.field(
        default_factory=dict)


class RAGSimulator:
    def __init__(self, cfg: SimConfig, corpus: Corpus, index,
                 requests: Sequence[Request],
                 profiler: Optional[CostProfiler] = None):
        # TP-scaled service times: swap the profile for its with_tp()
        # derivative ONCE here so every consumer below (cost profiler,
        # backend transfer times, decode_time) sees the same scaled model
        if cfg.tp > 1:
            cfg = dataclasses.replace(cfg,
                                      profile=cfg.profile.with_tp(cfg.tp))
        self.cfg = cfg
        self.corpus = corpus
        self.index = index
        self.requests = list(requests)
        # instance-owned seeded RNG: every stochastic choice (currently the
        # optional latency jitter) draws from here, never from the
        # process-global ``random``/``np.random`` state — same-seed
        # determinism is a tested property (tests/test_simulator.py)
        self.rng = random.Random(cfg.seed)
        prof = profiler or CostProfiler.from_profile(cfg.profile)
        self.tree = KnowledgeTree(
            int(cfg.gpu_cache_bytes), int(cfg.host_cache_bytes),
            int(cfg.disk_cache_bytes),
            policy=cfg.policy, profiler=prof,
            backend=_SimBackend(cfg.profile),
            bytes_per_token=int(cfg.profile.kv_bytes_per_token),
        )
        self.controller = RAGController(self.tree)
        self.spec_ctl = SpeculativeController(cfg.max_prefill_bs,
                                              enabled=cfg.speculative)
        # shared iteration-level policy (same object type the real runtime
        # drives); simulation has no block pool, so admission is unbounded
        self.sched: ContinuousBatchScheduler[_Job] = ContinuousBatchScheduler(
            SchedulerConfig(max_batch=cfg.max_batch,
                            max_prefill_bs=cfg.max_prefill_bs,
                            reorder=cfg.reorder,
                            reorder_window=cfg.reorder_window,
                            prefill_chunk=cfg.prefill_chunk,
                            max_prefill_tokens=cfg.max_prefill_tokens),
            viable=lambda job: not job.cancelled and not job.req.done)
        self.queue = self.sched.queue
        self.decode_running: List[_ReqState] = []
        self.engine_busy = False
        self.now = 0.0
        self._events: List = []
        self._seq = itertools.count()
        self.sched_times: List[float] = []
        self._all_states: List[_ReqState] = []
        self._partial_jobs: List[_Job] = []
        self.chunks_cancelled = 0
        self.chunk_tokens_saved = 0
        self.prefill_batches: List[int] = []   # chunks packed per iteration
        self.retrieval_stages = 0
        # CAG startup: pre-insert every doc into the disk tier (payloads are
        # byte counts in the simulator) — same preload contract as the real
        # engines, so sim and runtime share the residency policy exactly
        self.preload_stats: Optional[dict] = None
        if cfg.mode == "cag":
            self.preload_stats = self.controller.preload_corpus(
                range(len(corpus.doc_lengths)), corpus.doc_lengths,
                lambda d, n_tok: n_tok * self.tree.bytes_per_token)

    # ---- event plumbing ---------------------------------------------------

    def _push(self, t: float, kind: str, payload) -> None:
        heapq.heappush(self._events, (t, next(self._seq), kind, payload))

    def _jitter(self) -> float:
        """Multiplicative iteration-time noise from the seeded RNG."""
        j = self.cfg.latency_jitter
        if j <= 0.0:
            return 1.0
        return 1.0 + j * (2.0 * self.rng.random() - 1.0)

    # ---- main loop --------------------------------------------------------

    def run(self) -> SimMetrics:
        for r in self.requests:
            self._push(r.arrival, "arrival", r)
        while self._events:
            self.now, _, kind, payload = heapq.heappop(self._events)
            getattr(self, f"_on_{kind}")(payload)
        return self._metrics()

    # ---- arrival & staged retrieval ----------------------------------------

    def _on_arrival(self, r: Request) -> None:
        st = _ReqState(r=r, spec=SpecState(r.req_id),
                       remaining_out=r.output_len, search_start=self.now)
        self._all_states.append(st)
        # per-request top_k override (Request.top_k > 0): the front door's
        # SLO admission degrades by lowering retrieval depth; the real
        # engines honor the same override, so miss tokens stay identical
        k = min(r.top_k, self.cfg.top_k) if r.top_k > 0 else self.cfg.top_k
        if self.cfg.mode == "cag":
            # CAG: every doc's KV is already tree-resident, so there is no
            # retrieval to overlap — resolve docs with ONE synchronous probe
            # and submit the final (non-speculative) job at arrival
            docs = tuple(int(d) for d in self.index.search(r.query_vec, k))
            st.search_end = self.now
            st.final_docs = docs
            job = _Job(req=st, docs=docs, speculative=False)
            st.queued_jobs.append(job)
            plan_docs = [self.corpus.doc_lengths[i] for i in docs]
            cached = self._cached_tokens(docs, plan_docs)
            compute = sum(plan_docs) + len(r.question_tokens) - cached
            self.sched.submit(job, cached, compute)
            self._engine_maybe_start()
            return
        st.stages = list(self.index.staged_search(
            r.query_vec, k, self.cfg.search_fraction))
        t = self.now
        for stage in st.stages:
            t += stage.seconds
            self._push(t, "stage", (st, stage))

    def _pool_size(self) -> int:
        return self.sched.pool_size()

    def _on_stage(self, payload) -> None:
        st, stage = payload
        self.retrieval_stages += 1
        docs = tuple(stage.topk)
        if stage.is_final:
            st.search_end = self.now
            st.final_docs = docs
        import time as _t
        t0 = _t.perf_counter()
        action, d = self.spec_ctl.on_stage(
            st.spec, docs, self._pool_size(), is_final=stage.is_final)
        if action in ("terminate_and_launch", "terminate"):
            for job in st.queued_jobs:
                if not job.cancelled and job.docs != docs:
                    job.cancelled = True
        if action in ("launch", "terminate_and_launch"):
            job = _Job(req=st, docs=d, speculative=not stage.is_final)
            st.queued_jobs.append(job)
            # cached/compute lengths for cache-aware reordering
            plan_docs = [self.corpus.doc_lengths[i] for i in d]
            cached = self._cached_tokens(d, plan_docs)
            compute = sum(plan_docs) + len(st.r.question_tokens) - cached
            self.sched.submit(job, cached, compute)
        self.sched_times.append(_t.perf_counter() - t0)
        if stage.is_final:
            self._maybe_finalize(st)
        self._engine_maybe_start()

    def _maybe_finalize(self, st: _ReqState) -> None:
        """Search finished: if a matching prefill already completed, emit the
        first token now (speculation pays off — paper Fig. 11)."""
        if st.ttft >= 0 or st.done:
            return
        if st.prefill_docs == st.final_docs and st.prefill_done >= 0:
            self._first_token(st, max(self.now, st.prefill_done))

    # ---- engine ------------------------------------------------------------

    def _engine_maybe_start(self) -> None:
        if self.engine_busy:
            return
        self._sweep_stale_partials()
        import time as _t
        t0 = _t.perf_counter()
        act = self.sched.next_action(len(self.decode_running),
                                     refresh=self._job_lens)
        self.sched_times.append(_t.perf_counter() - t0)
        if act.kind == PREFILL:
            self._start_prefill_batch(act.chunks)
        elif act.kind == DECODE:
            self._start_decode()

    def _sweep_stale_partials(self) -> None:
        """Chunk-boundary cancellation (Alg. 2 at chunk grain): abort any
        in-flight chunked prefill whose job went stale between iterations —
        unpin its hit prefix and never compute the remaining chunks."""
        for job in [j for j in self._partial_jobs
                    if j.cancelled or j.req.done]:
            self._abort_chunked(job)

    def _abort_chunked(self, job: _Job) -> None:
        for n in job.plan.hit_nodes:      # unpin without inserting partials
            n.pinned = False
        self.chunks_cancelled += 1
        self.chunk_tokens_saved += sum(job.pending)
        job.pending = []
        self._partial_jobs.remove(job)
        self.sched.abort_prefill(job)

    def _cached_tokens(self, docs, doc_tokens) -> int:
        """Reusable-token estimate for reordering/admission: prefix mode
        counts the longest cached prefix, chunk mode counts each cached doc
        minus its page-aligned boundary recompute (same arithmetic as the
        real runtime's ``_job_ctx_beta``)."""
        if self.cfg.reuse != "chunk":
            return sum(n.n_tokens for n in self.tree.match_prefix(docs))
        cached = 0
        for i, node in enumerate(self.tree.match_chunks(docs)):
            if node is None:
                continue
            n_tok = int(doc_tokens[i])
            if node.exact_ctx and node.src_prefix == tuple(docs[:i]):
                cached += n_tok
            else:
                cached += n_tok - effective_recompute(
                    self.cfg.recompute_tokens, n_tok, self.cfg.block_size)
        return cached

    def _job_lens(self, job: _Job) -> Tuple[int, int]:
        doc_tokens = [int(self.corpus.doc_lengths[i]) for i in job.docs]
        cached = self._cached_tokens(job.docs, doc_tokens)
        total = sum(doc_tokens) + len(job.req.r.question_tokens)
        return cached, max(total - cached, 1)

    def _start_prefill_batch(self, chunks) -> None:
        """One engine iteration: the next chunk of every job the scheduler
        packed.  Iteration time = promotion transfers (first chunks) + the
        analytic compute cost apportioned per chunk token, summed over the
        ragged batch."""
        dt = 0.0
        ran = []
        for ch in chunks:
            job = ch.item
            st = job.req
            if job.cancelled or st.done:
                if job.plan is not None:
                    self._abort_chunked(job)
                else:
                    self.sched.abort_prefill(job)
                continue
            if job.plan is None:
                dt += self._begin_chunked(job)
            n = job.pending.pop(0)
            dt += n * job.sec_per_token
            ran.append(job)
        self.engine_busy = True
        if ran:                         # all-stale batches executed nothing
            self.prefill_batches.append(len(ran))
            dt *= self._jitter()
        self._push(self.now + dt, "prefill_batch_done", ran)

    def _begin_chunked(self, job: _Job) -> float:
        """Plan + promote on the first chunk; piece sizes come from the
        shared splitter (same chunk boundaries as the real runtime).
        Returns the promotion transfer seconds."""
        st = job.req
        doc_tokens = [int(self.corpus.doc_lengths[i]) for i in job.docs]
        q_tokens = len(st.r.question_tokens) + self.cfg.system_prompt_tokens
        if self.cfg.reuse == "chunk":
            plan = self.controller.plan_chunks(
                job.docs, doc_tokens, q_tokens,
                recompute_tokens=self.cfg.recompute_tokens,
                block_size=self.cfg.block_size)
        else:
            plan = self.controller.plan(job.docs, doc_tokens, q_tokens)
        transfer = self.controller.promote(plan)
        compute = self.tree.profiler.estimate(plan.alpha, plan.beta)
        job.plan = plan
        job.started = self.now
        if plan.chunks is not None:
            # compute segments: whole missed docs + reloc boundary heads
            seg_lens = [it.n_tokens if it.kind == "miss" else it.recompute
                        for it in plan.chunks if it.kind != "exact"]
            seg_lens.append(plan.question_tokens)
        else:
            seg_lens = list(plan.doc_tokens[len(plan.hit_nodes):]) \
                + [plan.question_tokens]
        job.pending = prefill_piece_sizes(seg_lens, self.cfg.prefill_chunk) \
            or [1]
        job.sec_per_token = compute / max(sum(job.pending), 1)
        self._partial_jobs.append(job)
        if st.final_docs is not None and job.docs == st.final_docs \
                and st.final_prefill_first_start < 0:
            st.final_prefill_first_start = self.now
        elif st.final_docs is None:
            # provisional docs may turn out final; record candidate start
            job.start_candidate = self.now
            st.spec_start_by_docs.setdefault(job.docs, self.now)
        return transfer

    def _on_prefill_batch_done(self, ran: List[_Job]) -> None:
        self.engine_busy = False
        # pass 1: settle every chunk with the scheduler BEFORE any side
        # effect — _first_token below can re-enter _engine_maybe_start, and
        # the packed batch's other jobs must already be consistent
        completed = []
        for job in ran:
            st = job.req
            if job.pending:
                if job.cancelled or st.done:
                    self._abort_chunked(job)
                else:
                    self.sched.note_chunk_done(job, job.pending)
                continue
            # prefill complete (a stale job still finishes its last chunk:
            # the paper cancels "after the current iteration")
            self.sched.note_chunk_done(job, [])
            self._partial_jobs.remove(job)
            completed.append(job)
        # pass 2: commits + first tokens
        for job in completed:
            st = job.req
            if not (job.cancelled or st.done):
                # completed prefills populate the tree even if speculative;
                # §8 "Large top-k": optionally cache only the leading docs
                if job.plan.chunks is not None:
                    self.controller.commit_chunks(
                        job.plan, max_docs=self.cfg.cache_top_k or None)
                else:
                    self.controller.commit(
                        job.plan, max_docs=self.cfg.cache_top_k or None)
                st.prefill_done = self.now
                st.prefill_docs = job.docs
                if st.final_docs is not None and job.docs == st.final_docs:
                    st.hit_tier_tokens = job.plan.hit_tier_tokens
                    if st.final_prefill_first_start < 0:
                        st.final_prefill_first_start = job.started
                    self._first_token(st, max(self.now, st.search_end))
                # else: wasted speculation; final job is queued already
            else:
                for n in job.plan.hit_nodes:   # unpin without inserting
                    n.pinned = False
        self._engine_maybe_start()

    def _first_token(self, st: _ReqState, t: float) -> None:
        if st.ttft >= 0:
            return
        # credit speculative start for the non-overlap metric
        if st.final_prefill_first_start < 0:
            cand = st.spec_start_by_docs.get(st.final_docs)
            if cand is not None:
                st.final_prefill_first_start = cand
        st.ttft = t - st.r.arrival
        st.context = (sum(int(self.corpus.doc_lengths[i]) for i in st.final_docs)
                      + len(st.r.question_tokens))
        st.remaining_out -= 1
        if st.remaining_out <= 0:
            st.done = True
            st.finish_time = t
        else:
            self.decode_running.append(st)
        self._engine_maybe_start()

    def _start_decode(self) -> None:
        batch = list(self.decode_running)
        ctx = float(np.mean([s.context for s in batch]))
        dt = self.cfg.profile.decode_time(len(batch), ctx) * self._jitter()
        self.engine_busy = True
        self._push(self.now + dt, "decode_done", batch)

    def _on_decode_done(self, batch: List[_ReqState]) -> None:
        self.engine_busy = False
        for st in batch:
            if st not in self.decode_running:
                continue
            st.context += 1
            st.remaining_out -= 1
            st.token_times.append(self.now)
            if st.remaining_out <= 0:
                st.done = True
                st.finish_time = self.now
                self.decode_running.remove(st)
        self._engine_maybe_start()

    # ---- metrics -------------------------------------------------------------

    def _metrics(self) -> SimMetrics:
        ttfts = []
        non_overlaps = []
        finishes = []
        wasted = 0
        for st in self._all_states:
            if st.ttft >= 0:
                ttfts.append(st.ttft)
                dur = st.search_end - st.search_start
                if st.final_prefill_first_start >= 0:
                    overlap = max(0.0, st.search_end
                                  - max(st.search_start,
                                        st.final_prefill_first_start))
                else:
                    overlap = 0.0
                non_overlaps.append(max(0.0, dur - min(overlap, dur)))
                finishes.append(getattr(st, "finish_time", st.search_end))
            wasted += st.spec.wasted_launches
        tpots = []
        for st in self._all_states:
            if len(st.token_times) >= 1 and st.ttft >= 0:
                t0 = st.r.arrival + st.ttft
                tpots.append((st.token_times[-1] - t0)
                             / max(len(st.token_times), 1))
        ttfts_a = np.asarray(ttfts) if ttfts else np.asarray([0.0])
        duration = (max(finishes) - min(r.arrival for r in self.requests)
                    if finishes else 0.0)
        return SimMetrics(
            avg_ttft=float(ttfts_a.mean()),
            p50_ttft=float(np.percentile(ttfts_a, 50)),
            p99_ttft=float(np.percentile(ttfts_a, 99)),
            avg_tpot=float(np.mean(tpots)) if tpots else 0.0,
            doc_hit_rate=self.controller.doc_hit_rate,
            completed=len(ttfts),
            duration=float(duration),
            throughput_rps=len(ttfts) / duration if duration > 0 else 0.0,
            avg_non_overlap_search=float(np.mean(non_overlaps)) if non_overlaps else 0.0,
            wasted_prefills=wasted,
            gpu_evictions=self.tree.stats["gpu_evictions"],
            swap_out_bytes=self.tree.stats["swap_out_bytes"],
            disk_evictions=self.tree.stats["disk_evictions"],
            spill_bytes=self.tree.stats["spill_bytes"],
            fetch_bytes=self.tree.stats["fetch_bytes"],
            hit_tokens_gpu=self.tree.stats["hit_tokens_gpu"],
            hit_tokens_host=self.tree.stats["hit_tokens_host"],
            hit_tokens_disk=self.tree.stats["hit_tokens_disk"],
            chunks_cancelled=self.chunks_cancelled,
            chunk_tokens_saved=self.chunk_tokens_saved,
            prefill_iterations=len(self.prefill_batches),
            avg_prefill_batch=(float(np.mean(self.prefill_batches))
                               if self.prefill_batches else 0.0),
            retrieval_stages=self.retrieval_stages,
            ttfts=list(map(float, ttfts)),
            disk_hit_ttfts=[float(st.ttft) for st in self._all_states
                            if st.ttft >= 0 and st.hit_tier_tokens[2] > 0],
        )


# --------------------------------------------------------------------------
# multi-replica simulation: the same ReplicaRouter the real driver uses
# --------------------------------------------------------------------------

@dataclasses.dataclass
class FleetSimResult:
    """Outcome of a multi-replica simulation: the cross-replica merge, the
    per-replica metrics, and the router's routing/skew accounting."""
    metrics: SimMetrics
    per_replica: List[SimMetrics]
    router_stats: Dict[str, object]


def _wmean(pairs: List[Tuple[float, float]]) -> float:
    """Weighted mean over (value, weight), 0.0 when all weights are zero."""
    tot = sum(w for _, w in pairs)
    return sum(v * w for v, w in pairs) / tot if tot > 0 else 0.0


def merge_sim_metrics(parts: Sequence[SimMetrics]) -> SimMetrics:
    """Cross-replica SimMetrics: percentiles recomputed over the pooled
    per-request TTFTs (exact), ratio metrics completion-weighted, counters
    summed, duration = the slowest replica (replicas run concurrently)."""
    ttfts = [t for m in parts for t in m.ttfts]
    ttfts_a = np.asarray(ttfts) if ttfts else np.asarray([0.0])
    completed = sum(m.completed for m in parts)
    duration = max((m.duration for m in parts), default=0.0)
    return SimMetrics(
        avg_ttft=float(ttfts_a.mean()),
        p50_ttft=float(np.percentile(ttfts_a, 50)),
        p99_ttft=float(np.percentile(ttfts_a, 99)),
        avg_tpot=_wmean([(m.avg_tpot, m.completed) for m in parts]),
        doc_hit_rate=_wmean([(m.doc_hit_rate, m.completed) for m in parts]),
        completed=completed,
        duration=float(duration),
        throughput_rps=completed / duration if duration > 0 else 0.0,
        avg_non_overlap_search=_wmean(
            [(m.avg_non_overlap_search, m.completed) for m in parts]),
        wasted_prefills=sum(m.wasted_prefills for m in parts),
        gpu_evictions=sum(m.gpu_evictions for m in parts),
        swap_out_bytes=sum(m.swap_out_bytes for m in parts),
        disk_evictions=sum(m.disk_evictions for m in parts),
        spill_bytes=sum(m.spill_bytes for m in parts),
        fetch_bytes=sum(m.fetch_bytes for m in parts),
        hit_tokens_gpu=sum(m.hit_tokens_gpu for m in parts),
        hit_tokens_host=sum(m.hit_tokens_host for m in parts),
        hit_tokens_disk=sum(m.hit_tokens_disk for m in parts),
        chunks_cancelled=sum(m.chunks_cancelled for m in parts),
        chunk_tokens_saved=sum(m.chunk_tokens_saved for m in parts),
        prefill_iterations=sum(m.prefill_iterations for m in parts),
        avg_prefill_batch=_wmean(
            [(m.avg_prefill_batch, m.prefill_iterations) for m in parts]),
        retrieval_stages=sum(m.retrieval_stages for m in parts),
        ttfts=list(map(float, ttfts)),
        disk_hit_ttfts=[t for m in parts for t in m.disk_hit_ttfts],
    )


def simulate_replicas(cfg: SimConfig, corpus: Corpus, index,
                      requests: Sequence[Request], *,
                      n_replicas: int = 1, routing: str = AFFINITY,
                      max_queue_skew: int = 4,
                      profiler: Optional[CostProfiler] = None
                      ) -> FleetSimResult:
    """Simulate N independent engine replicas behind a ``ReplicaRouter``.

    Each replica is a full ``RAGSimulator`` — its own ``KnowledgeTree``,
    scheduler and three-tier cache; no state is shared across replicas.
    The router object is the SAME class ``launch/serve.py`` drives over
    real ``ContinuousRuntime`` replicas (mirroring how the scheduler is
    shared), so simulated and real routing policy cannot drift: the trace
    is partitioned through ``partition_requests`` in arrival order, keyed
    by each request's (deterministic) retrieved doc IDs.
    """
    sims = [RAGSimulator(cfg, corpus, index, [], profiler=profiler)
            for _ in range(n_replicas)]
    router = ReplicaRouter(sims, config=FleetConfig(
        replicas=len(sims), routing=routing, max_queue_skew=max_queue_skew))
    ordered = sorted(requests, key=lambda r: r.arrival)
    # in-flight window: each replica drains max_batch requests concurrently
    # while the trace keeps arriving, so backlog — what the escape hatch
    # bounds — is a sliding window over the most recent dispatches
    shares = partition_requests(
        router, ordered,
        docs_of=lambda r: index.search(r.query_vec, cfg.top_k),
        doc_tokens_of=lambda docs: [int(corpus.doc_lengths[d])
                                    for d in docs],
        context_of=lambda r, docs, toks: (sum(toks)
                                          + len(r.question_tokens)
                                          + cfg.system_prompt_tokens),
        window=2 * cfg.max_batch * n_replicas)
    per = []
    for sim, share in zip(sims, shares):
        sim.requests = list(share)
        per.append(sim.run())
    return FleetSimResult(metrics=merge_sim_metrics(per), per_replica=per,
                          router_stats=router.stats())


@dataclasses.dataclass
class FrontDoorSimResult:
    metrics: SimMetrics            # pooled, INCLUDING front-door hits (each
    #                                charged FrontDoor.LOOKUP_SECONDS TTFT)
    miss_metrics: SimMetrics       # engine-served misses only
    per_replica: List[SimMetrics]
    router_stats: Dict[str, object]
    frontdoor_stats: Dict[str, object]
    partition: object              # frontdoor.FrontDoorPartition


def simulate_frontdoor(cfg: SimConfig, corpus: Corpus, index,
                       requests: Sequence[Request], frontdoor, *,
                       n_replicas: int = 1, routing: str = AFFINITY,
                       max_queue_skew: int = 4,
                       profiler: Optional[CostProfiler] = None
                       ) -> FrontDoorSimResult:
    """Simulate the full front-door stack: query cache -> SLO admission ->
    autoscaler -> ``ReplicaRouter`` -> N ``RAGSimulator`` replicas.

    ``frontdoor`` is a ``serving.frontdoor.FrontDoor`` — the SAME policy
    object ``launch/serve.py --frontdoor`` drives over real runtimes,
    walked through the SAME ``frontdoor_partition`` trace walk, so
    front-door policy cannot drift between simulation and reality
    (the PR 1/PR 4 shared-policy pattern).

    Cache hits never reach a replica; they are charged the front door's
    analytic lookup cost as TTFT and pooled into ``metrics`` so
    "front door on vs off" comparisons are honest about what the cache
    absorbed.  Shed requests are dropped (counted in frontdoor_stats).
    """
    from repro.serving.frontdoor import frontdoor_partition

    sims = [RAGSimulator(cfg, corpus, index, [], profiler=profiler)
            for _ in range(n_replicas)]
    router = ReplicaRouter(sims, config=FleetConfig(
        replicas=len(sims), routing=routing, max_queue_skew=max_queue_skew))

    def _k(r):
        return min(r.top_k, cfg.top_k) if r.top_k > 0 else cfg.top_k

    part = frontdoor_partition(
        frontdoor, router, requests,
        docs_of=lambda r: index.search(r.query_vec, _k(r)),
        doc_tokens_of=lambda docs: [int(corpus.doc_lengths[d])
                                    for d in docs],
        context_of=lambda r, docs, toks: (sum(toks)
                                          + len(r.question_tokens)
                                          + cfg.system_prompt_tokens),
        window=2 * cfg.max_batch * n_replicas)
    per = []
    for sim, share in zip(sims, part.shares):
        sim.requests = list(share)
        per.append(sim.run())
    miss = merge_sim_metrics(per)
    # pool the hits back in at the analytic lookup cost
    hit_ttfts = [frontdoor.LOOKUP_SECONDS] * len(part.hits)
    ttfts = list(miss.ttfts) + hit_ttfts
    ttfts_a = np.asarray(ttfts) if ttfts else np.asarray([0.0])
    completed = miss.completed + len(part.hits)
    pooled = dataclasses.replace(
        miss,
        avg_ttft=float(ttfts_a.mean()),
        p50_ttft=float(np.percentile(ttfts_a, 50)),
        p99_ttft=float(np.percentile(ttfts_a, 99)),
        completed=completed,
        throughput_rps=(completed / miss.duration
                        if miss.duration > 0 else 0.0),
        ttfts=list(map(float, ttfts)))
    return FrontDoorSimResult(metrics=pooled, miss_metrics=miss,
                              per_replica=per, router_stats=router.stats(),
                              frontdoor_stats=frontdoor.stats(),
                              partition=part)
