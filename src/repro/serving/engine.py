"""Real-execution RAG serving engine (tiny models, CPU-runnable end-to-end).

This is deliverable (b)'s driver: it runs the full RAGCache pipeline with
*actual* model states — staged vector search, knowledge-tree lookup,
host->device promotion, segment-chained prefix prefill, greedy decode, and
PGDSF-managed insertion of the newly computed document states.

Document payloads:
  * attention families: per-document KV segments, stored in a paged device
    store (vLLM-style blocks) with a numpy host tier;
  * SSM family (xLSTM): the fixed-size recurrent state snapshot after the
    document — only the *deepest* hit node's state is promoted (the
    state-caching generalization, docs/ARCHITECTURE.md §3);
  * hybrid: both.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.controller import RAGController
from repro.core.knowledge_tree import CacheBackend, KnowledgeTree
from repro.core.profiler import CostProfiler
from repro.core.reorder import ReorderQueue
from repro.core.speculative import SpecState, SpeculativeController
from repro.kvcache.paged import make_disk_store
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.retrieval.corpus import Corpus, Request
from repro.serving.config import EngineConfig, reject_legacy_kwargs
from repro.serving.scheduler import prefill_piece_sizes


class _JaxBackend(CacheBackend):
    """Device tier: jnp arrays; host tier: numpy copies; optional disk tier:
    mmap'd segments (attention-family {k, v} payloads only — recurrent state
    snapshots stay two-tier). Transfer timing is measured (CPU-to-CPU here,
    but the code path is the TPU one)."""

    def __init__(self, disk=None):
        self.disk = disk

    def swap_out(self, node):
        t0 = time.perf_counter()
        node.payload_host = jax.tree.map(np.asarray, node.payload_gpu)
        return time.perf_counter() - t0

    def load(self, node):
        t0 = time.perf_counter()
        node.payload_gpu = jax.tree.map(jnp.asarray, node.payload_host)
        jax.block_until_ready(node.payload_gpu)
        return time.perf_counter() - t0

    def spill(self, node):
        t0 = time.perf_counter()
        node.payload_disk = self.disk.write(node.payload_host["k"],
                                            node.payload_host["v"])
        return time.perf_counter() - t0

    def fetch(self, node):
        t0 = time.perf_counter()
        k, v = self.disk.read(node.payload_disk)
        node.payload_host = {"k": k, "v": v}
        return time.perf_counter() - t0

    def free_disk(self, node):
        if node.payload_disk is not None:
            self.disk.delete(node.payload_disk)
        node.payload_disk = None


@dataclasses.dataclass
class ServeResult:
    req_id: int
    tokens: List[int]
    ttft: float
    search_time: float
    transfer_time: float
    prefill_time: float
    alpha: int
    beta: int
    docs: Tuple[int, ...]
    # (V,) logits at the first generated token — the sequential engine is
    # the exact oracle --check-tokens tol:<eps> measures divergence against
    first_logits: Optional[np.ndarray] = None


class RAGServer:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        corpus: Corpus,
        index,
        *,
        config: Optional[EngineConfig] = None,
        reorder_window: int = 32,
        profiler: Optional[CostProfiler] = None,
        **legacy,
    ):
        # ``config=`` is the SOLE constructor API (serving/config.py); the
        # pre-PR 7 loose-kwargs path is gone and any stray kwarg raises a
        # TypeError naming the EngineConfig field that replaced it.
        # ``reorder_window`` / ``profiler`` stay explicit: they take live
        # objects / test-only shapes that don't belong in a CLI-round-trip
        # config.  The sequential engine deliberately IGNORES config.mesh:
        # it is the single-device token oracle every TP/replica
        # configuration is checked against (--check-tokens).
        reject_legacy_kwargs("RAGServer", legacy, EngineConfig)
        config = config if config is not None else EngineConfig()
        gpu_cache_bytes = config.gpu_cache_bytes
        host_cache_bytes = config.host_cache_bytes
        disk_cache_bytes = config.disk_cache_bytes
        disk_cache_dir = config.disk_cache_dir
        policy = config.policy
        top_k = config.top_k
        reorder = config.reorder
        speculative = config.speculative
        max_prefill_bs = config.max_prefill_bs
        prefill_chunk = config.prefill_chunk
        self.cfg = cfg
        self.params = params
        self.corpus = corpus
        self.index = index
        self.top_k = top_k
        # tokens per prefill call (0 = one call per segment).  Chunks are
        # split per segment by the shared ``prefill_piece_sizes`` helper, so
        # the chunked sequential engine issues the exact same attention
        # calls as the chunked continuous runtime (bit-identical tokens).
        self.prefill_chunk = prefill_chunk
        kv_bytes = (2 * cfg.n_layers * cfg.n_kv_heads * cfg.hd
                    * jnp.dtype(cfg.jdtype).itemsize)
        if cfg.family == "ssm":
            kv_bytes = 4  # state nodes are O(1); bill ~per-token trivially
        if cfg.family in ("ssm", "hybrid"):
            disk_cache_bytes = 0   # recurrent snapshots are not {k, v} dicts
        self.mode = config.mode
        if self.mode == "cag" and disk_cache_bytes <= 0:
            raise ValueError(
                "mode='cag' preloads the whole corpus KV into the disk tier "
                "and needs disk_cache_bytes > 0 sized for the corpus"
                + (" (recurrent-state families have no disk tier)"
                   if cfg.family in ("ssm", "hybrid") else ""))
        self.disk = make_disk_store(disk_cache_dir, disk_cache_bytes)
        self.tree = KnowledgeTree(
            gpu_cache_bytes, host_cache_bytes,
            disk_cache_bytes if self.disk is not None else 0,
            policy=policy,
            profiler=profiler or CostProfiler.from_fn(
                lambda a, b: 1e-4 * b + 2e-8 * b * (a + b),
                (0, 64, 256, 1024), (1, 32, 128, 512, 1024)),
            backend=_JaxBackend(self.disk), bytes_per_token=max(kv_bytes, 1),
        )
        self.controller = RAGController(self.tree)
        self.spec_ctl = SpeculativeController(max_prefill_bs, enabled=speculative)
        self.reorder = ReorderQueue(reorder_window, enabled=reorder)
        self._prefill_fn = jax.jit(
            lambda p, toks, pc, pl: M.prefill(cfg, p, {"tokens": toks},
                                              prefix_cache=pc, prefix_len=pl),
            static_argnames=("pl",))
        self.results: List[ServeResult] = []
        # CAG startup (docs/ARCHITECTURE.md §12): pre-insert the FULL corpus
        # KV into the disk tier — each doc's KV computed at position 0 with
        # no prefix (exactly what the engine computes for a doc served
        # first), so the preloaded states are bit-identical to RAG-computed
        # ones and --check-tokens holds unchanged.
        self.preload_stats: Optional[dict] = None
        if self.mode == "cag":
            self.preload_stats = self.controller.preload_corpus(
                range(len(corpus.doc_lengths)), corpus.doc_lengths,
                self._corpus_payload)

    def _corpus_payload(self, doc_id: int, n_tokens: int) -> dict:
        """Host-layout {k, v} KV of one corpus doc, computed standalone
        (position 0, no prefix) through the engine's own prefill path."""
        toks = self.corpus.doc_tokens[doc_id]
        _, cache, _ = self._prefill_segment(toks, None, 0)
        seg = self._extract_payload(cache, 0, len(toks))
        return jax.tree.map(np.asarray, seg)

    # ---- payload plumbing -------------------------------------------------

    def _assemble_prefix(self, nodes) -> Tuple[Optional[dict], int]:
        """Concatenate hit-node payloads into a model prefix_cache."""
        if not nodes:
            return None, 0
        if self.cfg.family == "ssm":
            # only the deepest state matters
            state = nodes[-1].payload_gpu
            plen = sum(n.n_tokens for n in nodes)
            return state, plen
        ks = jnp.concatenate([n.payload_gpu["k"] for n in nodes], axis=2)
        vs = jnp.concatenate([n.payload_gpu["v"] for n in nodes], axis=2)
        out = {"k": ks, "v": vs}
        if self.cfg.family == "hybrid":
            out["ssm"] = nodes[-1].payload_gpu["ssm"]
        return out, int(ks.shape[2])

    # ---- serving ------------------------------------------------------------

    def serve(self, requests: Sequence[Request],
              max_new_tokens: int = 4) -> List[ServeResult]:
        # cache-aware reordering over the (logical) arrival queue
        for r in requests:
            docs = tuple(self.index.search(r.query_vec, self._top_k_of(r)))
            hit = self.tree.match_prefix(docs)
            cached = sum(n.n_tokens for n in hit)
            total = sum(int(self.corpus.doc_lengths[d]) for d in docs) \
                + len(r.question_tokens)
            self.reorder.push((r, docs), cached, max(total - cached, 1))
        out = []
        while True:
            self.reorder.refresh(self._refresh_lens)
            item = self.reorder.pop()
            if item is None:
                break
            out.append(self._serve_one(*item, max_new_tokens=max_new_tokens))
        self.results.extend(out)
        return out

    def _top_k_of(self, r: Request) -> int:
        """Per-request retrieval depth: Request.top_k > 0 overrides (the
        front door's SLO admission degrades by lowering it; both engines
        honor the same override so miss tokens stay bit-identical)."""
        return min(r.top_k, self.top_k) if r.top_k > 0 else self.top_k

    def _refresh_lens(self, item):
        r, docs = item
        hit = self.tree.match_prefix(docs)
        cached = sum(n.n_tokens for n in hit)
        total = sum(int(self.corpus.doc_lengths[d]) for d in docs) \
            + len(r.question_tokens)
        return cached, max(total - cached, 1)

    def _serve_one(self, r: Request, docs: Tuple[int, ...],
                   max_new_tokens: int) -> ServeResult:
        # 1. staged retrieval + speculative-pipelining decisions (logical).
        #    CAG mode (docs/ARCHITECTURE.md §12) runs ZERO retrieval stages:
        #    docs were already resolved by the one synchronous index probe
        #    at arrival, so the staged walk (and its speculative decisions)
        #    degenerates away and search_time is identically 0.
        search_time = 0.0
        if self.mode != "cag":
            t0 = time.perf_counter()
            spec = SpecState(r.req_id)
            for stage in self.index.staged_search(r.query_vec,
                                                  self._top_k_of(r)):
                self.spec_ctl.on_stage(spec, tuple(stage.topk), 0,
                                       is_final=stage.is_final)
            search_time = time.perf_counter() - t0

        doc_tokens = [int(self.corpus.doc_lengths[d]) for d in docs]
        plan = self.controller.plan(docs, doc_tokens, len(r.question_tokens))
        transfer = self.controller.promote(plan)

        # 2. segment-chained prefill: cached prefix -> each uncached doc ->
        #    question; each uncached doc's states become tree payloads.
        t1 = time.perf_counter()
        prefix, plen = self._assemble_prefix(plan.hit_nodes)
        payloads = []
        for i in range(len(plan.hit_nodes), len(docs)):
            toks = self.corpus.doc_tokens[docs[i]]
            start = plen
            _, prefix, plen = self._prefill_segment(toks, prefix, plen)
            payloads.append(self._extract_payload(prefix, start, len(toks)))
        logits, cache, plen = self._prefill_segment(
            r.question_tokens, prefix, plen)
        logits = jax.block_until_ready(logits)
        prefill_time = time.perf_counter() - t1

        # 3. commit new doc states to the knowledge tree (PGDSF update)
        self.controller.commit(plan, payloads)

        # 4. greedy decode
        toks = [int(jnp.argmax(logits[0, -1]))]
        total_len = plen
        if max_new_tokens > 1:
            toks += self._decode(cache, toks[0], total_len, max_new_tokens - 1)
        ttft = search_time + transfer + prefill_time
        return ServeResult(
            req_id=r.req_id, tokens=toks, ttft=ttft,
            search_time=search_time, transfer_time=transfer,
            prefill_time=prefill_time, alpha=plan.alpha, beta=plan.beta,
            docs=docs, first_logits=np.asarray(logits[0, -1]),
        )

    def _prefill_segment(self, tokens, prefix, plen: int):
        """Prefill one segment (document or question) on top of ``prefix``,
        in ``prefill_chunk``-token pieces (one call for the whole segment
        when chunking is off).  Returns (last_logits, cache, new_plen)."""
        logits = None
        off = 0
        for n in prefill_piece_sizes([len(tokens)], self.prefill_chunk):
            toks = jnp.asarray(tokens[off:off + n])[None]
            logits, cache = self._prefill_fn(self.params, toks, prefix, plen)
            prefix, plen = cache, plen + n
            off += n
        # a zero-length segment runs no pieces: preserve the prefix chain
        return logits, prefix, plen

    def _extract_payload(self, cache, start: int, length: int):
        if self.cfg.family == "ssm":
            return jax.tree.map(lambda x: x, cache)     # state snapshot
        seg = {
            "k": cache["k"][:, :, start:start + length],
            "v": cache["v"][:, :, start:start + length],
        }
        if self.cfg.family == "hybrid":
            seg["ssm"] = cache["ssm"]
        return seg

    def _decode(self, cache, last_tok: int, cur_len: int, n: int) -> List[int]:
        cfg = self.cfg
        max_len = cur_len + n + 1
        dc = M.init_decode_cache(cfg, 1, max_len)
        if cfg.family == "ssm":
            dc = cache
        else:
            dc["k"] = dc["k"].at[:, :, :cur_len].set(cache["k"])
            dc["v"] = dc["v"].at[:, :, :cur_len].set(cache["v"])
            if cfg.family == "hybrid":
                dc["ssm"] = cache["ssm"]
        out = []
        pos = jnp.asarray([cur_len], jnp.int32)
        tok = jnp.asarray([[last_tok]])
        for _ in range(n):
            pos = pos + 1
            logits, dc = M.decode_step(cfg, self.params, tok, dc, pos)
            t = int(jnp.argmax(logits[0, -1]))
            out.append(t)
            tok = jnp.asarray([[t]])
        return out
