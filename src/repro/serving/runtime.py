"""Continuous-batching async serving runtime (real execution).

This is the event-loop engine the paper's serving numbers assume and the
sequential ``RAGServer`` lacks: iteration-level scheduling over many
concurrent requests, with

  * staged vector search running OFF the engine's critical path — each
    request's search stages are events on the runtime clock, and the
    ``SpeculativeController``'s per-stage decisions actually launch and
    terminate speculative prefills that overlap the remaining search
    (paper §5.3, Algorithm 2);
  * one engine iteration at a time: a *chunked, ragged-batched* prefill
    iteration — continuations of in-flight chunked prefills plus newly
    admitted jobs picked by the cache-aware ``ReorderQueue``, packed up to
    ``max_prefill_tokens`` — or ONE batched decode step for every running
    request.  A prefill split into ``prefill_chunk``-token pieces carries
    its partial KV across iterations in the paged store, and stale
    speculation is cancelled *between* chunks (the partial KV is freed and
    the remaining chunk tokens are never computed);
  * batched decode through the ``PagedKVStore``: each running request owns a
    token-level slot mapping (position -> (block, slot)); EVERY cached
    knowledge-tree document segment of the hit prefix is REFCOUNT-SHARED
    into it — block-aligned or not, since the mapping absorbs unaligned
    tails.  With ``attn="paged"`` (the default via "auto") each iteration
    runs per-layer paged attention STRAIGHT from the pool's page arrays
    through run tables (kernels/paged_attention.py: Pallas kernel on TPU,
    per-page jnp online softmax on CPU) and appends the new token's KV in
    place at its (block, slot) — nothing materializes the dense
    (L, B, S, KV, hd) context.  ``attn="dense"`` keeps the old slot-map
    gather + token scatter as an A/B baseline; greedy tokens are
    bit-identical across modes;
  * admission control and preemption by paged-block / tree-pin budget via
    the shared ``ContinuousBatchScheduler`` (the same policy object the
    discrete-event simulator executes) — the pin budget counts promote
    tokens too, so a hit path parked on host/disk cannot over-admit;
  * an optional mmap'd DISK tier below the host copies
    (``--disk-cache-bytes``): the knowledge tree demotes GPU -> host ->
    disk under one PGDSF clock cascade, and disk reads for a matched
    prefix are prefetched into host memory DURING the remaining retrieval
    stages (host-side I/O overlaps the accelerator exactly like the staged
    search), so the engine-critical promote stays a host->GPU copy.  See
    docs/ARCHITECTURE.md §2.

Clock semantics: the runtime keeps a virtual clock (seconds).  Engine
iterations advance it by their *measured* wall time (real JAX compute;
prefill shapes still jit-compile on first occurrence — NOTE that chunked
prefill multiplies unique (prefix_len, piece) shapes, so on this CPU-tiny
setup small chunk sizes are compile-dominated and chunked-mode latency
numbers include those compiles, like every prefill here; a production
deployment would bucket prefix lengths); retrieval stages
advance their own per-request lanes by max(measured stage wall time,
analytic stage cost) — search runs on host CPUs concurrently with the
accelerator, which is the paper's testbed overlap model.  TTFT is therefore
max(search_end, prefill_end) - arrival, NOT the serial sum the sequential
engine reports.

Families: attention-only (dense / moe / vlm).  SSM and hybrid recurrent
state cannot be paged per-block; serve those through the sequential engine.
"""
from __future__ import annotations

import contextlib
import dataclasses
import heapq
import itertools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import NamedSharding, PartitionSpec

from repro.core.controller import RAGController, effective_recompute
from repro.core.knowledge_tree import (CacheBackend, EvictionError,
                                       KnowledgeTree)
from repro.core.profiler import CostProfiler
from repro.core.speculative import SpecState, SpeculativeController
from repro.kvcache.paged import (DiskSegmentStore, OutOfBlocks, PagedKVStore,
                                 PagedSegment, make_disk_store)
from repro.launch.mesh import make_serving_mesh
from repro.launch.sharding import (assert_tp_compatible, pool_kv_spec,
                                   serving_param_shardings)
from repro.serving.config import (EngineConfig, MeshConfig,
                                  reject_legacy_kwargs)
from repro.models import layers as L
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.retrieval.corpus import Corpus, Request
from repro.serving.metrics import ServingMetrics
from repro.serving.scheduler import (DECODE, PREEMPT, PREFILL,
                                     ContinuousBatchScheduler, PagedAdmission,
                                     SchedulerConfig, prefill_piece_sizes)

WAITING, RUNNING, FINISHED = "waiting", "running", "finished"


class PagedBackend(CacheBackend):
    """Tree payloads are PagedSegments in the shared device store; the host
    tier holds dense numpy copies; the optional disk tier holds one mmap
    file per node (``DiskSegmentStore``). Transfer seconds are measured."""

    def __init__(self, store: PagedKVStore,
                 disk: Optional[DiskSegmentStore] = None):
        self.store = store
        self.disk = disk

    def swap_out(self, node):
        t0 = time.perf_counter()
        k, v = self.store.gather(node.payload_gpu)
        node.payload_host = {"k": np.asarray(k), "v": np.asarray(v)}
        return time.perf_counter() - t0

    def load(self, node):
        t0 = time.perf_counter()
        try:
            node.payload_gpu = self.store.put(
                jnp.asarray(node.payload_host["k"]),
                jnp.asarray(node.payload_host["v"]))
        except OutOfBlocks as e:
            raise EvictionError(str(e))   # promote() degrades to recompute
        jax.block_until_ready(self.store.k)
        return time.perf_counter() - t0

    def spill(self, node):
        t0 = time.perf_counter()
        node.payload_disk = self.disk.write(node.payload_host["k"],
                                            node.payload_host["v"])
        return time.perf_counter() - t0

    def fetch(self, node):
        t0 = time.perf_counter()
        k, v = self.disk.read(node.payload_disk)
        node.payload_host = {"k": k, "v": v}
        return time.perf_counter() - t0

    def free_gpu(self, node):
        if node.payload_gpu is not None:
            self.store.free(node.payload_gpu)
        node.payload_gpu = None

    def free_disk(self, node):
        if node.payload_disk is not None:
            self.disk.delete(node.payload_disk)
        node.payload_disk = None


class ShardedPagedBackend(PagedBackend):
    """Tensor-parallel pool backend — the fourth implementation of the
    ``serving/backend.py::Backend`` contract.

    Same tier semantics as ``PagedBackend``, but the device tier is a
    KV-head-sharded pool, so both hops batch their copies per mesh-axis
    member instead of staging a replicated segment:

      * demote (``swap_out``): ``device_get`` pulls each device's head slice
        exactly once and reassembles the dense host copy;
      * promote (``load``): the host segment enters ``store.put`` as numpy,
        and the store's ``_shard_segment`` ``device_put``s it with the
        pool's own KV-head sharding — one sub-copy per shard, never a full
        replica that the pool write would immediately reshard.
    """

    def swap_out(self, node):
        t0 = time.perf_counter()
        k, v = self.store.gather(node.payload_gpu)
        k, v = jax.device_get((k, v))
        node.payload_host = {"k": np.asarray(k), "v": np.asarray(v)}
        return time.perf_counter() - t0

    def load(self, node):
        t0 = time.perf_counter()
        try:
            node.payload_gpu = self.store.put(node.payload_host["k"],
                                              node.payload_host["v"])
        except OutOfBlocks as e:
            raise EvictionError(str(e))   # promote() degrades to recompute
        jax.block_until_ready(self.store.k)
        return time.perf_counter() - t0


@dataclasses.dataclass
class _PrefillResult:
    docs: Tuple[int, ...]
    cache: Optional[dict]           # dense full-sequence cache (L, 1, T, ...)
                                    # — None in paged-prefill mode
    first_token: int
    total_len: int
    alpha: int
    beta: int
    hit_docs: int
    hit_tier_tokens: Tuple[int, int, int]   # alpha split by (gpu, host, disk)
    speculative: bool
    started: float
    # paged-prefill mode: the computed KV already lives in the pool — the
    # result holds the page coordinates, not a dense copy.  hit_runs are
    # (blocks, n_tokens) snapshots of the shared (incref'd) prefix nodes;
    # pg_segs are the request-owned segments (uncached docs + question), in
    # sequence order.  Both lists are emptied when _paginate consumes them
    # (ownership transfers to the decode table) or _free_paged_kv drops them.
    hit_runs: List[Tuple[List[int], int]] = dataclasses.field(
        default_factory=list)
    pg_segs: List[PagedSegment] = dataclasses.field(default_factory=list)
    # ordered sequence layout: ("run"|"seg", index into hit_runs/pg_segs,
    # absolute start position).  Prefix mode is runs-then-segs; chunk mode
    # (--reuse chunk) interleaves shared runs and computed segments.
    layout: List[Tuple[str, int, int]] = dataclasses.field(
        default_factory=list)
    exact: bool = True              # False once a relocated chunk was reused
    first_logits: Optional[np.ndarray] = None   # (V,) at the first token


@dataclasses.dataclass
class _ChunkState:
    """Engine-side state of an in-flight chunked prefill: plan, execution
    cursor over the to-be-computed segments, remaining piece sizes, and the
    partial KV paged into the store between iterations."""
    plan: object                    # RequestPlan
    segs: List[np.ndarray]          # token arrays: uncached docs + question
    doc_bounds: List[Tuple[int, int]]  # (abs_start, length) per uncached doc
    pieces: List[int]               # remaining piece sizes (shared splitter)
    total: int                      # beta tokens in all pieces at start
    seg_idx: int = 0
    seg_off: int = 0
    plen: int = 0                   # absolute tokens prefixed so far
    prefix_hit: Optional[dict] = None  # dense cached-prefix KV (alpha tokens)
    partial_seg: Optional[object] = None  # PagedSegment of computed tokens
                                          # (dense mode only)
    cache: Optional[dict] = None    # dense full-seq cache, set when the
                                    # last piece completes (commit/paginate)
    logits: Optional[object] = None
    # paged-prefill mode: no dense KV at all.  hit_runs snapshot the shared
    # (pinned + incref'd) prefix nodes' pages; pg_segs hold one (initially
    # empty) segment per to-compute segment in ``segs`` — the kernel
    # scatters each chunk's KV straight into their freshly allocated pages.
    hit_runs: List[Tuple[List[int], int]] = dataclasses.field(
        default_factory=list)
    pg_segs: List[PagedSegment] = dataclasses.field(default_factory=list)
    # paged mode: ordered layout of the full sequence (see _PrefillResult).
    # seg_abs[i] is the absolute start position of compute segment i —
    # chunk mode scatters compute segments between shared runs, so the
    # cursor's q_start is seg_abs[seg_idx] + seg_off, not a running prefix.
    layout: List[Tuple[str, int, int]] = dataclasses.field(
        default_factory=list)
    seg_abs: List[int] = dataclasses.field(default_factory=list)
    miss_segs: List[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _Job:
    req: "_ReqRun"
    docs: Tuple[int, ...]
    speculative: bool
    enqueued: float
    cancelled: bool = False
    started: float = -1.0
    cs: Optional[_ChunkState] = None


@dataclasses.dataclass
class _ReqRun:
    r: Request
    tl: object                      # RequestTimeline
    spec: SpecState
    state: str = WAITING
    final_docs: Optional[Tuple[int, ...]] = None
    jobs: List[_Job] = dataclasses.field(default_factory=list)
    results: Dict[Tuple[int, ...], _PrefillResult] = dataclasses.field(
        default_factory=dict)
    start_by_docs: Dict[Tuple[int, ...], float] = dataclasses.field(
        default_factory=dict)
    # decode state: token-level slot mapping — position p of the request's
    # sequence lives at (pos_blk[p], pos_slot[p]) in the paged store
    pos_blk: List[int] = dataclasses.field(default_factory=list)
    pos_slot: List[int] = dataclasses.field(default_factory=list)
    owned_blocks: List[int] = dataclasses.field(default_factory=list)
    length: int = 0
    last_tok: int = 0
    tokens: List[int] = dataclasses.field(default_factory=list)
    remaining: int = 0
    exact: bool = True
    first_logits: Optional[np.ndarray] = None


@dataclasses.dataclass
class RuntimeResult:
    req_id: int
    tokens: List[int]
    ttft: float
    docs: Tuple[int, ...]
    alpha: int
    beta: int
    speculative_hit: bool
    # chunk-cache mode: False when a relocated chunk was reused (outputs are
    # approximate — verify with --check-tokens tol:<eps>); prefix mode and
    # full recomputes stay True (bit-identical contract holds).
    exact: bool = True
    first_logits: Optional[np.ndarray] = None   # (V,) logits at first token


class ContinuousRuntime:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        corpus: Corpus,
        index,
        *,
        config: Optional[EngineConfig] = None,
        n_blocks: Optional[int] = None,
        reorder_window: int = 32,
        profiler: Optional[CostProfiler] = None,
        **legacy,
    ):
        # ``config=`` is the SOLE constructor API (serving/config.py): one
        # frozen EngineConfig carries the whole knob surface, and any
        # pre-PR 7 loose kwarg raises a TypeError naming the config field
        # that replaced it.  ``n_blocks`` / ``reorder_window`` /
        # ``profiler`` stay explicit kwargs: they take test-only shapes or
        # live objects that don't belong in a CLI-round-trip config.
        reject_legacy_kwargs("ContinuousRuntime", legacy, EngineConfig)
        config = config if config is not None else EngineConfig()
        gpu_cache_bytes = config.gpu_cache_bytes
        host_cache_bytes = config.host_cache_bytes
        disk_cache_bytes = config.disk_cache_bytes
        disk_cache_dir = config.disk_cache_dir
        policy = config.policy
        top_k = config.top_k
        reorder = config.reorder
        speculative = config.speculative
        max_batch = config.max_batch
        max_prefill_bs = config.max_prefill_bs
        prefill_chunk = config.prefill_chunk
        max_prefill_tokens = config.max_prefill_tokens
        block_size = config.block_size
        attn = config.attn
        attn_impl = config.attn_impl
        reuse = config.reuse
        recompute_tokens = config.recompute_tokens
        search_time_scale = config.search_time_scale
        mesh = config.mesh
        if cfg.family in ("ssm", "hybrid"):
            raise ValueError(
                "recurrent-state families cannot be paged per-block; "
                "use the sequential RAGServer for ssm/hybrid")
        if attn not in ("dense", "paged", "auto"):
            raise ValueError(f"unknown attn mode {attn!r}")
        # "auto" resolves to the paged engine: Pallas kernel on TPU, the
        # pure-jnp per-page path elsewhere (kernels/ops.py dispatch).  The
        # dense gather survives only as the explicit --attn dense baseline.
        self.attn = "paged" if attn == "auto" else attn
        self.attn_impl = attn_impl
        if reuse not in ("prefix", "chunk"):
            raise ValueError(f"unknown reuse mode {reuse!r}")
        if reuse == "chunk" and self.attn != "paged":
            # relocated reuse needs per-run absolute positions in the run
            # table (boundary rows attend at their NEW positions over pages
            # cached elsewhere) — the dense gather has no such contract
            raise ValueError("--reuse chunk requires the paged engine "
                             "(--attn paged/auto)")
        self.reuse = reuse
        self.recompute_tokens = int(recompute_tokens)
        self.mode = config.mode
        if self.mode == "cag" and disk_cache_bytes <= 0:
            raise ValueError(
                "mode='cag' preloads the whole corpus KV into the disk tier "
                "and needs disk_cache_bytes > 0 sized for the corpus")
        self.cfg = cfg
        self.corpus = corpus
        self.index = index
        self.top_k = top_k
        self.search_time_scale = search_time_scale
        # ---- tensor parallelism (one replica spanning tp devices) --------
        # Params shard per launch/sharding.py::serving_param_shardings
        # (Megatron column rules; the two row matrices replicate — see the
        # deterministic-TP note there); the pool's (L, n_blocks, block, KV,
        # hd) planes shard whole KV heads over the "model" axis; block
        # tables / slot mappings / run tables stay replicated (they are
        # head-independent), so every scheduler/tree decision is identical
        # at any tp.  Model code traces under layers.tp_deterministic so
        # row-parallel contractions gather instead of all-reducing — the
        # bit-identical --check-tokens contract across mesh sizes.
        self.mesh_cfg = mesh or MeshConfig()
        self._mesh = None
        self._kv_sharding = None
        # CAG preloads compute each doc's KV through the single-device dense
        # prefill on the PRE-shard params (bit-identical to the sequential
        # oracle by construction); the sharded pool re-shards host copies on
        # promote, so the preloaded tier bytes work at any tp
        self._preload_params = params
        if self.mesh_cfg.tp > 1:
            assert_tp_compatible(cfg, self.mesh_cfg.tp)
            self._mesh = make_serving_mesh(self.mesh_cfg.tp)
            params = jax.device_put(
                params, serving_param_shardings(cfg, params, self._mesh))
            self._kv_sharding = NamedSharding(self._mesh, pool_kv_spec())
        self.params = params
        kv_bytes = (2 * cfg.n_layers * cfg.n_kv_heads * cfg.hd
                    * jnp.dtype(cfg.jdtype).itemsize)
        if n_blocks is None:
            n_blocks = int(np.clip(
                gpu_cache_bytes // (block_size * kv_bytes) + 64, 128, 4096))
        self.store = PagedKVStore(cfg.n_layers, n_blocks, block_size,
                                  cfg.n_kv_heads, cfg.hd,
                                  dtype=cfg.jdtype, device=True,
                                  kv_sharding=self._kv_sharding)
        self._scratch_block = self.store.pool.alloc(1)[0]  # dummy-row sink
        self.disk = make_disk_store(disk_cache_dir, disk_cache_bytes)
        self.tree = KnowledgeTree(
            gpu_cache_bytes, host_cache_bytes,
            disk_cache_bytes if self.disk is not None else 0,
            policy=policy,
            profiler=profiler or CostProfiler.from_fn(
                lambda a, b: 1e-4 * b + 2e-8 * b * (a + b),
                (0, 64, 256, 1024), (1, 32, 128, 512, 1024)),
            backend=(ShardedPagedBackend if self._kv_sharding is not None
                     else PagedBackend)(self.store, self.disk),
            bytes_per_token=max(kv_bytes, 1),
        )
        self.controller = RAGController(self.tree)
        self.spec_ctl = SpeculativeController(max_prefill_bs,
                                              enabled=speculative)
        self.max_new_tokens = 4       # refined per serve()
        self.admission = PagedAdmission(self.store.pool, self.tree,
                                        decode_reserve=self.max_new_tokens)
        self.sched: ContinuousBatchScheduler[_Job] = ContinuousBatchScheduler(
            SchedulerConfig(max_batch=max_batch,
                            max_prefill_bs=max_prefill_bs,
                            reorder=reorder, reorder_window=reorder_window,
                            prefill_chunk=prefill_chunk,
                            max_prefill_tokens=max_prefill_tokens),
            viable=self._job_viable, admit=self._job_admissible)
        self.metrics = ServingMetrics()
        self.metrics.prefill_token_budget = max_prefill_tokens
        self._partial_jobs: List[_Job] = []   # jobs with live chunk state
        self._prefill_fn = jax.jit(
            lambda p, toks, pc, pl: M.prefill(cfg, p, {"tokens": toks},
                                              prefix_cache=pc, prefix_len=pl),
            static_argnames=("pl",))
        # paged-prefill step: ragged chunk rows computed straight against
        # the (donated) pool planes — jit retraces per (B, Sq) bucket, like
        # the dense prefill retraces per (prefix_len, piece) shape
        _impl, _tp_mesh = attn_impl, self._mesh
        self._paged_prefill_fn = jax.jit(
            lambda p, toks, tb, cn, sts, qs, ql, wb, ws, kp, vp:
            M.paged_prefill_step(cfg, p, toks, kp, vp, tb, cn, sts, qs, ql,
                                 wb, ws, attn_impl=_impl, mesh=_tp_mesh),
            donate_argnums=(9, 10), **self._decode_jit_kw())
        self._decode_fn = None        # built in serve() once n_slots is known
        self._n_slots = 0
        self._n_tbl = 0               # run-table width (paged mode)
        # event loop
        self.now = 0.0
        self._events: List = []
        self._seq = itertools.count()
        self.engine_busy = False
        self.running: List[_ReqRun] = []   # decode-stage requests, FIFO
        self._force_decode = False         # progress guard after a
                                           # pagination failure (see below)
        self._all: List[_ReqRun] = []
        # CAG startup (docs/ARCHITECTURE.md §12): pre-insert the FULL corpus
        # KV into the disk tier.  Each doc's KV is computed at position 0
        # with no prefix — exactly what the engine computes for a doc served
        # first — so preloaded states are bit-identical to RAG-computed ones
        # and --check-tokens holds unchanged.
        self.preload_stats: Optional[dict] = None
        if self.mode == "cag":
            self.preload_stats = self.controller.preload_corpus(
                range(len(corpus.doc_lengths)), corpus.doc_lengths,
                self._corpus_payload)

    def _corpus_payload(self, doc_id: int, n_tokens: int) -> dict:
        """Host-layout (L, 1, T, KV, hd) {k, v} KV of one corpus doc,
        computed standalone through the dense prefill on pre-shard params."""
        toks = jnp.asarray(self.corpus.doc_tokens[doc_id])[None]
        _, cache = self._prefill_fn(self._preload_params, toks, None, 0)
        return {"k": np.asarray(cache["k"]), "v": np.asarray(cache["v"])}

    # ------------------------------------------------------------------
    # scheduler callbacks
    # ------------------------------------------------------------------

    def _job_viable(self, job: _Job) -> bool:
        return not job.cancelled and job.req.state == WAITING

    def _job_ctx_beta(self, job: _Job) -> Tuple[int, int, int]:
        """(context, beta, promote) token counts for one job: full sequence,
        to-be-computed tokens, and hit-prefix tokens NOT resident in GPU —
        a pinned path on host/disk still consumes GPU pin budget when the
        prefill promotes it (the admission check must see that)."""
        ctx = (sum(int(self.corpus.doc_lengths[d]) for d in job.docs)
               + len(job.req.r.question_tokens))
        if self.reuse == "chunk":
            cached = promote = 0
            for i, node in enumerate(self.tree.match_chunks(job.docs)):
                if node is None:
                    continue
                n_tok = int(self.corpus.doc_lengths[job.docs[i]])
                if node.exact_ctx and \
                        node.src_prefix == tuple(job.docs[:i]):
                    reused = n_tok
                else:
                    r = effective_recompute(self.recompute_tokens, n_tok,
                                            self.store.block_size)
                    reused = n_tok - r     # 0 when r covers the whole chunk
                cached += reused
                if reused and not node.in_gpu:
                    promote += node.n_tokens   # the whole node promotes
            return ctx, max(ctx - cached, 1), promote
        hit = self.tree.match_prefix(job.docs)
        cached = sum(n.n_tokens for n in hit)
        promote = sum(n.n_tokens for n in hit if not n.in_gpu)
        return ctx, max(ctx - cached, 1), promote

    def _job_admissible(self, job: _Job) -> bool:
        ctx, beta, promote = self._job_ctx_beta(job)
        return self.admission.admissible(ctx, beta, promote)

    def _job_lens(self, job: _Job) -> Tuple[int, int]:
        ctx, beta, _ = self._job_ctx_beta(job)
        return ctx - beta, beta

    # ------------------------------------------------------------------
    # event plumbing
    # ------------------------------------------------------------------

    def _push(self, t: float, kind: str, payload) -> None:
        heapq.heappush(self._events, (t, next(self._seq), kind, payload))

    # ------------------------------------------------------------------
    # serve
    # ------------------------------------------------------------------

    def serve(self, requests: Sequence[Request],
              max_new_tokens: int = 4) -> List[RuntimeResult]:
        self.max_new_tokens = max_new_tokens
        self.admission.decode_reserve = max_new_tokens
        max_doc = int(max(self.corpus.doc_lengths))
        max_q = max((len(r.question_tokens) for r in requests), default=8)
        max_ctx = self.top_k * max_doc + max_q + max_new_tokens
        n_slots = self.store.pool.blocks_for_tokens(max_ctx) + 1
        if n_slots > self.store.pool.n_blocks - 1:
            raise ValueError(
                f"paged pool too small: a worst-case request needs "
                f"{n_slots - 1} blocks but the pool has "
                f"{self.store.pool.n_blocks - 1} usable; raise n_blocks or "
                f"lower top_k/doc length")
        if n_slots != self._n_slots or self._decode_fn is None:
            self._n_slots = n_slots
            # paged mode reads runs, not a contiguous span: every segment of
            # the slot mapping (<= top_k shared docs + 1 private) may end
            # mid-block, wasting at most one table entry each.  Chunk mode
            # splits a relocated doc into boundary seg + shared tail, so up
            # to 2 entries per doc go to waste instead of 1.
            per_doc = 2 if self.reuse == "chunk" else 1
            self._n_tbl = n_slots + per_doc * self.top_k + 1
            self._build_decode_fn()
        first = len(self._all)
        for r in requests:
            self._push(max(r.arrival, self.now), "arrival", r)
        while self._events:
            self.now, _, kind, payload = heapq.heappop(self._events)
            getattr(self, f"_on_{kind}")(payload)
        unserved = [st.r.req_id for st in self._all[first:]
                    if st.state != FINISHED]
        if unserved:
            raise RuntimeError(
                f"requests {unserved} were never served (admission-starved "
                f"to the end of the event loop — pool or tree budget too "
                f"small for the workload)")
        out = []
        for st in self._all[first:]:
            out.append(RuntimeResult(
                req_id=st.r.req_id, tokens=list(st.tokens), ttft=st.tl.ttft,
                docs=st.final_docs or (), alpha=st.tl.alpha, beta=st.tl.beta,
                speculative_hit=st.tl.speculative_hit,
                exact=st.exact, first_logits=st.first_logits))
        out.sort(key=lambda x: x.req_id)
        return out

    # ------------------------------------------------------------------
    # arrivals & staged retrieval (host-CPU lanes, one per request)
    # ------------------------------------------------------------------

    def _on_arrival(self, r: Request) -> None:
        tl = self.metrics.timeline(next(self._seq), self.now)
        tl.req_id = r.req_id
        tl.search_start = self.now
        st = _ReqRun(r=r, tl=tl, spec=SpecState(r.req_id),
                     remaining=self.max_new_tokens)
        self._all.append(st)
        # per-request top_k override (Request.top_k > 0): the front door's
        # SLO admission degrades requests by lowering retrieval depth; both
        # engines honor it so degraded misses stay bit-identical under
        # --check-tokens.  Degradation only ever LOWERS top_k, so the
        # serve()-time max_ctx sizing (self.top_k) stays an upper bound.
        k = min(r.top_k, self.top_k) if r.top_k > 0 else self.top_k
        if self.mode == "cag":
            # ZERO retrieval stages (docs/ARCHITECTURE.md §12): the corpus
            # KV is already resident, so doc resolution is one synchronous
            # deterministic index probe, the retrieval/prefill-overlap
            # machinery degenerates (no stage events, no speculative
            # prefills, search_time identically 0), and the single final
            # job enters the scheduler at arrival.
            docs = tuple(int(d) for d in self.index.search(r.query_vec, k))
            st.tl.search_end = self.now
            st.final_docs = docs
            job = _Job(req=st, docs=docs, speculative=False,
                       enqueued=self.now)
            st.jobs.append(job)
            cached, compute = self._job_lens(job)
            self.sched.submit(job, cached, compute)
            self._prefetch_disk(docs)
            st.tl.queue_enter = self.now
            self._engine_kick()
            return
        # materialize stages, measuring the real scan cost of each stage;
        # the per-request search lane advances by max(measured, analytic)
        t = self.now
        it = iter(self.index.staged_search(r.query_vec, k))
        while True:
            t0 = time.perf_counter()
            try:
                stage = next(it)
            except StopIteration:
                break
            wall = time.perf_counter() - t0
            t += max(wall, stage.seconds) * self.search_time_scale
            self._push(t, "stage", (st, stage))

    def _on_stage(self, payload) -> None:
        st, stage = payload
        self.metrics.retrieval_stages += 1
        docs = tuple(stage.topk)
        if stage.is_final:
            st.tl.search_end = self.now
            st.final_docs = docs
        action, d = self.spec_ctl.on_stage(
            st.spec, docs, self.sched.pool_size(), is_final=stage.is_final)
        if action in ("terminate_and_launch", "terminate"):
            for job in st.jobs:
                if not job.cancelled and job.docs != docs:
                    job.cancelled = True
        if action in ("launch", "terminate_and_launch"):
            job = _Job(req=st, docs=d, speculative=not stage.is_final,
                       enqueued=self.now)
            st.jobs.append(job)
            cached, compute = self._job_lens(job)
            self.sched.submit(job, cached, compute)
            self._prefetch_disk(d)
            if not stage.is_final:
                self.metrics.spec_prefills += 1
        if stage.is_final:
            if st.tl.queue_enter < 0:
                st.tl.queue_enter = self.now
            self._maybe_finalize(st)
        self._engine_kick()

    def _prefetch_disk(self, docs: Tuple[int, ...]) -> None:
        """Overlap disk reads with the remaining retrieval stages (the same
        trick speculative prefill plays with compute, §5.3): as soon as a
        stage's top-k is known, stage any disk-only node of the matched
        prefix into host memory.  Disk I/O runs on host CPUs concurrently
        with the accelerator, so — like the staged search itself — it does
        not advance the engine clock; the later engine-critical promote
        becomes a pure host->GPU copy."""
        if self.disk is None:
            return
        if self.reuse == "chunk":
            hit = [n for n in self.tree.match_chunks(docs) if n is not None]
        else:
            hit = self.tree.match_prefix(docs)
        pinned = set(hit)   # staging node k must not re-spill node k-1
        for n in hit:
            if n.in_disk and not n.in_host and not n.in_gpu:
                before = self.tree.stats["fetch_bytes"]
                self.tree.fetch_to_host(n, pinned=pinned)
                moved = self.tree.stats["fetch_bytes"] - before
                if moved:
                    self.metrics.disk_prefetches += 1
                    self.metrics.disk_prefetch_bytes += moved

    def _maybe_finalize(self, st: _ReqRun) -> None:
        """Search done: if a prefill for the final docs already completed,
        the speculation paid off — emit the first token now."""
        if st.tl.first_token >= 0 or st.state != WAITING:
            return
        res = st.results.get(st.final_docs)
        if res is not None:
            self._first_token(st, res, max(self.now, st.tl.search_end))

    # ------------------------------------------------------------------
    # engine loop
    # ------------------------------------------------------------------

    def _engine_kick(self) -> None:
        while not self.engine_busy:
            self._sweep_stale_partials()
            self.admission.invalidate()   # fresh resource snapshot per kick
            if self._force_decode and self.running:
                # a pagination just failed on shared-block pressure: run one
                # decode iteration first so running requests make progress
                # toward releasing their tables (livelock guard)
                self._force_decode = False
                self._start_decode()
                return
            self._force_decode = False
            act = self.sched.next_action(len(self.running),
                                         refresh=self._job_lens)
            if act.kind == PREFILL:
                self._start_prefill_batch(act.chunks)
                return
            if act.kind == DECODE:
                self._start_decode()
                return
            if act.kind == PREEMPT:
                self._preempt_one()
                continue               # resources freed; re-evaluate
            return                     # IDLE

    def _sweep_stale_partials(self) -> None:
        """Chunk-boundary cancellation: a kick only runs between engine
        iterations, so any in-flight chunked prefill whose job went stale
        (terminated speculation, finished request) is aborted HERE — partial
        KV freed, hit nodes unpinned, remaining chunk tokens never computed
        (Alg. 2 "terminate after the current iteration", at chunk grain)."""
        for job in [j for j in self._partial_jobs
                    if not self._job_viable(j)]:
            self._abort_chunked(job)

    def _preempt_one(self) -> None:
        """Free the youngest running request and send it back to prefill
        (vLLM-style recompute preemption)."""
        victim = max(self.running, key=lambda s: s.tl.first_token)
        self.running.remove(victim)
        self._release_table(victim)
        victim.state = WAITING
        victim.tokens = []
        victim.remaining = self.max_new_tokens
        stale_res = victim.results.pop(victim.final_docs, None)
        if stale_res is not None:
            self._free_paged_kv(stale_res)
        victim.tl.first_token = -1.0    # recompute re-emits the first token
        victim.tl.token_times = []
        victim.tl.preemptions += 1
        self.metrics.preemptions += 1
        job = _Job(req=victim, docs=victim.final_docs, speculative=False,
                   enqueued=self.now)
        victim.jobs.append(job)
        cached, compute = self._job_lens(job)
        self.sched.submit(job, cached, compute)

    # ---- chunked + batched prefill -------------------------------------

    def _start_prefill_batch(self, chunks) -> None:
        """One engine iteration: execute the next chunk of every job the
        scheduler packed (ragged — chunk sizes differ per job).  Real
        compute is measured and billed as one iteration on the virtual
        clock; commit / first-token decisions happen at the completion
        event, so retrieval stages landing mid-iteration cancel at the
        chunk boundary, never mid-chunk."""
        self.engine_busy = True
        t0 = time.perf_counter()
        outcomes = []                  # (job, finished)
        executed = 0
        rows = []                      # paged mode: packed chunk rows
        for ch in chunks:
            job = ch.item
            if not self._job_viable(job):
                # went stale in this very event-loop instant; nothing ran
                if job.cs is not None:
                    self._abort_chunked(job)
                else:
                    self.sched.abort_prefill(job)
                continue
            if job.cs is None:
                self._begin_chunked(job)
            if self.attn == "paged":
                row = self._prep_paged_chunk(job)
                if row is None:
                    continue           # OutOfBlocks: job aborted + requeued
                rows.append(row)
                executed += row[-1]
            else:
                n = self._run_chunk(job)
                if n < 0:
                    continue           # paged partial hit OutOfBlocks: job
                                       # was aborted + requeued in-place
                executed += n
            outcomes.append((job, not job.cs.pieces))
        if rows:
            # ONE ragged batched call for every packed chunk — the batch is
            # row-independent (padding rows fully masked), so tokens are
            # identical whatever shares the iteration
            self._run_paged_rows(rows)
        dt = time.perf_counter() - t0
        if outcomes:
            # all-stale batches (every chunk went stale in this event-loop
            # instant) executed nothing: don't record a phantom iteration
            self.metrics.record_iteration("prefill", 1)
            self.metrics.record_prefill_batch(len(outcomes), executed)
        self._push(self.now + dt, "prefill_batch_done", outcomes)

    def _begin_chunked(self, job: _Job) -> None:
        """First chunk: plan the request, promote the hit prefix, and build
        the execution cursor.  Piece sizes come from the shared splitter so
        runtime, simulator and sequential engine chunk identically."""
        st = job.req
        job.started = self.now
        st.start_by_docs.setdefault(job.docs, self.now)
        doc_tokens = [int(self.corpus.doc_lengths[d]) for d in job.docs]
        if self.reuse == "chunk":
            plan = self.controller.plan_chunks(
                job.docs, doc_tokens, len(st.r.question_tokens),
                recompute_tokens=self.recompute_tokens,
                block_size=self.store.block_size)
        else:
            plan = self.controller.plan(job.docs, doc_tokens,
                                        len(st.r.question_tokens))
        self.controller.promote(plan)   # host->device pull
        if plan.chunks is not None:
            self._begin_chunk_layout(job, plan)
            return
        segs = [np.asarray(self.corpus.doc_tokens[job.docs[i]])
                for i in range(len(plan.hit_nodes), len(job.docs))]
        bounds, start = [], plan.alpha
        for s in segs:
            bounds.append((start, len(s)))
            start += len(s)
        segs.append(np.asarray(st.r.question_tokens))
        pieces = prefill_piece_sizes([len(s) for s in segs],
                                     self.sched.config.prefill_chunk)
        if not pieces:
            raise ValueError(
                f"request {st.r.req_id}: nothing to prefill (empty question "
                f"and fully cached documents) — no logits can be produced")
        if self.attn == "paged":
            # no dense gather of the hit prefix: snapshot its page runs and
            # refcount-share them (the nodes are also pinned until commit,
            # so the pages can be read in place for the whole prefill)
            hit_runs, layout, plen = [], [], 0
            for node in plan.hit_nodes:
                seg = node.payload_gpu
                self.store.share(seg)
                layout.append(("run", len(hit_runs), plen))
                hit_runs.append((list(seg.blocks), seg.n_tokens))
                plen += seg.n_tokens
            seg_abs, pos = [], plen
            for i, s in enumerate(segs):
                seg_abs.append(pos)
                layout.append(("seg", i, pos))
                pos += len(s)
            job.cs = _ChunkState(plan=plan, segs=segs, doc_bounds=bounds,
                                 pieces=pieces, total=sum(pieces), plen=plen)
            job.cs.hit_runs = hit_runs
            job.cs.layout = layout
            job.cs.seg_abs = seg_abs
            job.cs.pg_segs = [PagedSegment(self.store, [], 0) for _ in segs]
        else:
            prefix_hit, plen = self._assemble_prefix(plan.hit_nodes)
            job.cs = _ChunkState(plan=plan, segs=segs, doc_bounds=bounds,
                                 pieces=pieces, total=sum(pieces),
                                 plen=plen, prefix_hit=prefix_hit)
        self._partial_jobs.append(job)

    def _begin_chunk_layout(self, job: _Job, plan) -> None:
        """Chunk-cache twin of the prefix begin path (--reuse chunk): the
        request's sequence is an ORDERED INTERLEAVING of shared cached runs
        and to-compute segments.  Per doc position (ChunkItem):

          * exact — share the node's pages whole, like a prefix hit;
          * reloc — an owned boundary segment of ``recompute`` tokens (the
            doc head, recomputed at its NEW absolute position over the true
            preceding context) followed by the node's page-aligned TAIL
            pages, refcount-shared in place (stale RoPE — approximate);
          * miss — an owned segment computing the whole doc.

        The question is the final owned segment.  Compute segments sit at
        scattered absolute offsets, so each records its start (seg_abs)."""
        st = job.req
        bs = self.store.block_size
        segs: List[np.ndarray] = []
        seg_abs: List[int] = []
        layout: List[Tuple[str, int, int]] = []
        hit_runs: List[Tuple[List[int], int]] = []
        miss_segs: List[int] = []
        pos = 0
        for it in plan.chunks:
            if it.kind == "exact":
                seg = it.node.payload_gpu
                self.store.share(seg)
                layout.append(("run", len(hit_runs), pos))
                hit_runs.append((list(seg.blocks), seg.n_tokens))
                self.metrics.exact_chunk_hits += 1
            elif it.kind == "reloc":
                toks = np.asarray(self.corpus.doc_tokens[it.doc_id])
                segs.append(toks[:it.recompute])
                seg_abs.append(pos)
                layout.append(("seg", len(segs) - 1, pos))
                # recompute is page-aligned (effective_recompute), so the
                # reused tail starts at slot 0 of a block — the run-table /
                # decode-run contract every shared run must satisfy
                tail = list(it.node.payload_gpu.blocks[it.recompute // bs:])
                self.store.share_blocks(tail)
                layout.append(("run", len(hit_runs), pos + it.recompute))
                hit_runs.append((tail, it.n_tokens - it.recompute))
                self.metrics.reloc_chunk_hits += 1
                self.metrics.reloc_recompute_tokens += it.recompute
            else:
                segs.append(np.asarray(self.corpus.doc_tokens[it.doc_id]))
                seg_abs.append(pos)
                layout.append(("seg", len(segs) - 1, pos))
                miss_segs.append(len(segs) - 1)
            pos += it.n_tokens
        segs.append(np.asarray(st.r.question_tokens))
        seg_abs.append(pos)
        layout.append(("seg", len(segs) - 1, pos))
        seg_lens = [len(s) for s in segs]
        chunk = self.sched.config.prefill_chunk
        if chunk > 0:
            pieces = prefill_piece_sizes(seg_lens, chunk)
        else:
            # one piece per segment even unchunked: a piece's query rows are
            # CONSECUTIVE absolute positions (kernel q_start contract), and
            # here compute segments are separated by shared runs
            pieces = [int(n) for n in seg_lens if n > 0]
        if not pieces:
            raise ValueError(
                f"request {st.r.req_id}: nothing to prefill (empty question "
                f"and fully cached documents) — no logits can be produced")
        job.cs = _ChunkState(plan=plan, segs=segs, doc_bounds=[],
                             pieces=pieces, total=sum(pieces),
                             plen=plan.alpha)
        job.cs.hit_runs = hit_runs
        job.cs.layout = layout
        job.cs.seg_abs = seg_abs
        job.cs.miss_segs = miss_segs
        job.cs.pg_segs = [PagedSegment(self.store, [], 0) for _ in segs]
        self._partial_jobs.append(job)

    def _chunk_prefix(self, cs: _ChunkState) -> Tuple[Optional[dict], int]:
        """KV prefix for the next piece: the dense cached-prefix alone
        (first iteration), plus the partial KV gathered back out of the
        paged store on continuation iterations."""
        if cs.partial_seg is None:
            return cs.prefix_hit, cs.plen
        k, v = self.store.gather(cs.partial_seg)
        if cs.prefix_hit is None:
            return {"k": k, "v": v}, cs.plen
        return {"k": jnp.concatenate([cs.prefix_hit["k"], k], axis=2),
                "v": jnp.concatenate([cs.prefix_hit["v"], v], axis=2)}, cs.plen

    def _run_chunk(self, job: _Job) -> int:
        """Execute the next piece of ``job``'s prefill.  A piece never spans
        a segment boundary when chunking is enabled; with chunking disabled
        the single piece walks every segment (legacy one-iteration prefill).
        Returns tokens computed, or -1 if paging the partial KV failed and
        the job was aborted + requeued."""
        cs = job.cs
        n = cs.pieces.pop(0)
        multi_iter = bool(cs.pieces) or cs.partial_seg is not None
        prefix, plen = self._chunk_prefix(cs)
        plen0, left = plen, n
        logits = cache = None
        while left > 0:
            seg = cs.segs[cs.seg_idx]
            take = min(left, len(seg) - cs.seg_off)
            toks = jnp.asarray(seg[cs.seg_off:cs.seg_off + take])[None]
            with self._trace_ctx():
                logits, cache = self._prefill_fn(self.params, toks,
                                                 prefix, plen)
            prefix, plen = cache, plen + take
            cs.seg_off += take
            left -= take
            while cs.seg_idx < len(cs.segs) and \
                    cs.seg_off >= len(cs.segs[cs.seg_idx]):
                cs.seg_idx += 1
                cs.seg_off = 0
        jax.block_until_ready(logits)
        cs.plen = plen
        cs.logits = logits
        if not cs.pieces or not multi_iter:
            # final piece (or legacy single-iteration prefill): the carried
            # cache is the full sequence — keep it dense for commit/paginate
            cs.cache = cache
        else:
            # page the newly computed KV into the store so the only live
            # copy of the partial prefill is paged (cancellation frees it)
            k = cache["k"][:, :, plen0:plen]
            v = cache["v"][:, :, plen0:plen]
            nb = self.store.pool.blocks_for_tokens(plen - plen0)
            if not self._reclaim_blocks(nb):
                self._abort_chunked(job, requeue=True)
                return -1
            try:
                if cs.partial_seg is None:
                    cs.partial_seg = self.store.put(k, v)
                else:
                    self.store.append(cs.partial_seg, k, v)
            except OutOfBlocks:
                self._abort_chunked(job, requeue=True)
                return -1
        return n

    # ---- paged ragged prefill (no dense KV at any point) ---------------

    def _prep_paged_chunk(self, job: _Job):
        """Allocate pages for the next piece of ``job`` and build its row of
        the ragged batch: chunk tokens, their (block, slot) write coords,
        and the run table covering cached prefix + everything computed so
        far INCLUDING this chunk (causal masking over absolute positions
        keeps row i from seeing slots past it).  Returns None if the pool
        cannot hold the piece (job aborted + requeued in place)."""
        cs = job.cs
        n = cs.pieces.pop(0)
        while cs.seg_idx < len(cs.segs) and \
                cs.seg_off >= len(cs.segs[cs.seg_idx]):
            cs.seg_idx += 1          # skip empty segments before anchoring
            cs.seg_off = 0
        # the piece's rows are consecutive absolute positions anchored at
        # the cursor's segment (chunk mode scatters compute segments between
        # shared runs, so a running prefix length is NOT the position)
        q_start = cs.seg_abs[cs.seg_idx] + cs.seg_off
        toks = np.zeros(n, np.int32)
        wblk = np.full(n, self._scratch_block, np.int32)
        wslot = np.zeros(n, np.int32)
        off, left = 0, n
        while left > 0:
            seg = cs.segs[cs.seg_idx]
            pg = cs.pg_segs[cs.seg_idx]
            take = min(left, len(seg) - cs.seg_off)
            need = (self.store.pool.blocks_for_tokens(pg.n_tokens + take)
                    - len(pg.blocks))
            if need > 0 and not self._reclaim_blocks(need):
                self._abort_chunked(job, requeue=True)
                return None
            try:
                blk, slot = self.store.extend_alloc(pg, take)
            except OutOfBlocks:
                self._abort_chunked(job, requeue=True)
                return None
            toks[off:off + take] = seg[cs.seg_off:cs.seg_off + take]
            wblk[off:off + take] = blk
            wslot[off:off + take] = slot
            cs.seg_off += take
            off += take
            left -= take
            while cs.seg_idx < len(cs.segs) and \
                    cs.seg_off >= len(cs.segs[cs.seg_idx]):
                cs.seg_idx += 1
                cs.seg_off = 0
        cs.plen += n
        tables, counts, starts = self._paged_chunk_row(cs)
        return (job, toks, wblk, wslot, q_start, tables, counts, starts, n)

    def _paged_chunk_row(self, cs: _ChunkState):
        """Run-table row over the ordered sequence layout, same contract as
        decode (kernels/paged_attention.py): every entry starts at slot 0 of
        a fresh block, so runs are exactly the per-block spans.  Each entry
        carries its TRUE absolute start — with chunk-mode interleaving, a
        shared run can sit PAST a partially filled compute segment, and
        causal masking over absolute positions (not table order) is what
        keeps those later keys invisible to this piece's rows."""
        T = self._n_tbl
        bs = self.store.block_size
        tables = np.full(T, self._scratch_block, np.int32)
        counts = np.zeros(T, np.int32)
        starts = np.zeros(T, np.int32)
        j = 0
        for kind, idx, abs0 in cs.layout:
            if kind == "run":
                blocks, ntok = cs.hit_runs[idx]
            else:
                pg = cs.pg_segs[idx]
                blocks, ntok = pg.blocks, pg.n_tokens
            for bi, blk in enumerate(blocks):
                c = min(bs, ntok - bi * bs)
                if c <= 0:
                    break
                tables[j] = blk
                counts[j] = c
                starts[j] = abs0 + bi * bs
                j += 1
        assert j <= T, (j, T)
        return tables, counts, starts

    def _run_paged_rows(self, rows) -> None:
        """Execute one ragged batched paged-prefill iteration.  Rows pad to
        ``max_prefill_bs`` and chunk lengths to a power-of-two bucket (>= 8)
        to bound jit retraces; padding rows/tokens write into the scratch
        block and are fully masked (q_len), so every real row's output —
        and therefore every token — is independent of what shares the
        batch."""
        B = max(self.sched.config.max_prefill_bs, len(rows))
        Sq = max(8, 1 << (max(r[-1] for r in rows) - 1).bit_length())
        T = self._n_tbl
        toks = np.zeros((B, Sq), np.int32)
        wblk = np.full((B, Sq), self._scratch_block, np.int32)
        wslot = np.zeros((B, Sq), np.int32)
        tables = np.full((B, T), self._scratch_block, np.int32)
        counts = np.zeros((B, T), np.int32)
        starts = np.zeros((B, T), np.int32)
        q_start = np.zeros((B,), np.int32)
        q_len = np.zeros((B,), np.int32)
        for i, (job, t, wb, ws, qs, tb, cn, st_, n) in enumerate(rows):
            toks[i, :n] = t
            wblk[i, :n] = wb
            wslot[i, :n] = ws
            tables[i] = tb
            counts[i] = cn
            starts[i] = st_
            q_start[i] = qs
            q_len[i] = n
        with self._trace_ctx():
            logits, self.store.k, self.store.v = self._paged_prefill_fn(
                self.params, jnp.asarray(toks), jnp.asarray(tables),
                jnp.asarray(counts), jnp.asarray(starts),
                jnp.asarray(q_start), jnp.asarray(q_len),
                jnp.asarray(wblk), jnp.asarray(wslot),
                self.store.k, self.store.v)
        logits = jax.block_until_ready(logits)
        for i, row in enumerate(rows):
            row[0].cs.logits = logits[i:i + 1]       # (1, 1, V)

    def _on_prefill_batch_done(self, payload) -> None:
        self.engine_busy = False
        for job, finished in payload:
            st = job.req
            cs = job.cs
            if cs is None:
                continue               # aborted mid-iteration (requeue path)
            stale = job.cancelled or st.state != WAITING
            if not finished:
                if stale:
                    self._abort_chunked(job)
                else:
                    self.sched.note_chunk_done(job, cs.pieces)
                continue
            # prefill complete
            self.sched.note_chunk_done(job, [])
            pg_segs, hit_runs = cs.pg_segs, cs.hit_runs
            if not stale:
                # ownership of the paged state moves to the result BEFORE
                # _drop_chunk_state (which frees whatever is still attached)
                cs.pg_segs, cs.hit_runs = [], []
            self._drop_chunk_state(job)
            if stale:
                for n in cs.plan.hit_nodes:   # unpin without committing
                    n.pinned = False
                self.metrics.wasted_prefills += 1
                continue
            res = _PrefillResult(
                docs=job.docs, cache=cs.cache,
                first_token=int(jnp.argmax(cs.logits[0, -1])),
                total_len=cs.plen,
                alpha=cs.plan.alpha, beta=cs.plan.beta,
                hit_docs=cs.plan.hit_docs,
                hit_tier_tokens=cs.plan.hit_tier_tokens,
                speculative=job.speculative, started=job.started,
                hit_runs=hit_runs, pg_segs=pg_segs,
                layout=list(cs.layout), exact=cs.plan.exact,
                first_logits=np.asarray(cs.logits[0, -1]))
            if cs.plan.chunks is not None:
                self._commit_paged_chunks(
                    cs.plan, [pg_segs[i] for i in cs.miss_segs])
            elif self.attn == "paged":
                self._commit_paged(cs.plan, pg_segs[:len(cs.doc_bounds)])
            else:
                payloads = [(start, length, cs.cache)
                            for start, length in cs.doc_bounds]
                self._commit_payloads(cs.plan, payloads)
            st.results[job.docs] = res
            if st.final_docs is not None and job.docs == st.final_docs:
                self._first_token(st, res, max(self.now, st.tl.search_end))
        self._engine_kick()

    def _drop_chunk_state(self, job: _Job) -> None:
        cs = job.cs
        if cs is not None:
            if cs.partial_seg is not None:
                self.store.free(cs.partial_seg)
                cs.partial_seg = None
            self._free_paged_kv(cs)
        job.cs = None
        if job in self._partial_jobs:
            self._partial_jobs.remove(job)

    def _free_paged_kv(self, holder) -> None:
        """Drop a _ChunkState's or _PrefillResult's paged KV references:
        release the shared hit runs (one incref each) and free the owned
        segments.  No-op once ownership has transferred (lists emptied)."""
        for blocks, _ in holder.hit_runs:
            self.store.release(blocks)
        holder.hit_runs = []
        for pg in holder.pg_segs:
            if pg.blocks:
                self.store.free(pg)
        holder.pg_segs = []

    def _abort_chunked(self, job: _Job, requeue: bool = False) -> None:
        """Mid-prefill cancellation: free the partial KV, unpin the hit
        prefix, and account the chunk tokens that were never computed."""
        cs = job.cs
        saved = sum(cs.pieces) if cs is not None else 0
        if cs is not None:
            for n in cs.plan.hit_nodes:
                n.pinned = False
        self._drop_chunk_state(job)
        self.sched.abort_prefill(job)
        if not requeue:
            # a requeued job recomputes everything later — only genuine
            # cancellations (stale speculation / finished request) save work
            self.metrics.record_chunk_cancel(saved)
        if requeue:
            # paged-pool pressure, not staleness: recompute later — force a
            # decode iteration first so running requests free blocks
            job.cancelled = True
            self._force_decode = True
            redo = _Job(req=job.req, docs=job.docs,
                        speculative=job.speculative, enqueued=self.now)
            job.req.jobs.append(redo)
            cached, compute = self._job_lens(redo)
            self.sched.submit(redo, cached, compute)

    def _commit_payloads(self, plan, payloads) -> None:
        """Page the new per-doc KV segments into the store and insert them
        into the knowledge tree; stop caching at the first doc the pool
        cannot hold (graceful §8-style truncation)."""
        segs = []
        for (start, length, cache) in payloads:
            k = cache["k"][:, :, start:start + length]
            v = cache["v"][:, :, start:start + length]
            if not self._reclaim_blocks(self.store.pool.blocks_for_tokens(length)):
                break
            try:
                segs.append(self.store.put(k, v))
            except OutOfBlocks:
                break
        inserted = self.controller.commit(
            plan, segs, max_docs=len(plan.hit_nodes) + len(segs))
        # free every segment the tree did not take: the tail when insert
        # stopped early, and duplicates when a concurrent chunked prefill
        # committed the same doc path first (the tree keeps the incumbent)
        kept = {id(n.payload_gpu) for n in inserted}
        for seg in segs:
            if id(seg) not in kept:
                self.store.free(seg)

    def _commit_paged(self, plan, doc_segs) -> None:
        """Paged twin of ``_commit_payloads``: the per-doc KV already lives
        in pool blocks (the prefill kernel scattered it in place), so
        committing is pure refcounting — share each segment to mint the
        tree's independent reference, then drop it again for every segment
        the tree declined (duplicate doc path or insert stopped early)."""
        for seg in doc_segs:
            self.store.share(seg)
        inserted = self.controller.commit(
            plan, list(doc_segs), max_docs=len(plan.hit_nodes) + len(doc_segs))
        kept = {id(n.payload_gpu) for n in inserted}
        for seg in doc_segs:
            if id(seg) not in kept:
                self.store.release(seg.blocks)

    def _commit_paged_chunks(self, plan, doc_segs) -> None:
        """Chunk-mode commit (--reuse chunk): only MISS docs enter the flat
        chunk cache — the canonical entry for an exact/reloc hit is the node
        already resident, and relocated boundary segments stay request-
        private (their KV is position-specific).  Pure refcounting like
        ``_commit_paged``; declined segments return their extra ref."""
        for seg in doc_segs:
            self.store.share(seg)
        inserted = self.controller.commit_chunks(plan, list(doc_segs))
        kept = {id(n.payload_gpu) for n in inserted}
        for seg in doc_segs:
            if id(seg) not in kept:
                self.store.release(seg.blocks)

    def _reclaim_blocks(self, needed: int) -> bool:
        """Evict unpinned tree leaves (PGDSF order, shared Alg. 1 loop)
        until the pool has ``needed`` free blocks."""
        try:
            self.tree.evict_gpu_until(
                lambda: self.store.pool.free_blocks >= needed)
            return True
        except EvictionError:
            return False

    def _assemble_prefix(self, nodes) -> Tuple[Optional[dict], int]:
        if not nodes:
            return None, 0
        ks, vs = [], []
        for n in nodes:
            k, v = self.store.gather(n.payload_gpu)
            ks.append(k)
            vs.append(v)
        k = jnp.concatenate(ks, axis=2)
        return {"k": k, "v": jnp.concatenate(vs, axis=2)}, int(k.shape[2])

    # ---- first token & decode admission --------------------------------

    def _first_token(self, st: _ReqRun, res: _PrefillResult, t: float) -> None:
        tl = st.tl
        tl.first_token = t
        tl.prefill_end = t
        tl.alpha, tl.beta = res.alpha, res.beta
        tl.hit_docs = res.hit_docs
        (tl.hit_tokens_gpu, tl.hit_tokens_host,
         tl.hit_tokens_disk) = res.hit_tier_tokens
        tl.n_docs = len(res.docs)
        tl.docs = res.docs
        tl.speculative_hit = res.speculative or res.started < tl.search_end
        start = st.start_by_docs.get(res.docs)
        if start is not None:
            tl.final_prefill_start = start
        st.tokens = [res.first_token]
        st.exact = res.exact
        st.first_logits = res.first_logits
        st.remaining = self.max_new_tokens - 1
        for job in st.jobs:            # any other pending work is now moot
            if not job.cancelled and job.docs != res.docs:
                job.cancelled = True
        if st.remaining <= 0:
            self._finish(st, t)
            return
        if not self._paginate(st, res):
            # pool pressure raced us between admission and join: retry later
            self._requeue_after_pagination_failure(st)
            return
        st.state = RUNNING
        st.last_tok = res.first_token
        self.running.append(st)

    def _requeue_after_pagination_failure(self, st: _ReqRun) -> None:
        res = st.results.pop(st.final_docs, None)
        if res is not None:
            self._free_paged_kv(res)
        st.tokens = []
        st.tl.first_token = -1.0       # not actually servable yet
        self._force_decode = True      # guarantee decode progress before
                                       # this job can be re-popped
        job = _Job(req=st, docs=st.final_docs, speculative=False,
                   enqueued=self.now)
        st.jobs.append(job)
        cached, compute = self._job_lens(job)
        self.sched.submit(job, cached, compute)

    def _paginate(self, st: _ReqRun, res: _PrefillResult) -> bool:
        """Build the request's decode slot mapping: refcount-share EVERY
        complete GPU-resident knowledge-tree prefix node — block-aligned or
        not; the token-level (block, slot) mapping absorbs unaligned doc
        tails, so a 20-token doc in 16-token blocks shares both its blocks
        and the next doc's tokens simply start in a fresh block — and copy
        the rest (uncached docs + question) into private blocks with decode
        reserve."""
        if self.attn == "paged" and (res.pg_segs or res.hit_runs):
            return self._paginate_paged(st, res)
        bs = self.store.block_size
        pos_blk: List[int] = []
        pos_slot: List[int] = []
        shared: List[int] = []
        offset = 0
        for node in self.tree.match_prefix(res.docs):
            seg = node.payload_gpu
            if (seg is None or not node.in_gpu
                    or seg.n_tokens != node.n_tokens):
                break
            self.store.share(seg)
            for i in range(seg.n_tokens):
                pos_blk.append(seg.blocks[i // bs])
                pos_slot.append(i % bs)
            shared.extend(seg.blocks)
            offset += seg.n_tokens
        rest = res.total_len - offset
        k = res.cache["k"][:, :, offset:res.total_len]
        v = res.cache["v"][:, :, offset:res.total_len]
        need = self.store.pool.blocks_for_tokens(rest + st.remaining)
        if not self._reclaim_blocks(need):
            self.store.release(shared)
            return False
        try:
            priv = self.store.put(k, v, reserve_tokens=st.remaining)
        except OutOfBlocks:
            self.store.release(shared)
            return False
        for i in range(rest + st.remaining):
            pos_blk.append(priv.blocks[i // bs])
            pos_slot.append(i % bs)
        st.pos_blk, st.pos_slot = pos_blk, pos_slot
        st.owned_blocks = shared + priv.blocks
        st.length = res.total_len
        self.metrics.blocks_shared += len(shared)
        self.metrics.blocks_copied += len(priv.blocks)
        return True

    def _paginate_paged(self, st: _ReqRun, res: _PrefillResult) -> bool:
        """Paged twin of ``_paginate``: every token already sits in a pool
        block — the cached prefix in the shared hit runs, the rest in the
        result's owned segments — so building the decode slot mapping is
        pure bookkeeping plus one allocation-only extension of the question
        segment for the decode reserve.  On success the result's references
        transfer wholesale to ``st.owned_blocks`` (lists emptied); on
        failure ``res`` is left untouched for the requeue path to free."""
        bs = self.store.block_size
        qseg = res.pg_segs[-1]
        need = (self.store.pool.blocks_for_tokens(qseg.n_tokens + st.remaining)
                - len(qseg.blocks))
        if need > 0 and not self._reclaim_blocks(need):
            return False
        try:
            self.store.extend_alloc(qseg, st.remaining)
        except OutOfBlocks:
            return False
        pos_blk: List[int] = []
        pos_slot: List[int] = []
        shared: List[int] = []
        owned: List[int] = []
        # walk the ordered layout — prefix mode is runs-then-segs, chunk
        # mode interleaves them; either way entries appear in absolute
        # position order, so appending yields the position->slot mapping
        for kind, idx, _ in res.layout:
            if kind == "run":
                blocks, n_tokens = res.hit_runs[idx]
                for i in range(n_tokens):
                    pos_blk.append(blocks[i // bs])
                    pos_slot.append(i % bs)
                shared.extend(blocks)
            else:
                pg = res.pg_segs[idx]
                for i in range(pg.n_tokens):
                    pos_blk.append(pg.blocks[i // bs])
                    pos_slot.append(i % bs)
                owned.extend(pg.blocks)
        st.pos_blk, st.pos_slot = pos_blk, pos_slot
        st.owned_blocks = shared + owned
        st.length = res.total_len
        self.metrics.blocks_shared += len(shared)
        self.metrics.blocks_copied += len(owned)
        res.pg_segs, res.hit_runs = [], []    # ownership moved to the table
        return True

    def _release_table(self, st: _ReqRun) -> None:
        if st.owned_blocks:
            self.store.release(st.owned_blocks)
        st.pos_blk, st.pos_slot, st.owned_blocks = [], [], []
        st.length = 0

    # ---- batched decode ------------------------------------------------

    def _build_decode_fn(self) -> None:
        if self.attn == "paged":
            self._build_paged_decode_fn()
        else:
            self._build_dense_decode_fn()

    def _trace_ctx(self):
        """Context for every call that may TRACE model code: under TP,
        layers.tp_deterministic makes row-parallel contractions gather
        their activations instead of lowering to a partial-sum all-reduce
        (the one mesh-size-dependent float reduction).  jit caches the
        traced computation, so wrapping the calls — not just the first —
        is belt-and-braces for new shape signatures."""
        return (L.tp_deterministic(self._mesh) if self._mesh is not None
                else contextlib.nullcontext())

    def _decode_jit_kw(self) -> dict:
        """Under TP, pin the decode step's output shardings: tokens come
        back replicated (the host event loop reads them), and the pool
        planes keep the pool's own KV-head sharding so the (8, 9) donation
        reuses the sharded buffers in place instead of silently copying."""
        if self._kv_sharding is None:
            return {}
        rep = NamedSharding(self._mesh, PartitionSpec())
        return {"out_shardings": (rep, self._kv_sharding,
                                  self._kv_sharding)}

    def _build_paged_decode_fn(self) -> None:
        """Decode attention straight from the pool's page arrays: per-layer
        paged attention through run tables (kernels/ops.py dispatch — Pallas
        on TPU, per-page jnp online softmax on CPU), new-token KV appended
        in place at its (block, slot).  Nothing here scales with the dense
        max-context span S — the steady-state iteration touches live pages
        only."""
        cfg = self.cfg
        impl = self.attn_impl
        tp_mesh = self._mesh

        def step(params, toks, tables, counts, starts, pos,
                 write_blk, write_slot, k_pages, v_pages):
            logits, k_pages, v_pages = M.paged_decode_step(
                cfg, params, toks, k_pages, v_pages, tables, counts, starts,
                write_blk, write_slot, pos, attn_impl=impl, mesh=tp_mesh)
            return jnp.argmax(logits[:, -1], axis=-1), k_pages, v_pages

        self._decode_fn = jax.jit(step, donate_argnums=(8, 9),
                                  **self._decode_jit_kw())
        # warm up the single decode shape (dummy rows decode token 0 into
        # the scratch block, exactly like a padding row in _start_decode)
        args = self._paged_decode_args([])
        with self._trace_ctx():
            _, self.store.k, self.store.v = self._decode_fn(
                self.params, *args, self.store.k, self.store.v)
        jax.block_until_ready(self.store.k)

    def _paged_decode_args(self, batch):
        """Pack the run tables for one paged decode iteration.  Contract
        (kernels/paged_attention.py): the slot mapping is a list of runs,
        each starting at slot 0 of its block — run boundaries are exactly
        the positions with pos_slot == 0."""
        B = self.sched.config.max_batch
        T = self._n_tbl
        toks = np.zeros((B, 1), np.int32)
        tables = np.full((B, T), self._scratch_block, np.int32)
        counts = np.zeros((B, T), np.int32)
        starts = np.zeros((B, T), np.int32)
        pos = np.ones((B,), np.int32)
        write_blk = np.full((B,), self._scratch_block, np.int32)
        write_slot = np.zeros((B,), np.int32)
        counts[:, 0] = 1               # dummy rows attend their scratch write
        for i, st in enumerate(batch):
            n = st.length + 1          # incl. the token decoded this step
            blk = np.asarray(st.pos_blk[:n], np.int32)
            slot = np.asarray(st.pos_slot[:n], np.int32)
            run = np.flatnonzero(slot == 0)
            assert len(run) <= T, (len(run), T)
            counts[i] = 0
            tables[i, :len(run)] = blk[run]
            counts[i, :len(run)] = np.diff(np.append(run, n))
            starts[i, :len(run)] = run
            pos[i] = n
            toks[i, 0] = st.last_tok
            write_blk[i] = st.pos_blk[st.length]
            write_slot[i] = st.pos_slot[st.length]
        return (jnp.asarray(toks), jnp.asarray(tables), jnp.asarray(counts),
                jnp.asarray(starts), jnp.asarray(pos),
                jnp.asarray(write_blk), jnp.asarray(write_slot))

    def _build_dense_decode_fn(self) -> None:
        cfg = self.cfg
        B = self.sched.config.max_batch
        S = self._n_slots * self.store.block_size   # max token positions

        def step(params, toks, blk_map, slot_map, lengths, k_pages, v_pages):
            # token-level slot mapping (vLLM-style slot_mapping): position p
            # of request b lives at (blk_map[b, p], slot_map[b, p]), so the
            # gathered dense sequence is hole-free even when shared tree
            # segments end mid-block — sharing needs no block alignment
            k = k_pages[:, blk_map, slot_map]       # (L, B, S, KV, hd)
            v = v_pages[:, blk_map, slot_map]
            logits, new = M.decode_step(cfg, params, toks,
                                        {"k": k, "v": v}, lengths + 1)
            bidx = jnp.arange(B)
            newk = new["k"][:, bidx, lengths]          # (L, B, KV, hd)
            newv = new["v"][:, bidx, lengths]
            blk = blk_map[bidx, lengths]
            slot = slot_map[bidx, lengths]
            k_pages = k_pages.at[:, blk, slot].set(newk.astype(k_pages.dtype))
            v_pages = v_pages.at[:, blk, slot].set(newv.astype(v_pages.dtype))
            return jnp.argmax(logits[:, -1], axis=-1), k_pages, v_pages

        self._decode_fn = jax.jit(step, donate_argnums=(5, 6),
                                  **self._decode_jit_kw())
        # warm up the single decode shape so its compile never lands on the
        # serving clock (all dummy rows write into the scratch block)
        toks = jnp.zeros((B, 1), jnp.int32)
        blk_map = jnp.full((B, S), self._scratch_block, jnp.int32)
        slot_map = jnp.zeros((B, S), jnp.int32)
        lengths = jnp.zeros((B,), jnp.int32)
        with self._trace_ctx():
            _, self.store.k, self.store.v = self._decode_fn(
                self.params, toks, blk_map, slot_map, lengths,
                self.store.k, self.store.v)
        jax.block_until_ready(self.store.k)

    def _start_decode(self) -> None:
        batch = self.running[:self.sched.config.max_batch]
        self.engine_busy = True
        self.metrics.record_iteration("decode", len(batch))
        t0 = time.perf_counter()
        if self.attn == "paged":
            args = self._paged_decode_args(batch)
            with self._trace_ctx():
                next_toks, self.store.k, self.store.v = self._decode_fn(
                    self.params, *args, self.store.k, self.store.v)
        else:
            with self._trace_ctx():
                next_toks, self.store.k, self.store.v = self._decode_fn(
                    self.params, *self._dense_decode_args(batch),
                    self.store.k, self.store.v)
        next_toks = np.asarray(jax.block_until_ready(next_toks))
        dt = time.perf_counter() - t0
        self._push(self.now + dt, "decode_done",
                   (batch, [int(t) for t in next_toks[:len(batch)]]))

    def _dense_decode_args(self, batch):
        B = self.sched.config.max_batch
        S = self._n_slots * self.store.block_size
        toks = np.zeros((B, 1), np.int32)
        blk_map = np.full((B, S), self._scratch_block, np.int32)
        slot_map = np.zeros((B, S), np.int32)
        lengths = np.zeros((B,), np.int32)
        for i, st in enumerate(batch):
            toks[i, 0] = st.last_tok
            blk_map[i, :len(st.pos_blk)] = st.pos_blk
            slot_map[i, :len(st.pos_slot)] = st.pos_slot
            lengths[i] = st.length
        return (jnp.asarray(toks), jnp.asarray(blk_map),
                jnp.asarray(slot_map), jnp.asarray(lengths))

    def _on_decode_done(self, payload) -> None:
        batch, toks = payload
        self.engine_busy = False
        for st, tok in zip(batch, toks):
            if st.state != RUNNING:     # preempted meanwhile
                continue
            st.tokens.append(tok)
            st.last_tok = tok
            st.length += 1
            st.remaining -= 1
            st.tl.token_times.append(self.now)
            if st.remaining <= 0:
                self.running.remove(st)
                self._release_table(st)
                self._finish(st, self.now)
        self._engine_kick()

    def _finish(self, st: _ReqRun, t: float) -> None:
        st.state = FINISHED
        st.tl.finish = t
        st.tl.tokens = list(st.tokens)
        for job in st.jobs:
            job.cancelled = True
        # drop the prefill results (incl. wasted speculations) — the paged
        # store/tree is the only KV owner after a request completes; paged
        # results still hold refcounts that must be returned to the pool
        for res in st.results.values():
            self._free_paged_kv(res)
        st.results = {}
        st.jobs = []
