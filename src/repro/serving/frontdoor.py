"""Front-door request layer: query-level cache, SLO-aware admission, fleet
autoscaling — the subsystem AHEAD of the ``ReplicaRouter``.

RAGCache caches the *KV states* of retrieved knowledge; at millions of
users many requests should never reach an engine at all.  Real QA traffic
repeats itself (the query-cache pattern in SNIPPETS.md §1), so the front
door answers three questions per arriving request, in order:

  1. **Have we answered this exact query recently?**  ``QueryCache`` keys
     an FNV-1a hash of the question tokens; a live (non-expired) entry
     serves the cached retrieval result + finished answer with no engine
     work at all.
  2. **Have we answered a near-duplicate?**  The same cache holds each
     cached query's embedding vector; a cosine probe at/above
     ``sim_threshold`` serves the cached entry too.  Similarity hits are
     *approximate by contract*: the cached answer belongs to a semantically
     close query, and the TTL bounds how stale either hit can be.
  3. **Can the fleet afford this miss right now?**  ``SloAdmission``
     predicts TTFT from the current backlog and an EWMA of observed
     service times; when the prediction exceeds the request's per-tenant
     target it first *degrades* (lowers the request's ``top_k`` toward the
     tenant's floor — less context, faster prefill), and *sheds* only when
     even the floor cannot meet a multiple of the target.

``FleetAutoscaler`` closes the loop: it grows/shrinks the ACTIVE replica
count within ``[min_replicas, max_replicas]`` against backlog/TTFT
signals (hysteresis + cooldown so bursts don't thrash), and every
scale-up warms the joining replica by seeding its knowledge tree from its
disk tier (``warm_from_disk``: disk-resident nodes staged into host
memory, so the first requests pay a host->GPU copy instead of a
recompute).  Scale-down never destroys a replica — it stops routing to it,
and the replica's tree (including its disk tier) stays warm for the next
scale-up.

Policy cannot drift between simulation and the real runtime: the SAME
``FrontDoor``/``SloAdmission``/``FleetAutoscaler`` objects are driven by
``serving/simulator.py::simulate_frontdoor`` over ``RAGSimulator``
replicas and by ``launch/serve.py --frontdoor`` over real
``ContinuousRuntime`` replicas, through the shared
``frontdoor_partition`` trace walk below (the PR 1/PR 4 shared-policy
pattern; asserted by tests/test_frontdoor.py).

Front-door hits never change engine computation — they bypass it — and
misses are forwarded with an explicit per-request ``top_k``, so
``--check-tokens`` stays bit-identical for every miss at any replica
count (degraded misses included: both engines honor ``Request.top_k``).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.retrieval.corpus import Request

# lookup kinds
HIT_EXACT = "hit_exact"
HIT_SIMILAR = "hit_similar"
MISS = "miss"
# admission actions
ADMIT = "admit"
DEGRADE = "degrade"
SHED = "shed"


def query_key(question_tokens) -> int:
    """FNV-1a over the question-token bytes: deterministic across processes
    (unlike salted ``hash``), so cache behavior is reproducible."""
    h = 0xcbf29ce484222325
    for t in np.asarray(question_tokens).ravel():
        h ^= (int(t) + 1) & 0xffffffffffffffff
        h = (h * 0x100000001b3) & 0xffffffffffffffff
    return h


@dataclasses.dataclass
class CacheEntry:
    key: int
    vec: np.ndarray                # unit-normalized query embedding
    docs: Tuple[int, ...]          # cached retrieval result
    answer: List[int]              # finished answer tokens
    source_req_id: int             # request that produced the entry
    created: float                 # insertion time (TTL anchors here —
    #                                a hit never refreshes freshness, so
    #                                staleness is bounded by exactly ttl)
    top_k: int                     # effective retrieval depth the answer
    #                                was generated with (>= 1, always
    #                                recorded explicitly); a lookup
    #                                demanding more depth must NOT be
    #                                served this entry


class QueryCache:
    """Exact + embedding-similarity request cache with TTL expiry and an
    LRU capacity bound.

    Exact hits key the FNV-1a hash of the question tokens; similarity hits
    cosine-probe the cached (unit-normalized) query vectors and serve the
    best entry at/above ``sim_threshold``.  ``sim_threshold >= 1.0``
    disables the similarity probe (exact-only).  Entries expire ``ttl``
    seconds after INSERTION regardless of use, and the LRU bound evicts
    the least-recently-HIT entry first — recency of use keeps an entry
    resident, but never extends its freshness.
    """

    def __init__(self, *, capacity: int = 1024, ttl: float = 60.0,
                 sim_threshold: float = 0.98):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if not 0.0 < ttl:
            raise ValueError("ttl must be positive")
        self.capacity = capacity
        self.ttl = ttl
        self.sim_threshold = sim_threshold
        self._entries: "OrderedDict[int, CacheEntry]" = OrderedDict()
        self._mat: Optional[np.ndarray] = None   # stacked vecs, rebuilt lazily
        self._mat_keys: List[int] = []
        self.hits_exact = 0
        self.hits_similar = 0
        self.misses = 0
        self.expired = 0
        self.evicted = 0
        self.depth_filtered = 0    # live entries skipped: cached top_k too
        #                            shallow for the lookup's required depth

    def __len__(self) -> int:
        return len(self._entries)

    def _invalidate_mat(self) -> None:
        self._mat = None
        self._mat_keys = []

    def _expire(self, now: float) -> None:
        stale = [k for k, e in self._entries.items()
                 if e.created + self.ttl <= now]
        for k in stale:
            del self._entries[k]
            self.expired += 1
        if stale:
            self._invalidate_mat()

    def lookup(self, query_vec: np.ndarray, question_tokens,
               now: float, *, min_top_k: int = 0
               ) -> Tuple[str, Optional[CacheEntry]]:
        """(kind, entry): kind is HIT_EXACT / HIT_SIMILAR / MISS.  Expired
        entries are reclaimed first, so they can never be served.

        ``min_top_k``: required retrieval depth — an entry whose recorded
        ``top_k`` is below it is invisible to BOTH probes (a degraded
        tenant's answer must never serve a full-depth request).  Every
        entry records its effective depth explicitly (>= 1), so there is
        no unknown/legacy case to special-case here.
        """
        self._expire(now)
        key = query_key(question_tokens)
        entry = self._entries.get(key)
        if entry is not None:
            if entry.top_k >= min_top_k:
                self._entries.move_to_end(key)
                self.hits_exact += 1
                return HIT_EXACT, entry
            self.depth_filtered += 1   # too shallow: fall through to the
            #                            similarity probe / miss
        if self.sim_threshold < 1.0 and self._entries:
            if self._mat is None:
                self._mat_keys = list(self._entries)
                self._mat = np.stack(
                    [self._entries[k].vec for k in self._mat_keys])
            q = np.asarray(query_vec, np.float32)
            q = q / max(float(np.linalg.norm(q)), 1e-12)
            sims = self._mat @ q
            if min_top_k > 0:
                ok = np.asarray(
                    [self._entries[k].top_k >= min_top_k
                     for k in self._mat_keys])
                sims = np.where(ok, sims, -np.inf)
            best = int(np.argmax(sims))
            if float(sims[best]) >= self.sim_threshold:
                k = self._mat_keys[best]
                self._entries.move_to_end(k)
                self.hits_similar += 1
                return HIT_SIMILAR, self._entries[k]
        self.misses += 1
        return MISS, None

    def insert(self, query_vec: np.ndarray, question_tokens,
               docs: Sequence[int], answer: Sequence[int],
               source_req_id: int, now: float, *,
               top_k: int) -> CacheEntry:
        if top_k < 1:
            raise ValueError(
                "CacheEntry.top_k records the EFFECTIVE retrieval depth and "
                "must be >= 1 (the 0 = unknown/legacy sentinel is retired)")
        self._expire(now)
        key = query_key(question_tokens)
        vec = np.asarray(query_vec, np.float32)
        vec = vec / max(float(np.linalg.norm(vec)), 1e-12)
        entry = CacheEntry(key=key, vec=vec, docs=tuple(int(d) for d in docs),
                           answer=[int(t) for t in answer],
                           source_req_id=source_req_id, created=now,
                           top_k=int(top_k))
        self._entries[key] = entry      # re-insert refreshes freshness
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evicted += 1
        self._invalidate_mat()
        return entry

    def stats(self) -> Dict[str, int]:
        return {
            "size": len(self._entries),
            "capacity": self.capacity,
            "hits_exact": self.hits_exact,
            "hits_similar": self.hits_similar,
            "misses": self.misses,
            "expired": self.expired,
            "evicted": self.evicted,
            "depth_filtered": self.depth_filtered,
        }


@dataclasses.dataclass
class TenantSLO:
    ttft_target: float             # seconds
    min_top_k: int = 1             # degrade floor


@dataclasses.dataclass
class AdmissionDecision:
    action: str                    # ADMIT / DEGRADE / SHED
    top_k: int                     # effective retrieval depth for the engine
    predicted_ttft: float


class SloAdmission:
    """Per-tenant SLO-aware admission: shed or degrade when predicted TTFT
    exceeds the tenant's target.

    Predicted TTFT = (backlog / active_replicas + 1) * service-time EWMA:
    the request waits behind its share of the fleet backlog (the QUEUEING
    term), then pays one service time itself (the SERVICE term).
    Degrading lowers the request's ``top_k`` — prefill cost is roughly
    linear in retrieved context, so serving k' of k docs scales the
    predicted SERVICE term by k'/k; the queueing term is other requests'
    work and does not shrink when this one retrieves fewer docs.  If even
    the tenant's ``min_top_k`` floor predicts more than ``shed_factor``
    x target, the request is shed (a deliberate hysteresis band: between
    1x and ``shed_factor`` x target the degraded floor is still admitted,
    so a cold or noisy service estimate sheds nothing).  A deep backlog is
    therefore shed, never "degraded away": no value of k' can scale the
    queueing term below the target."""

    def __init__(self, slos: Dict[str, TenantSLO], *,
                 default: Optional[TenantSLO] = None, top_k: int = 2,
                 shed_factor: float = 2.0, ewma_alpha: float = 0.2,
                 init_service: float = 0.05):
        if top_k < 1:
            raise ValueError("top_k must be >= 1")
        self.slos = dict(slos)
        self.default = default or TenantSLO(ttft_target=0.5)
        self.top_k = top_k
        self.shed_factor = shed_factor
        self.ewma_alpha = ewma_alpha
        self.service_est = init_service   # EWMA of observed per-request TTFT
        self.decisions: Dict[str, int] = {ADMIT: 0, DEGRADE: 0, SHED: 0}

    def slo_of(self, tenant: str) -> TenantSLO:
        return self.slos.get(tenant, self.default)

    def predicted_ttft(self, backlog: int, active: int) -> float:
        return (backlog / max(active, 1) + 1.0) * self.service_est

    def decide(self, tenant: str, backlog: int,
               active: int) -> AdmissionDecision:
        slo = self.slo_of(tenant)
        # queueing: waiting behind other requests' (full-depth) work —
        # invariant under THIS request's top_k.  service: this request's
        # own prefill, the only part degrading can shrink.
        queue = (backlog / max(active, 1)) * self.service_est
        service = self.service_est
        pred = queue + service
        k = self.top_k
        if pred <= slo.ttft_target:
            self.decisions[ADMIT] += 1
            return AdmissionDecision(ADMIT, k, pred)
        floor = max(1, min(slo.min_top_k, self.top_k))
        while k > floor and \
                queue + service * k / self.top_k > slo.ttft_target:
            k -= 1
        if queue + service * k / self.top_k \
                > self.shed_factor * slo.ttft_target:
            self.decisions[SHED] += 1
            return AdmissionDecision(SHED, 0, pred)
        action = DEGRADE if k < self.top_k else ADMIT
        self.decisions[action] += 1
        return AdmissionDecision(action, k, pred)

    def observe_ttft(self, ttft: float) -> None:
        if ttft >= 0:
            a = self.ewma_alpha
            self.service_est = (1 - a) * self.service_est + a * ttft


@dataclasses.dataclass
class AutoscaleConfig:
    min_replicas: int = 1
    max_replicas: int = 1
    scale_up_backlog: float = 8.0   # backlog PER ACTIVE replica above which
    #                                 the fleet grows
    scale_down_backlog: float = 2.0  # per-replica backlog below which it
    #                                  shrinks (hysteresis band between)
    target_ttft: float = 0.0        # optional TTFT trigger (0 = backlog-only):
    #                                 grow when the service EWMA-based
    #                                 prediction exceeds this
    cooldown: float = 2.0           # seconds between scale events

    def __post_init__(self):
        if self.min_replicas < 1 or self.max_replicas < self.min_replicas:
            raise ValueError("need 1 <= min_replicas <= max_replicas")
        if self.scale_down_backlog > self.scale_up_backlog:
            raise ValueError("scale_down_backlog must be <= scale_up_backlog")


@dataclasses.dataclass
class ScaleEvent:
    t: float
    active: int                    # fleet size AFTER the event
    reason: str


class FleetAutoscaler:
    """Grows/shrinks the ACTIVE replica count within configured bounds
    against queue-depth / predicted-TTFT signals.  Pure policy: the caller
    (``frontdoor_partition``) applies the returned count to the router's
    active set and warms joining replicas."""

    def __init__(self, cfg: AutoscaleConfig):
        self.cfg = cfg
        self.active = cfg.min_replicas
        self.events: List[ScaleEvent] = []
        self.min_seen = self.active
        self.max_seen = self.active
        self._last_event = -1e18

    def observe(self, now: float, backlog: int,
                predicted_ttft: float = 0.0) -> int:
        """Feed one load sample; returns the (possibly new) active count."""
        cfg = self.cfg
        if now - self._last_event < cfg.cooldown:
            return self.active
        per = backlog / max(self.active, 1)
        if self.active < cfg.max_replicas and (
                per > cfg.scale_up_backlog
                or (cfg.target_ttft > 0.0
                    and predicted_ttft > cfg.target_ttft)):
            self.active += 1
            why = (f"backlog/replica {per:.1f} > {cfg.scale_up_backlog}"
                   if per > cfg.scale_up_backlog else
                   f"pred TTFT {predicted_ttft * 1e3:.0f}ms > "
                   f"{cfg.target_ttft * 1e3:.0f}ms")
            self.events.append(ScaleEvent(now, self.active, f"up: {why}"))
            self._last_event = now
        elif self.active > cfg.min_replicas and per < cfg.scale_down_backlog \
                and (cfg.target_ttft <= 0.0
                     or predicted_ttft <= cfg.target_ttft):
            self.active -= 1
            self.events.append(ScaleEvent(
                now, self.active,
                f"down: backlog/replica {per:.1f} < "
                f"{cfg.scale_down_backlog}"))
            self._last_event = now
        self.min_seen = min(self.min_seen, self.active)
        self.max_seen = max(self.max_seen, self.active)
        return self.active

    def stats(self) -> Dict[str, object]:
        return {
            "min_replicas": self.cfg.min_replicas,
            "max_replicas": self.cfg.max_replicas,
            "active": self.active,
            "min_seen": self.min_seen,
            "max_seen": self.max_seen,
            "events": [(e.t, e.active, e.reason) for e in self.events],
        }


def warm_from_disk(replica, max_bytes: int = 0) -> int:
    """Seed a joining replica's knowledge tree from its DISK tier: stage
    disk-only nodes into host memory (top-down, parents first — the tier
    invariant) so the replica's first requests pay a host->GPU copy, not a
    full recompute.  Returns bytes staged.  A replica with no tree or no
    disk-resident state warms for free (0 bytes) — scale-down keeps trees
    intact precisely so this pays on the next scale-up."""
    tree = getattr(replica, "tree", None)
    if tree is None:
        return 0
    before = tree.stats.get("fetch_bytes", 0)
    budget = max_bytes if max_bytes > 0 else None
    stack = [tree.root]
    while stack:
        node = stack.pop()
        if node is not tree.root and node.in_disk and not node.in_host \
                and not node.in_gpu:
            tree.fetch_to_host(node)
            if budget is not None \
                    and tree.stats.get("fetch_bytes", 0) - before >= budget:
                break
        stack.extend(node.children.values())
    return tree.stats.get("fetch_bytes", 0) - before


@dataclasses.dataclass
class FrontDoorDecision:
    kind: str                      # HIT_EXACT / HIT_SIMILAR / SHED / MISS
    top_k: int = 0                 # effective retrieval depth (misses only)
    degraded: bool = False
    entry: Optional[CacheEntry] = None
    predicted_ttft: float = 0.0


class FrontDoor:
    """The composed policy object driven identically by the simulator and
    the real runtime (module docstring).  Per-request flow:

        exact hit -> similarity hit -> SLO admission (shed/degrade) ->
        autoscaler observe -> forward to the replica router
    """

    # analytic cost of a front-door hit: hash + cosine probe + queue pop.
    # Charged as the hit's TTFT so "mean TTFT with the front door on"
    # never pretends cache lookups are free.
    LOOKUP_SECONDS = 2e-4

    def __init__(self, cache: QueryCache, admission: SloAdmission,
                 autoscaler: Optional[FleetAutoscaler] = None, **legacy):
        if legacy:
            # assembled-objects API: knobs live in the components, built
            # from FrontDoorConfig via make_frontdoor() — loose kwargs here
            # were never a config channel and fail loudly naming it
            raise TypeError(
                f"FrontDoor() takes (cache, admission, autoscaler) only; "
                f"unexpected kwarg(s) {sorted(legacy)} — build the stack "
                f"from FrontDoorConfig via make_frontdoor() "
                f"(serving/config.py; docs/ARCHITECTURE.md §10)")
        self.cache = cache
        self.admission = admission
        self.autoscaler = autoscaler
        self.backlog = 0               # admitted misses in flight
        self.shed_by_tenant: Dict[str, int] = {}
        self.degraded = 0
        # per-tenant SLO attainment: tenant -> [completed, attained]
        self._slo_counts: Dict[str, List[int]] = {}

    # ---- per-request decision -------------------------------------------

    def active_replicas(self) -> int:
        return self.autoscaler.active if self.autoscaler is not None else 1

    def required_top_k(self, r: Request) -> int:
        """Depth this request's answer must have been generated with: its
        own explicit ``top_k`` when set, else the fleet's full default —
        a previously-degraded tenant's cached answer must not be served
        to a request admitted at full depth."""
        return int(r.top_k) if r.top_k > 0 else self.admission.top_k

    def handle(self, r: Request, now: float) -> FrontDoorDecision:
        kind, entry = self.cache.lookup(r.query_vec, r.question_tokens, now,
                                        min_top_k=self.required_top_k(r))
        if entry is not None:
            self._note_slo(r.tenant, self.LOOKUP_SECONDS)
            return FrontDoorDecision(kind=kind, entry=entry)
        dec = self.admission.decide(r.tenant, self.backlog,
                                    self.active_replicas())
        if dec.action == SHED:
            self.shed_by_tenant[r.tenant] = \
                self.shed_by_tenant.get(r.tenant, 0) + 1
            return FrontDoorDecision(kind=SHED,
                                     predicted_ttft=dec.predicted_ttft)
        if dec.action == DEGRADE:
            self.degraded += 1
        self.backlog += 1
        if self.autoscaler is not None:
            self.autoscaler.observe(now, self.backlog, dec.predicted_ttft)
        return FrontDoorDecision(kind=MISS, top_k=dec.top_k,
                                 degraded=dec.action == DEGRADE,
                                 predicted_ttft=dec.predicted_ttft)

    # ---- completion feedback --------------------------------------------

    def note_complete(self, r: Request, docs: Sequence[int],
                      answer: Sequence[int], ttft: float,
                      now: float) -> None:
        """An admitted miss finished on some replica: populate the query
        cache with its retrieval result + answer, update the service-time
        estimate and the tenant's SLO attainment, release backlog."""
        self.backlog = max(0, self.backlog - 1)
        self.admission.observe_ttft(ttft)
        self._note_slo(r.tenant, ttft)
        self.cache.insert(r.query_vec, r.question_tokens, docs, answer,
                          r.req_id, now, top_k=self.required_top_k(r))
        if self.autoscaler is not None:
            self.autoscaler.observe(
                now, self.backlog,
                self.admission.predicted_ttft(self.backlog,
                                              self.active_replicas()))

    def _note_slo(self, tenant: str, ttft: float) -> None:
        c = self._slo_counts.setdefault(tenant, [0, 0])
        c[0] += 1
        if ttft <= self.admission.slo_of(tenant).ttft_target:
            c[1] += 1

    # ---- reporting -------------------------------------------------------

    def slo_attainment(self) -> Dict[str, Tuple[int, int, float]]:
        """tenant -> (completed, attained, fraction)."""
        return {t: (c[0], c[1], c[1] / c[0] if c[0] else 0.0)
                for t, c in sorted(self._slo_counts.items())}

    def stats(self) -> Dict[str, object]:
        cs = self.cache.stats()
        handled = cs["hits_exact"] + cs["hits_similar"] + cs["misses"]
        out: Dict[str, object] = {
            "cache": cs,
            "hit_rate": ((cs["hits_exact"] + cs["hits_similar"])
                         / max(handled, 1)),
            "shed": dict(self.shed_by_tenant),
            "shed_total": sum(self.shed_by_tenant.values()),
            "degraded": self.degraded,
            "admission": dict(self.admission.decisions),
            "slo_attainment": {
                t: {"completed": n, "attained": a, "fraction": f}
                for t, (n, a, f) in self.slo_attainment().items()},
            "slo_targets_ms": {t: s.ttft_target * 1e3
                               for t, s in sorted(
                                   self.admission.slos.items())},
        }
        if self.autoscaler is not None:
            out["autoscale"] = self.autoscaler.stats()
        return out


# --------------------------------------------------------------------------
# the shared trace walk: simulator and real driver partition through HERE
# --------------------------------------------------------------------------

@dataclasses.dataclass
class FrontDoorPartition:
    """Outcome of routing one trace through the front door.

    shares[i] holds the (possibly ``top_k``-rewritten) miss requests
    assigned to replica i; hits/shed never reach a replica.  ``warmed``
    maps replica index -> bytes staged from its disk tier at scale-up."""
    shares: List[List[Request]]
    hits: List[Tuple[Request, FrontDoorDecision]]
    shed: List[Request]
    misses: List[Request]          # rewritten requests, arrival order
    warmed: Dict[int, int]


def frontdoor_partition(fd: FrontDoor, router, requests: Sequence[Request],
                        *, docs_of: Callable[[Request], Sequence[int]],
                        doc_tokens_of=None, context_of=None,
                        window: int = 0,
                        warm_replica: Callable = warm_from_disk,
                        ) -> FrontDoorPartition:
    """Walk a trace (arrival order) through the front door and the replica
    router.  Mirrors ``router.partition_requests`` — and is shared the
    same way: ``launch/serve.py --frontdoor`` (real runtimes) and
    ``serving/simulator.py::simulate_frontdoor`` (RAGSimulator replicas)
    both call THIS function with the SAME policy objects, so front-door
    behavior cannot drift between simulation and reality.

    The sliding ``window`` models per-replica backlog draining while the
    trace arrives (identical to partition_requests): a request leaving the
    window completes — the front door learns its retrieval result +
    answer-to-be (cache insert keyed by the ORIGINAL request; the answer
    tokens are attached by the caller after serving via ``hits``'
    ``entry.source_req_id``), the admission layer gets a service-time
    sample, and the autoscaler sees the drained backlog.  Completion-time
    TTFT feedback uses the admission layer's own prediction at dispatch —
    the caller can re-observe real TTFTs afterwards, but the PARTITION
    must be a function of the trace alone so both engines replay it
    identically.

    Autoscale events fire inside ``fd.handle``; this walk applies them:
    the router's active set follows ``fd.autoscaler.active``, and every
    replica joining the active set is warmed from its disk tier.
    """
    shares: List[List[Request]] = [[] for _ in router.replicas]
    hits: List[Tuple[Request, FrontDoorDecision]] = []
    shed: List[Request] = []
    misses: List[Request] = []
    warmed: Dict[int, int] = {}
    in_flight: List[Tuple[int, Request, Sequence[int], float]] = []
    active = router.active
    if fd.autoscaler is not None:
        # the fleet starts at the autoscaler's current count (min_replicas
        # on a fresh scaler), growing only as load demands
        active = min(fd.autoscaler.active, len(router.replicas))
        router.set_active(active)

    def _complete(idx: int, req: Request, docs: Sequence[int],
                  pred: float, now: float) -> None:
        router.note_complete(idx)
        fd.note_complete(req, docs, [], pred, now)

    for r in sorted(requests, key=lambda q: q.arrival):
        now = r.arrival
        dec = fd.handle(r, now)
        if dec.kind in (HIT_EXACT, HIT_SIMILAR):
            hits.append((r, dec))
            continue
        if dec.kind == SHED:
            shed.append(r)
            continue
        # autoscaler may have grown/shrunk the fleet on this arrival
        if fd.autoscaler is not None and fd.autoscaler.active != active:
            grew = range(active, fd.autoscaler.active)
            active = fd.autoscaler.active
            router.set_active(active)
            for i in grew:
                warmed[i] = warmed.get(i, 0) + int(
                    warm_replica(router.replicas[i]) or 0)
        req = r if dec.top_k == fd.admission.top_k \
            else dataclasses.replace(r, top_k=dec.top_k)
        docs = tuple(docs_of(req))
        toks = None if doc_tokens_of is None else doc_tokens_of(docs)
        ctx = 0 if context_of is None else int(context_of(req, docs, toks))
        rd = router.route(docs, toks, context_tokens=ctx)
        shares[rd.index].append(req)
        misses.append(req)
        if rd.admitted:
            in_flight.append((rd.index, req, docs, dec.predicted_ttft))
            if window > 0 and len(in_flight) > window:
                idx, q, d, pred = in_flight.pop(0)
                _complete(idx, q, d, pred, now)
        else:
            # no replica could admit: the engine's own admission queues it;
            # front-door backlog still drains when the window slides
            fd.backlog = max(0, fd.backlog - 1)
    for idx, q, d, pred in in_flight:
        _complete(idx, q, d, pred, q.arrival)
    return FrontDoorPartition(shares=shares, hits=hits, shed=shed,
                              misses=misses, warmed=warmed)


def attach_answers(part: FrontDoorPartition,
                   answers: Dict[int, Sequence[int]]) -> None:
    """After serving, fill each cache entry's answer tokens from the source
    request's served tokens (req_id -> tokens).  Hit decisions share the
    entry object, so hits see the answer too."""
    for _, dec in part.hits:
        if dec.entry is not None and not dec.entry.answer:
            src = answers.get(dec.entry.source_req_id)
            if src is not None:
                dec.entry.answer = [int(t) for t in src]


def make_frontdoor(*, capacity: int = 512, ttl: float = 60.0,
                   sim_threshold: float = 0.98,
                   slos: Optional[Dict[str, TenantSLO]] = None,
                   default_slo_ttft: float = 0.5, top_k: int = 2,
                   min_replicas: int = 1, max_replicas: int = 1,
                   autoscale: bool = False,
                   scale_up_backlog: float = 8.0,
                   scale_down_backlog: float = 2.0,
                   cooldown: float = 2.0,
                   init_service: float = 0.05) -> FrontDoor:
    """One-call constructor shared by serve.py, simulate_frontdoor and the
    benchmarks, so every driver assembles the identical policy stack."""
    cache = QueryCache(capacity=capacity, ttl=ttl,
                       sim_threshold=sim_threshold)
    admission = SloAdmission(
        slos or {}, default=TenantSLO(ttft_target=default_slo_ttft),
        top_k=top_k, init_service=init_service)
    scaler = None
    if autoscale:
        scaler = FleetAutoscaler(AutoscaleConfig(
            min_replicas=min_replicas, max_replicas=max_replicas,
            scale_up_backlog=scale_up_backlog,
            scale_down_backlog=scale_down_backlog, cooldown=cooldown))
    return FrontDoor(cache, admission, scaler)
