"""Typed serving configuration (the EngineConfig surface).

``launch/serve.py`` grew ~40 loose argparse flags across six PRs, and every
constructor in the serving stack took them as positional/keyword soup.  This
module is the single place that shape lives now: four frozen dataclasses,
built ONCE from the parsed args, threaded through the runtime / engine /
simulator constructors.

  * ``MeshConfig``      — tensor-parallel geometry of ONE replica (the
                          ``--tp N`` surface; axis names match
                          ``launch/sharding.py``'s partition rules)
  * ``EngineConfig``    — everything one engine (continuous runtime or
                          sequential RAGServer) needs: cache-tier budgets,
                          scheduler knobs, paged-pool shape, attention
                          engine, and the mesh
  * ``FleetConfig``     — replica count + routing policy (the PR 4 layer)
  * ``FrontDoorConfig`` — query cache / SLO admission / autoscaler knobs
                          (the PR 6 layer)

``config=`` is the SOLE constructor API: the loose-kwargs paths on
``ContinuousRuntime`` / ``RAGServer`` / ``ReplicaRouter`` / ``FrontDoor``
were deleted (this PR finished the PR 7 migration).  Passing a legacy
kwarg raises ``TypeError`` naming the config field that replaced it —
see the migration note in docs/ARCHITECTURE.md §10.

Every config round-trips through the CLI: ``from_args(parse(to_cli()))``
is the identity (property-tested for MeshConfig in
tests/test_engine_config.py), so a config can be logged, re-run, or
shipped to a remote driver as plain flags.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def reject_legacy_kwargs(ctor: str, kwargs: dict, config_cls,
                         aliases: Optional[dict] = None) -> None:
    """Fail loudly on pre-PR 7 loose constructor kwargs.

    ``config=`` is the sole constructor API now; every stray kwarg raises a
    TypeError that names the config field replacing it (``aliases`` maps
    renamed kwargs, e.g. ReplicaRouter's ``policy`` -> FleetConfig.routing).
    """
    if not kwargs:
        return
    aliases = aliases or {}
    fields = {f.name for f in dataclasses.fields(config_cls)}
    hints = []
    for k in sorted(kwargs):
        field = aliases.get(k, k)
        if field in fields:
            hints.append(f"{k!r} -> pass config="
                         f"{config_cls.__name__}(..., {field}=...)")
        else:
            hints.append(f"{k!r} (no {config_cls.__name__} equivalent)")
    raise TypeError(
        f"{ctor}() got unexpected keyword argument(s): the loose-kwargs "
        f"constructor path was removed — config={config_cls.__name__}(...) "
        f"is the sole API (docs/ARCHITECTURE.md §10).  "
        + "; ".join(hints))


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    """Tensor-parallel geometry of one serving replica.

    ``tp`` devices form a ``(data=1, model=tp)`` mesh
    (``launch/mesh.py::make_serving_mesh``); params shard per
    ``launch/sharding.py::param_spec`` and the paged pool shards its KV-head
    dim over ``axis``.  ``tp=1`` is the single-device engine (no mesh is
    ever built).  Replicas never share a mesh — TP is *within* a replica,
    PR 4's affinity routing is *across* replicas (a 2D fleet).
    """
    tp: int = 1
    axis: str = "model"

    def __post_init__(self):
        if self.tp < 1:
            raise ValueError(f"MeshConfig.tp must be >= 1, got {self.tp}")
        if not self.axis:
            raise ValueError("MeshConfig.axis must be a non-empty axis name")

    @classmethod
    def from_args(cls, args) -> "MeshConfig":
        return cls(tp=getattr(args, "tp", 1))

    def to_cli(self) -> Tuple[str, ...]:
        return ("--tp", str(self.tp))


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Everything ONE engine needs; mirrors the serve.py flag surface."""
    gpu_cache_bytes: int = 64 * 2**20
    host_cache_bytes: int = 512 * 2**20
    disk_cache_bytes: int = 0
    disk_cache_dir: Optional[str] = None
    policy: str = "pgdsf"
    top_k: int = 2
    reorder: bool = True
    speculative: bool = True
    max_batch: int = 4
    max_prefill_bs: int = 4
    prefill_chunk: int = 0
    max_prefill_tokens: int = 0
    block_size: int = 16
    attn: str = "auto"
    attn_impl: Optional[str] = None
    search_time_scale: float = 1.0
    # KV-reuse discipline (docs/ARCHITECTURE.md §11): "prefix" = the
    # classic knowledge-tree longest-cached-prefix reuse (bit-identical);
    # "chunk" = per-doc chunk cache reused at any position with
    # `recompute_tokens` boundary rows recomputed per relocated chunk
    # (approximate — verify with --check-tokens tol:<eps>).
    reuse: str = "prefix"
    recompute_tokens: int = 16
    # Workload mode (docs/ARCHITECTURE.md §12): "rag" = classic staged
    # retrieval per request; "cag" = cache-augmented generation — the FULL
    # corpus KV is preloaded into the knowledge tree at startup (disk-tier
    # resident, promoted on demand through the PGDSF cascade) and requests
    # serve with ZERO retrieval stages (doc resolution is one synchronous
    # deterministic index probe at arrival).  Requires a disk tier
    # (disk_cache_bytes > 0) big enough for the whole corpus.
    mode: str = "rag"
    mesh: MeshConfig = dataclasses.field(default_factory=MeshConfig)

    def __post_init__(self):
        if self.reuse not in ("prefix", "chunk"):
            raise ValueError(
                f"EngineConfig.reuse must be 'prefix' or 'chunk', "
                f"got {self.reuse!r}")
        if self.recompute_tokens < 0:
            raise ValueError("EngineConfig.recompute_tokens must be >= 0")
        if self.mode not in ("rag", "cag"):
            raise ValueError(
                f"EngineConfig.mode must be 'rag' or 'cag', "
                f"got {self.mode!r}")

    @classmethod
    def from_args(cls, args) -> "EngineConfig":
        return cls(
            gpu_cache_bytes=args.gpu_cache_bytes,
            host_cache_bytes=args.host_cache_bytes,
            disk_cache_bytes=args.disk_cache_bytes,
            disk_cache_dir=args.disk_cache_dir,
            policy=args.policy,
            top_k=args.top_k,
            reorder=not args.no_reorder,
            speculative=not args.no_spec,
            max_batch=args.max_batch,
            max_prefill_bs=getattr(args, "max_prefill_bs", 4),
            prefill_chunk=args.prefill_chunk,
            max_prefill_tokens=args.max_prefill_tokens,
            block_size=args.block_size,
            attn=args.attn,
            search_time_scale=args.search_scale,
            reuse=getattr(args, "reuse", "prefix"),
            recompute_tokens=getattr(args, "recompute_tokens", 16),
            mode=getattr(args, "mode", "rag"),
            mesh=MeshConfig.from_args(args),
        )

    def to_cli(self) -> Tuple[str, ...]:
        out = ["--gpu-cache-bytes", str(self.gpu_cache_bytes),
               "--host-cache-bytes", str(self.host_cache_bytes),
               "--disk-cache-bytes", str(self.disk_cache_bytes),
               "--policy", self.policy, "--top-k", str(self.top_k),
               "--max-batch", str(self.max_batch),
               "--max-prefill-bs", str(self.max_prefill_bs),
               "--prefill-chunk", str(self.prefill_chunk),
               "--max-prefill-tokens", str(self.max_prefill_tokens),
               "--block-size", str(self.block_size), "--attn", self.attn,
               "--search-scale", str(self.search_time_scale),
               "--reuse", self.reuse,
               "--recompute-tokens", str(self.recompute_tokens),
               "--mode", self.mode]
        if self.disk_cache_dir is not None:
            out += ["--disk-cache-dir", self.disk_cache_dir]
        if not self.reorder:
            out.append("--no-reorder")
        if not self.speculative:
            out.append("--no-spec")
        return tuple(out) + self.mesh.to_cli()


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Cross-replica layer: N independent engines behind the router."""
    replicas: int = 1
    routing: str = "affinity"
    max_queue_skew: int = 4
    # shadow-ledger bound of the router's per-replica routed-docs sets
    # (serving/router.py); was a loose ReplicaRouter kwarg before this PR
    max_shadow_paths: int = 4096

    @classmethod
    def from_args(cls, args) -> "FleetConfig":
        return cls(replicas=max(1, args.replicas), routing=args.routing,
                   max_queue_skew=args.max_queue_skew,
                   max_shadow_paths=getattr(args, "max_shadow_paths", 4096))

    def to_cli(self) -> Tuple[str, ...]:
        return ("--replicas", str(self.replicas), "--routing", self.routing,
                "--max-queue-skew", str(self.max_queue_skew),
                "--max-shadow-paths", str(self.max_shadow_paths))


@dataclasses.dataclass(frozen=True)
class FrontDoorConfig:
    """Front-door request layer (query cache + SLO admission + autoscaler)."""
    enabled: bool = False
    ttl: float = 60.0
    sim_threshold: float = 0.98
    capacity: int = 512
    autoscale: bool = False
    autoscale_min: int = 1
    scale_up_backlog: float = 8.0
    scale_down_backlog: float = 2.0
    cooldown: float = 2.0
    slo_ttft_ms: float = 500.0

    @classmethod
    def from_args(cls, args) -> "FrontDoorConfig":
        return cls(
            enabled=args.frontdoor, ttl=args.frontdoor_ttl,
            sim_threshold=args.frontdoor_sim_threshold,
            capacity=args.frontdoor_capacity, autoscale=args.autoscale,
            autoscale_min=args.autoscale_min,
            scale_up_backlog=args.scale_up_backlog,
            scale_down_backlog=args.scale_down_backlog,
            cooldown=args.autoscale_cooldown, slo_ttft_ms=args.slo_ttft_ms)

    def to_cli(self) -> Tuple[str, ...]:
        out = ["--frontdoor-ttl", str(self.ttl),
               "--frontdoor-sim-threshold", str(self.sim_threshold),
               "--frontdoor-capacity", str(self.capacity),
               "--autoscale-min", str(self.autoscale_min),
               "--scale-up-backlog", str(self.scale_up_backlog),
               "--scale-down-backlog", str(self.scale_down_backlog),
               "--autoscale-cooldown", str(self.cooldown),
               "--slo-ttft-ms", str(self.slo_ttft_ms)]
        if self.enabled:
            out.append("--frontdoor")
        if self.autoscale:
            out.append("--autoscale")
        return tuple(out)
