"""Iteration-level continuous-batching scheduler (vLLM/Orca-style) with
chunk-granular prefill.

One decision per engine iteration: run a PREFILL iteration (a *batch* of
prefill chunks — continuations of in-flight chunked prefills plus newly
admitted jobs, packed raggedly up to ``max_prefill_tokens``) or ONE batched
decode step for every running request.  Prefill is preferred while the
decode batch has room — it adds a request to the batch, which is what keeps
the GPU busy under load — and decode drains the batch otherwise.

Chunked prefill (Sarathi-style, paper Alg. 2 "terminate after the current
iteration"): a prefill is split into ``prefill_chunk``-token pieces and the
engine carries the KV across iterations.  Between chunks the scheduler
re-decides, so stale speculation is cancelled mid-prefill (the engine frees
the partial KV) instead of wasting the whole prefill, and decode interleaves
with long prefills.  Chunk pieces NEVER span segment (document/question)
boundaries — see ``prefill_piece_sizes`` — which keeps every per-segment
attention call shape-identical to the unchunked engine and therefore the
greedy tokens bit-identical.

The scheduler is engine-agnostic: queue items are opaque; the engine supplies
``viable`` (not cancelled / request not finished) and ``admit`` (resource
admission) callbacks.  Both the real JAX runtime (``serving.runtime``) and
the discrete-event simulator (``serving.simulator``) drive THIS code, so the
simulated policy and the executed policy cannot drift.

Chunk protocol: ``next_action`` returns ``Action(PREFILL, chunks=[...])``.
Each ``PrefillChunk`` is a token allowance for one item; ``first=True``
means the engine has not started this item yet (it must plan the request
and report the authoritative remaining piece sizes).  After executing a
chunk the engine calls ``note_chunk_done(item, remaining_pieces)`` (empty =
prefill complete) or ``abort_prefill(item)`` if the item went stale at the
chunk boundary.  Until the first report, a popped item is tracked as a
partial with unknown pieces and is not re-issued.

Admission control is by paged-KV-block budget and knowledge-tree pin budget
(``PagedAdmission``); admission is checked once, when a job's FIRST chunk is
admitted — a partial prefill already holds its resources, so continuations
bypass admission (finishing is the only way to release them).  When an
admissible-resource-starved request has been skipped ``preempt_after_skips``
times, the scheduler asks the engine to preempt (engine picks the victim —
youngest running request — frees its blocks, and requeues it).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Generic, List, Optional, Sequence, TypeVar

from repro.core.reorder import ReorderQueue

T = TypeVar("T")

PREFILL = "prefill"
DECODE = "decode"
PREEMPT = "preempt"
IDLE = "idle"


def prefill_piece_sizes(seg_lens: Sequence[int], chunk: int) -> List[int]:
    """Split a prefill into per-iteration piece sizes (tokens).

    seg_lens: token count of each to-be-computed segment, in order (uncached
    documents, then the question).  chunk <= 0 disables chunking: the whole
    prefill is one piece (one engine iteration, the legacy behaviour).

    With chunking enabled, every segment is split independently into
    ceil(len/chunk) pieces — pieces never span a segment boundary, so the
    attention calls that compute a given document's KV are a pure function
    of (document length, chunk size), independent of how much prefix was
    cached.  That is what keeps chunked greedy tokens bit-identical to the
    unchunked engine.

    Shared by the runtime, the simulator and the sequential engine — the
    single source of chunk boundaries (no duplicated chunking logic).
    """
    lens = [int(n) for n in seg_lens if n > 0]
    if not lens:
        return []
    if chunk <= 0:
        return [sum(lens)]
    out: List[int] = []
    for n in lens:
        out.extend([chunk] * (n // chunk))
        if n % chunk:
            out.append(n % chunk)
    return out


@dataclasses.dataclass
class SchedulerConfig:
    max_batch: int = 4             # decode batch slots (paper testbed: 4)
    max_prefill_bs: int = 4        # DSP speculative-prefill pool bound
    reorder: bool = True           # cache-aware reordering (§5.2)
    reorder_window: int = 32       # starvation window
    preempt_after_skips: int = 8   # admission-starved skips before preemption
    prefill_chunk: int = 0         # tokens per prefill piece (0 = whole
                                   # prefill in one engine iteration)
    max_prefill_tokens: int = 0    # ragged prefill-batch token budget per
                                   # iteration (0 = one request per iteration)


@dataclasses.dataclass
class PrefillChunk(Generic[T]):
    item: T
    tokens: int                    # planned token allowance this iteration
    first: bool                    # engine must plan the request (chunk 0)


@dataclasses.dataclass
class Action(Generic[T]):
    kind: str                      # PREFILL | DECODE | PREEMPT | IDLE
    item: Optional[T] = None       # first prefill job (back-compat)
    chunks: List[PrefillChunk] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _Partial(Generic[T]):
    """In-flight chunked prefill.  ``pending`` is the engine-reported list of
    remaining piece sizes; empty means the engine has not reported yet (the
    item was just popped) and the item must not be re-issued."""
    item: T
    pending: List[int]
    reported: bool = False


class ContinuousBatchScheduler(Generic[T]):
    def __init__(
        self,
        config: SchedulerConfig,
        *,
        viable: Callable[[T], bool],
        admit: Optional[Callable[[T], bool]] = None,
    ):
        self.config = config
        self.viable = viable
        self.admit = admit
        self.queue: ReorderQueue[T] = ReorderQueue(
            config.reorder_window, enabled=config.reorder)
        self.prefills_running = 0
        self._partials: List[_Partial[T]] = []

    # ---- queue interface ---------------------------------------------------

    def submit(self, item: T, cached_len: int, compute_len: int) -> None:
        self.queue.push(item, cached_len, max(compute_len, 1))

    def pool_size(self) -> int:
        """Pending-prefill pool size for Algorithm 2's admission bound."""
        return len(self.queue) + self.prefills_running

    def note_prefill_start(self) -> None:
        self.prefills_running += 1

    def note_prefill_end(self) -> None:
        self.prefills_running -= 1

    # ---- chunk protocol ----------------------------------------------------

    def note_chunk_done(self, item: T, pending: Sequence[int]) -> None:
        """Engine report after executing one chunk of ``item``: the
        authoritative remaining piece sizes.  Empty = prefill complete."""
        for p in self._partials:
            if p.item is item:
                if pending:
                    p.pending = [int(n) for n in pending]
                    p.reported = True
                else:
                    self._partials.remove(p)
                    self.prefills_running -= 1
                return

    def abort_prefill(self, item: T) -> None:
        """Engine report that an in-flight chunked prefill was cancelled at a
        chunk boundary (stale speculation / finished request / resource
        pressure).  The engine has already freed the partial KV."""
        for p in self._partials:
            if p.item is item:
                self._partials.remove(p)
                self.prefills_running -= 1
                return

    # ---- the per-iteration decision ---------------------------------------

    def next_action(
        self,
        n_running: int,
        refresh: Optional[Callable[[T], tuple]] = None,
    ) -> Action[T]:
        """Decide what the engine should launch this iteration.

        n_running: current decode-batch size.
        refresh: recompute (cached_len, compute_len) per item — hit lengths
        move as the knowledge tree evolves between submit and schedule.
        """
        self.queue.prune(lambda it: not self.viable(it))
        if n_running < self.config.max_batch:
            if refresh is not None:
                self.queue.refresh(refresh)
            # admission verdicts are O(resource-state) to compute; evaluate
            # once per entry per round and reuse between the starvation
            # bump and the pop filter
            verdicts = {}

            def adm(it):
                if self.admit is None:
                    return True
                key = id(it)
                if key not in verdicts:
                    verdicts[key] = self.admit(it)
                return verdicts[key]

            blocked = lambda it: self.viable(it) and not adm(it)
            # the preemption check runs EVERY round (a stream of small
            # admissible jobs must not starve a large request forever), but
            # only while >1 request runs: evicting the sole running request
            # gains no concurrency, only recompute waste — and because the
            # engine preempts youngest-first, the oldest running request
            # always advances, which is what guarantees global progress
            # (no preemption ping-pong when the pool only fits one request)
            if (self.admit is not None and n_running > 1
                    and self.queue.max_skipped(blocked)
                    >= self.config.preempt_after_skips):
                # a request is starving on resources only: make room
                return Action(PREEMPT)

            budget = self.config.max_prefill_tokens or 0
            chunks: List[PrefillChunk] = []
            used = 0
            # 1. continue in-flight chunked prefills, oldest first — a
            # partial already holds its blocks/pins, so finishing it is
            # always the cheapest way to free resources.  Non-viable
            # partials are skipped here; the engine sweeps and aborts them
            # at its next chunk boundary.
            for p in self._partials:
                if not p.reported or not p.pending:
                    continue           # awaiting the engine's first report
                if not self.viable(p.item):
                    continue
                n = p.pending[0]
                if chunks and (budget <= 0 or used + n > budget):
                    break
                chunks.append(PrefillChunk(p.item, n, first=False))
                used += n
                if budget <= 0:
                    break              # one request per iteration
            # 2. admit new jobs while the ragged batch has budget room
            popped = False
            while not chunks or (budget > 0 and used < budget):
                cand = self.queue.peek_entry(
                    lambda it: self.viable(it) and adm(it))
                if cand is None:
                    break
                chunk_cap = self.config.prefill_chunk
                n = max(1, min(cand.compute_len, chunk_cap)
                        if chunk_cap > 0 else cand.compute_len)
                if chunks and budget > 0 and used + n > budget:
                    break              # first chunk would not fit the budget
                # entries age exactly once per scheduling ROUND, however
                # many jobs a ragged batch packs
                self.queue.remove(cand, age=not popped)
                popped = True
                self._partials.append(_Partial(cand.item, []))
                self.prefills_running += 1
                chunks.append(PrefillChunk(cand.item, n, first=True))
                used += n
                if budget <= 0:
                    break              # one request per iteration
            if not popped:
                # nothing popped, so nothing aged: bump blocked entries here
                # — exactly one increment per round either way, INCLUDING
                # continuation-only rounds of a chunked prefill (a blocked
                # request was passed over then too; freezing its skip count
                # for a whole chunked prefill would stall the starvation /
                # preemption windows)
                self.queue.bump_skipped(blocked)
            if chunks:
                return Action(PREFILL, chunks[0].item, chunks)
        if n_running > 0:
            return Action(DECODE)
        return Action(IDLE)


# --------------------------------------------------------------------------
# admission control: paged-block + tree-pin budgets
# --------------------------------------------------------------------------

def tree_pinned_gpu_bytes(tree) -> int:
    """Bytes of GPU-tier nodes pinned by in-flight requests."""
    return sum(n.bytes_ for n in tree.nodes() if n.pinned and n.in_gpu)


@dataclasses.dataclass
class PagedAdmission:
    """Budget check for one prefill job against shared serving resources.

    pool:   the device BlockPool backing both tree payloads and request
            block tables.
    tree:   the KnowledgeTree (GPU tier doubles as the doc-state budget).
    decode_reserve: tokens of decode headroom to reserve at admission
            (max_new_tokens) so a running request can never stall mid-decode.
    """
    pool: object                    # BlockPool
    tree: object                    # KnowledgeTree
    decode_reserve: int
    # cached (available_blocks, pin_headroom_bytes): the two tree walks are
    # identical for every job in a scheduling round, so the engine
    # invalidates once per kick and all queued jobs share one snapshot
    _snap: object = dataclasses.field(default=None, init=False, repr=False)

    def invalidate(self) -> None:
        self._snap = None

    def _snapshot(self):
        if self._snap is None:
            self._snap = (
                self.pool.free_blocks + self.evictable_blocks(),
                self.tree.gpu_capacity - tree_pinned_gpu_bytes(self.tree),
            )
        return self._snap

    def blocks_needed(self, context_tokens: int) -> int:
        return self.pool.blocks_for_tokens(context_tokens + self.decode_reserve)

    def evictable_blocks(self) -> int:
        """Blocks actually recoverable by evicting unpinned GPU-tier tree
        nodes. Blocks refcount-shared into a running request's block table
        do NOT count — they stay allocated after eviction, and counting
        them livelocks the engine (admission keeps green-lighting a job
        whose pagination can never succeed until a running request ends)."""
        total = 0
        for n in self.tree.nodes():
            seg = n.payload_gpu
            if n.in_gpu and not n.pinned and seg is not None \
                    and hasattr(seg, "blocks"):
                total += self.pool.exclusive(seg.blocks)
        return total

    def admissible(self, context_tokens: int, beta_tokens: int,
                   promote_tokens: int = 0) -> bool:
        """context_tokens: full sequence (docs + question) the request will
        hold in its block table; beta_tokens: to-be-computed tokens whose
        document states the prefill will pin into the tree's GPU tier;
        promote_tokens: hit-prefix tokens currently parked on host or disk —
        a pinned path needing a disk fetch / host load lands in the same GPU
        pin budget as newly computed state, so it must be admitted against
        it (otherwise a cold-tier hit over-admits exactly when the cache is
        under the most pressure)."""
        avail, headroom = self._snapshot()
        if self.blocks_needed(context_tokens) > avail:
            return False
        return ((beta_tokens + promote_tokens) * self.tree.bytes_per_token
                <= headroom)
