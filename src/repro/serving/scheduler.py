"""Iteration-level continuous-batching scheduler (vLLM/Orca-style).

One decision per engine iteration: start ONE prefill (possibly speculative,
picked from the cache-aware ``ReorderQueue``) or run ONE batched decode step
for every running request.  Prefill is preferred while the decode batch has
room — it adds a request to the batch, which is what keeps the GPU busy
under load — and decode drains the batch otherwise.

The scheduler is engine-agnostic: queue items are opaque; the engine supplies
``viable`` (not cancelled / request not finished) and ``admit`` (resource
admission) callbacks.  Both the real JAX runtime (``serving.runtime``) and
the discrete-event simulator (``serving.simulator``) drive THIS code, so the
simulated policy and the executed policy cannot drift.

Admission control is by paged-KV-block budget and knowledge-tree pin budget
(``PagedAdmission``): a request is admitted only if the block pool can hold
its full context plus decode reservation and the tree's GPU tier can take its
to-be-computed document states on top of currently pinned bytes.  When an
admissible-resource-starved request has been skipped ``preempt_after_skips``
times, the scheduler asks the engine to preempt (engine picks the victim —
youngest running request — frees its blocks, and requeues it).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Generic, Optional, TypeVar

from repro.core.reorder import ReorderQueue

T = TypeVar("T")

PREFILL = "prefill"
DECODE = "decode"
PREEMPT = "preempt"
IDLE = "idle"


@dataclasses.dataclass
class SchedulerConfig:
    max_batch: int = 4             # decode batch slots (paper testbed: 4)
    max_prefill_bs: int = 4        # DSP speculative-prefill pool bound
    reorder: bool = True           # cache-aware reordering (§5.2)
    reorder_window: int = 32       # starvation window
    preempt_after_skips: int = 8   # admission-starved skips before preemption


@dataclasses.dataclass
class Action(Generic[T]):
    kind: str                      # PREFILL | DECODE | PREEMPT | IDLE
    item: Optional[T] = None       # the prefill job for PREFILL


class ContinuousBatchScheduler(Generic[T]):
    def __init__(
        self,
        config: SchedulerConfig,
        *,
        viable: Callable[[T], bool],
        admit: Optional[Callable[[T], bool]] = None,
    ):
        self.config = config
        self.viable = viable
        self.admit = admit
        self.queue: ReorderQueue[T] = ReorderQueue(
            config.reorder_window, enabled=config.reorder)
        self.prefills_running = 0

    # ---- queue interface ---------------------------------------------------

    def submit(self, item: T, cached_len: int, compute_len: int) -> None:
        self.queue.push(item, cached_len, max(compute_len, 1))

    def pool_size(self) -> int:
        """Pending-prefill pool size for Algorithm 2's admission bound."""
        return len(self.queue) + self.prefills_running

    def note_prefill_start(self) -> None:
        self.prefills_running += 1

    def note_prefill_end(self) -> None:
        self.prefills_running -= 1

    # ---- the per-iteration decision ---------------------------------------

    def next_action(
        self,
        n_running: int,
        refresh: Optional[Callable[[T], tuple]] = None,
    ) -> Action[T]:
        """Decide what the engine should launch this iteration.

        n_running: current decode-batch size.
        refresh: recompute (cached_len, compute_len) per item — hit lengths
        move as the knowledge tree evolves between submit and schedule.
        """
        self.queue.prune(lambda it: not self.viable(it))
        if n_running < self.config.max_batch:
            if refresh is not None:
                self.queue.refresh(refresh)
            if self.admit is None:
                job = self.queue.pop(self.viable)
                return Action(PREFILL, job) if job is not None \
                    else (Action(DECODE) if n_running else Action(IDLE))
            # admission verdicts are O(resource-state) to compute; evaluate
            # once per entry per round and reuse between the starvation
            # bump and the pop filter
            verdicts = {}

            def adm(it):
                key = id(it)
                if key not in verdicts:
                    verdicts[key] = self.admit(it)
                return verdicts[key]

            blocked = lambda it: self.viable(it) and not adm(it)
            # the preemption check runs EVERY round (a stream of small
            # admissible jobs must not starve a large request forever), but
            # only while >1 request runs: evicting the sole running request
            # gains no concurrency, only recompute waste — and because the
            # engine preempts youngest-first, the oldest running request
            # always advances, which is what guarantees global progress
            # (no preemption ping-pong when the pool only fits one request)
            if (n_running > 1
                    and self.queue.max_skipped(blocked)
                    >= self.config.preempt_after_skips):
                # a request is starving on resources only: make room
                return Action(PREEMPT)
            job = self.queue.pop(lambda it: self.viable(it) and adm(it))
            if job is not None:
                # pop aged every remaining entry (incl. blocked ones)
                return Action(PREFILL, job)
            # nothing popped, so nothing aged: bump blocked entries here —
            # exactly one increment per round either way
            self.queue.bump_skipped(blocked)
        if n_running > 0:
            return Action(DECODE)
        return Action(IDLE)


# --------------------------------------------------------------------------
# admission control: paged-block + tree-pin budgets
# --------------------------------------------------------------------------

def tree_pinned_gpu_bytes(tree) -> int:
    """Bytes of GPU-tier nodes pinned by in-flight requests."""
    return sum(n.bytes_ for n in tree.nodes() if n.pinned and n.in_gpu)


@dataclasses.dataclass
class PagedAdmission:
    """Budget check for one prefill job against shared serving resources.

    pool:   the device BlockPool backing both tree payloads and request
            block tables.
    tree:   the KnowledgeTree (GPU tier doubles as the doc-state budget).
    decode_reserve: tokens of decode headroom to reserve at admission
            (max_new_tokens) so a running request can never stall mid-decode.
    """
    pool: object                    # BlockPool
    tree: object                    # KnowledgeTree
    decode_reserve: int
    # cached (available_blocks, pin_headroom_bytes): the two tree walks are
    # identical for every job in a scheduling round, so the engine
    # invalidates once per kick and all queued jobs share one snapshot
    _snap: object = dataclasses.field(default=None, init=False, repr=False)

    def invalidate(self) -> None:
        self._snap = None

    def _snapshot(self):
        if self._snap is None:
            self._snap = (
                self.pool.free_blocks + self.evictable_blocks(),
                self.tree.gpu_capacity - tree_pinned_gpu_bytes(self.tree),
            )
        return self._snap

    def blocks_needed(self, context_tokens: int) -> int:
        return self.pool.blocks_for_tokens(context_tokens + self.decode_reserve)

    def evictable_blocks(self) -> int:
        """Blocks actually recoverable by evicting unpinned GPU-tier tree
        nodes. Blocks refcount-shared into a running request's block table
        do NOT count — they stay allocated after eviction, and counting
        them livelocks the engine (admission keeps green-lighting a job
        whose pagination can never succeed until a running request ends)."""
        total = 0
        for n in self.tree.nodes():
            seg = n.payload_gpu
            if n.in_gpu and not n.pinned and seg is not None \
                    and hasattr(seg, "blocks"):
                total += self.pool.exclusive(seg.blocks)
        return total

    def admissible(self, context_tokens: int, beta_tokens: int) -> bool:
        """context_tokens: full sequence (docs + question) the request will
        hold in its block table; beta_tokens: to-be-computed tokens whose
        document states the prefill will pin into the tree's GPU tier."""
        avail, headroom = self._snapshot()
        if self.blocks_needed(context_tokens) > avail:
            return False
        return beta_tokens * self.tree.bytes_per_token <= headroom
