"""The ``Backend`` contract: the tier-hop surface every cache backend
implements.

Before this module the contract existed only by convention: ``PagedBackend``
(serving/runtime.py), ``_JaxBackend`` (serving/engine.py) and ``_SimBackend``
(serving/simulator.py) each re-implemented the same seven methods against
``core/knowledge_tree.py::CacheBackend``'s duck-typed dispatch, and nothing
would catch a fourth implementation drifting (a misspelled ``free_gpu`` only
surfaces as a silently-unfreed tier).  ``Backend`` is that surface as a
``typing.Protocol``; the tensor-parallel ``ShardedPagedBackend``
(serving/runtime.py) is the fourth implementation of the now-explicit
contract, and tests/test_backend_protocol.py holds all four to it.

Hop methods return the SECONDS the copy cost (measured wall time in the real
backends, analytic transfer time in the simulator's); free methods return
nothing.  ``demote_copy``/``promote_copy``/``free_tier`` are the generic
tier-indexed dispatchers the eviction cascade calls, so policy code never
names a tier pair.
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable


@runtime_checkable
class Backend(Protocol):
    """Tier-hop surface of a knowledge-tree cache backend.

    Tier levels (core/knowledge_tree.py): 0 = GPU, 1 = host, 2 = disk.
    ``node`` is a ``knowledge_tree.Node`` whose ``payload_gpu`` /
    ``payload_host`` / ``payload_disk`` slots the backend moves between.
    """

    # ---- named hops (one per adjacent tier pair) -------------------------

    def swap_out(self, node) -> float:
        """GPU -> host copy; returns seconds."""
        ...

    def load(self, node) -> float:
        """host -> GPU copy; returns seconds.  May raise ``EvictionError``
        when the device tier cannot hold the payload (promotion degrades to
        recompute)."""
        ...

    def spill(self, node) -> float:
        """host -> disk write; returns seconds."""
        ...

    def fetch(self, node) -> float:
        """disk -> host read; returns seconds."""
        ...

    # ---- frees -----------------------------------------------------------

    def free_gpu(self, node) -> None: ...

    def free_host(self, node) -> None: ...

    def free_disk(self, node) -> None: ...

    # ---- generic tier-indexed dispatch (the cascade's entry points) ------

    def demote_copy(self, node, level: int) -> float:
        """Copy from tier ``level`` to tier ``level + 1``; returns seconds."""
        ...

    def promote_copy(self, node, level: int) -> float:
        """Copy from tier ``level`` to tier ``level - 1``; returns seconds."""
        ...

    def free_tier(self, node, level: int) -> None: ...


def conforms(obj) -> bool:
    """True when ``obj`` satisfies the ``Backend`` protocol (method presence
    — the runtime_checkable check; signatures are exercised by the
    conformance test's live calls)."""
    return isinstance(obj, Backend)
