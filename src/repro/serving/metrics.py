"""Per-request serving telemetry for the continuous-batching runtime.

Each request gets a ``RequestTimeline`` of absolute timestamps on the
runtime's clock (arrival, retrieval stages, prefill, first token, decode
tokens).  ``ServingMetrics`` aggregates timelines plus per-iteration engine
records into the paper's headline numbers — TTFT / TPOT / queueing-time
percentiles, decode-batch occupancy, retrieval-overlap accounting (how
much of the staged vector search was hidden behind speculative prefill,
§5.3 / Fig. 19), and per-tier cache attribution: each request's cached
prefix split by the tier (gpu/host/disk) its hit nodes were resident in at
plan time, plus disk prefetches overlapped with search.

``FleetMetrics`` layers the multi-replica view on top (docs/ARCHITECTURE.md
§8): one ``ServingMetrics`` per replica plus the ``ReplicaRouter``'s
routing accounting, aggregated into per-replica occupancy / hit-token
tiers / routed-vs-escaped counts and cross-replica TTFT percentiles
computed over the POOLED per-request timelines (exact, not a mean of
per-replica percentiles).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np


@dataclasses.dataclass
class RequestTimeline:
    req_id: int
    arrival: float
    search_start: float = -1.0
    search_end: float = -1.0
    # first time *any* prefill (speculative or final) for the finally-chosen
    # document set started — the overlap credit (paper Fig. 19)
    final_prefill_start: float = -1.0
    prefill_end: float = -1.0
    queue_enter: float = -1.0          # final docs queued for the engine
    first_token: float = -1.0
    finish: float = -1.0
    token_times: List[float] = dataclasses.field(default_factory=list)
    # cache accounting
    alpha: int = 0                     # cached prefix tokens
    beta: int = 0                      # computed tokens
    # alpha split by the tier each hit node was resident in at plan time
    hit_tokens_gpu: int = 0
    hit_tokens_host: int = 0
    hit_tokens_disk: int = 0
    hit_docs: int = 0
    n_docs: int = 0
    speculative_hit: bool = False      # final docs matched a live speculation
    preemptions: int = 0
    tokens: List[int] = dataclasses.field(default_factory=list)
    docs: tuple = ()

    @property
    def ttft(self) -> float:
        return self.first_token - self.arrival if self.first_token >= 0 else -1.0

    @property
    def tpot(self) -> float:
        """Mean time per output token after the first (paper §8)."""
        if not self.token_times or self.first_token < 0:
            return 0.0
        return (self.token_times[-1] - self.first_token) / len(self.token_times)

    @property
    def queueing(self) -> float:
        """Final-docs queue entry -> prefill start (scheduling delay)."""
        if self.queue_enter < 0 or self.final_prefill_start < 0:
            return 0.0
        return max(0.0, self.final_prefill_start - self.queue_enter)

    @property
    def search_time(self) -> float:
        if self.search_end < 0:
            return 0.0
        return self.search_end - self.search_start

    @property
    def non_overlapped_search(self) -> float:
        """Portion of the staged search NOT hidden behind a prefill of the
        final document set. Sequential serving: == search_time."""
        dur = self.search_time
        if self.final_prefill_start < 0:
            return dur
        overlap = max(0.0, self.search_end
                      - max(self.search_start, self.final_prefill_start))
        return max(0.0, dur - min(overlap, dur))


def percentiles(xs: List[float]) -> Dict[str, float]:
    if not xs:
        return {"mean": 0.0, "p50": 0.0, "p90": 0.0, "p99": 0.0, "max": 0.0}
    a = np.asarray(xs, np.float64)
    return {
        "mean": float(a.mean()),
        "p50": float(np.percentile(a, 50)),
        "p90": float(np.percentile(a, 90)),
        "p99": float(np.percentile(a, 99)),
        "max": float(a.max()),
    }


class ServingMetrics:
    """Aggregator owned by the runtime; the benchmark and launch driver read
    ``summary()`` / ``format_report()``."""

    def __init__(self):
        self.timelines: Dict[int, RequestTimeline] = {}
        # per engine iteration: ("prefill", 1) or ("decode", batch_size)
        self.iterations: List[tuple] = []
        self.wasted_prefills = 0
        self.spec_prefills = 0
        # staged-retrieval events processed (one per search stage).  CAG
        # mode's zero-retrieval-stage invariant asserts this stays 0.
        self.retrieval_stages = 0
        self.preemptions = 0
        self.blocks_shared = 0         # tree blocks refcounted into tables
        self.blocks_copied = 0         # unaligned doc tokens re-put privately
        # disk tier: prefetches issued during retrieval stages (overlapped
        # host-side I/O — see runtime._prefetch_disk)
        self.disk_prefetches = 0
        self.disk_prefetch_bytes = 0
        # chunked/batched prefill accounting
        # per prefill iteration: (n_chunks_packed, tokens_computed)
        self.prefill_batches: List[tuple] = []
        self.prefill_token_budget = 0  # max_prefill_tokens (0 = unbounded)
        self.chunks_cancelled = 0      # prefills aborted at a chunk boundary
        self.chunk_tokens_saved = 0    # prefill tokens NOT computed thanks to
                                       # mid-prefill cancellation
        # chunk-cache reuse (--reuse chunk, docs/ARCHITECTURE.md §11)
        self.exact_chunk_hits = 0      # docs reused bit-identically
        self.reloc_chunk_hits = 0      # docs reused at a new position
        self.reloc_recompute_tokens = 0   # boundary tokens recomputed

    def record_prefill_batch(self, n_chunks: int, n_tokens: int) -> None:
        self.prefill_batches.append((n_chunks, n_tokens))

    def record_chunk_cancel(self, tokens_saved: int) -> None:
        self.chunks_cancelled += 1
        self.chunk_tokens_saved += int(tokens_saved)

    def timeline(self, req_id: int, arrival: float) -> RequestTimeline:
        tl = self.timelines.get(req_id)
        if tl is None:
            tl = RequestTimeline(req_id=req_id, arrival=arrival)
            self.timelines[req_id] = tl
        return tl

    def record_iteration(self, kind: str, batch: int) -> None:
        self.iterations.append((kind, batch))

    # ---- aggregation ------------------------------------------------------

    def completed(self) -> List[RequestTimeline]:
        return [t for t in self.timelines.values() if t.first_token >= 0]

    def summary(self) -> Dict[str, object]:
        done = self.completed()
        decode_batches = [b for k, b in self.iterations if k == "decode"]
        n_prefills = sum(1 for k, _ in self.iterations if k == "prefill")
        spec_hits = sum(1 for t in done if t.speculative_hit)
        chunk_counts = [c for c, _ in self.prefill_batches]
        chunk_tokens = [t for _, t in self.prefill_batches]
        budget = self.prefill_token_budget
        return {
            "completed": len(done),
            "ttft": percentiles([t.ttft for t in done]),
            "tpot": percentiles([t.tpot for t in done if t.token_times]),
            "queueing": percentiles([t.queueing for t in done]),
            "search": percentiles([t.search_time for t in done]),
            "non_overlapped_search": percentiles(
                [t.non_overlapped_search for t in done]),
            "decode_iterations": len(decode_batches),
            "prefill_iterations": n_prefills,
            "mean_decode_batch": (float(np.mean(decode_batches))
                                  if decode_batches else 0.0),
            "max_decode_batch": max(decode_batches, default=0),
            "speculative_hits": spec_hits,
            "speculative_prefills": self.spec_prefills,
            "retrieval_stages": self.retrieval_stages,
            "wasted_prefills": self.wasted_prefills,
            "preemptions": self.preemptions,
            "prefill_chunks": int(sum(chunk_counts)),
            "prefill_batch_occupancy": (float(np.mean(chunk_counts))
                                        if chunk_counts else 0.0),
            "max_prefill_batch": max(chunk_counts, default=0),
            "prefill_token_fill": (
                float(np.mean(chunk_tokens)) / budget
                if budget > 0 and chunk_tokens else 0.0),
            "chunks_cancelled": self.chunks_cancelled,
            "chunk_tokens_saved": self.chunk_tokens_saved,
            "exact_chunk_hits": self.exact_chunk_hits,
            "reloc_chunk_hits": self.reloc_chunk_hits,
            "reloc_recompute_tokens": self.reloc_recompute_tokens,
            "blocks_shared": self.blocks_shared,
            "blocks_copied": self.blocks_copied,
            "tier_hit_tokens": {
                "gpu": sum(t.hit_tokens_gpu for t in done),
                "host": sum(t.hit_tokens_host for t in done),
                "disk": sum(t.hit_tokens_disk for t in done),
            },
            "disk_prefetches": self.disk_prefetches,
            "disk_prefetch_bytes": self.disk_prefetch_bytes,
            "doc_hit_rate": (sum(t.hit_docs for t in done)
                             / max(sum(t.n_docs for t in done), 1)),
        }

    def format_report(self) -> str:
        s = self.summary()

        def ms(p):
            return (f"mean {p['mean'] * 1e3:7.1f}  p50 {p['p50'] * 1e3:7.1f}"
                    f"  p90 {p['p90'] * 1e3:7.1f}  p99 {p['p99'] * 1e3:7.1f}")

        lines = [
            f"completed requests      : {s['completed']}",
            f"TTFT (ms)               : {ms(s['ttft'])}",
            f"TPOT (ms)               : {ms(s['tpot'])}",
            f"queueing (ms)           : {ms(s['queueing'])}",
            f"search (ms)             : {ms(s['search'])}",
            f"non-overlapped search   : {ms(s['non_overlapped_search'])}",
            f"engine iterations       : {s['prefill_iterations']} prefill / "
            f"{s['decode_iterations']} decode",
            f"decode batch occupancy  : mean {s['mean_decode_batch']:.2f} "
            f"max {s['max_decode_batch']}",
            f"speculation             : {s['speculative_hits']} hits / "
            f"{s['speculative_prefills']} launched / "
            f"{s['wasted_prefills']} wasted",
            f"preemptions             : {s['preemptions']}",
            f"prefill chunks          : {s['prefill_chunks']} run / "
            f"{s['chunks_cancelled']} cancelled mid-prefill / "
            f"{s['chunk_tokens_saved']} tokens saved",
            f"prefill batch occupancy : mean {s['prefill_batch_occupancy']:.2f} "
            f"max {s['max_prefill_batch']} "
            f"fill {s['prefill_token_fill']:.2f}",
            f"paged blocks            : {s['blocks_shared']} shared / "
            f"{s['blocks_copied']} copied",
            f"cache hit tokens        : gpu {s['tier_hit_tokens']['gpu']} / "
            f"host {s['tier_hit_tokens']['host']} / "
            f"disk {s['tier_hit_tokens']['disk']}",
            f"disk prefetches         : {s['disk_prefetches']} "
            f"({s['disk_prefetch_bytes']} B overlapped with search)",
            f"doc hit rate            : {s['doc_hit_rate']:.2%}",
        ]
        return "\n".join(lines)


class FleetMetrics:
    """Cross-replica aggregation for the multi-replica serving driver.

    The driver adds each replica's ``ServingMetrics`` after serving and
    attaches the router's ``stats()`` dict; ``summary()`` pools every
    replica's completed timelines so the cross-replica TTFT/TPOT
    percentiles are exact."""

    def __init__(self, router_stats: Dict[str, object] | None = None,
                 frontdoor_stats: Dict[str, object] | None = None):
        self.replicas: List[Tuple[str, ServingMetrics]] = []
        self.router_stats: Dict[str, object] = router_stats or {}
        # serving/frontdoor.py FrontDoor.stats(): query-cache hit rates,
        # per-tenant SLO attainment, shed counts, autoscale events
        self.frontdoor_stats: Dict[str, object] = frontdoor_stats or {}

    def add_replica(self, name: str, metrics: ServingMetrics) -> None:
        self.replicas.append((name, metrics))

    def summary(self) -> Dict[str, object]:
        done = [t for _, m in self.replicas for t in m.completed()]
        per_replica = []
        for name, m in self.replicas:
            s = m.summary()
            per_replica.append({
                "name": name,
                "completed": s["completed"],
                "decode_occupancy": s["mean_decode_batch"],
                "prefill_occupancy": s["prefill_batch_occupancy"],
                "tier_hit_tokens": s["tier_hit_tokens"],
                "blocks_shared": s["blocks_shared"],
                "preemptions": s["preemptions"],
            })
        tiers = {t: sum(r["tier_hit_tokens"][t] for r in per_replica)
                 for t in ("gpu", "host", "disk")}
        return {
            "replicas": len(self.replicas),
            "completed": len(done),
            "ttft": percentiles([t.ttft for t in done]),
            "tpot": percentiles([t.tpot for t in done if t.token_times]),
            "tier_hit_tokens": tiers,
            "per_replica": per_replica,
            "routing": dict(self.router_stats),
            "frontdoor": dict(self.frontdoor_stats),
        }

    def format_report(self) -> str:
        s = self.summary()
        p = s["ttft"]
        rs = s["routing"]
        kinds = rs.get("kind_counts", {})
        routed = rs.get("routed", [])
        escaped = rs.get("escaped", 0)
        lines = [
            f"fleet: {s['replicas']} replicas, {s['completed']} completed, "
            f"policy {rs.get('policy', '?')}",
            f"cross-replica TTFT (ms) : mean {p['mean'] * 1e3:7.1f}  "
            f"p50 {p['p50'] * 1e3:7.1f}  p99 {p['p99'] * 1e3:7.1f}",
            f"routed per replica      : {routed}  "
            f"(escaped {escaped}, max skew {rs.get('max_skew_observed', 0)}"
            f"/{rs.get('max_queue_skew', '?')} bound)",
            f"decision kinds          : "
            + (", ".join(f"{k} {v}" for k, v in sorted(kinds.items()))
               or "none"),
            f"fleet hit tokens        : gpu {s['tier_hit_tokens']['gpu']} / "
            f"host {s['tier_hit_tokens']['host']} / "
            f"disk {s['tier_hit_tokens']['disk']}",
        ]
        for r in s["per_replica"]:
            lines.append(
                f"  {r['name']:<12} completed {r['completed']:>4}  "
                f"decode occ {r['decode_occupancy']:.2f}  "
                f"prefill occ {r['prefill_occupancy']:.2f}  "
                f"hit gpu/host/disk {r['tier_hit_tokens']['gpu']}/"
                f"{r['tier_hit_tokens']['host']}/"
                f"{r['tier_hit_tokens']['disk']}  "
                f"shared {r['blocks_shared']}  "
                f"preempt {r['preemptions']}")
        fd = s["frontdoor"]
        if fd:
            cache = fd.get("cache", {})
            lines.append(
                f"front door              : hit rate {fd.get('hit_rate', 0.0):.2%} "
                f"(exact {cache.get('hits_exact', 0)} / "
                f"similar {cache.get('hits_similar', 0)} / "
                f"miss {cache.get('misses', 0)}), "
                f"shed {fd.get('shed_total', 0)}, "
                f"degraded {fd.get('degraded', 0)}, "
                f"cache {cache.get('size', 0)}/{cache.get('capacity', 0)} "
                f"(expired {cache.get('expired', 0)}, "
                f"evicted {cache.get('evicted', 0)})")
            targets = fd.get("slo_targets_ms", {})
            for tenant, att in sorted(fd.get("slo_attainment", {}).items()):
                tgt = targets.get(tenant)
                tgt_s = f" (target {tgt:.0f}ms)" if tgt is not None else ""
                lines.append(
                    f"  SLO {tenant or '<default>':<12} "
                    f"attained {att['attained']}/{att['completed']} "
                    f"= {att['fraction']:.2%}{tgt_s}")
            scale = fd.get("autoscale")
            if scale:
                lines.append(
                    f"autoscale               : active {scale['active']} "
                    f"in [{scale['min_replicas']}, {scale['max_replicas']}] "
                    f"(seen {scale['min_seen']}..{scale['max_seen']}, "
                    f"{len(scale['events'])} events)")
                for t, active, reason in scale["events"]:
                    lines.append(f"  t={t:8.3f}s -> {active} ({reason})")
        return "\n".join(lines)
