"""Hymba-1.5B [arXiv:2411.13676] — hybrid-head: parallel attention + mamba
heads in every block, SWA on most layers with a few global. 32L d_model=1600
25H (GQA kv=5) d_ff=5504 vocab=32001 ssm_state=16."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    sliding_window=1024,    # Hymba: SWA everywhere except a few global layers
    global_every=16,        # layers 16, 32 global (approximates first/mid/last)
)

REDUCED = dataclasses.replace(
    CONFIG, name="hymba-reduced", n_layers=2, d_model=320, n_heads=5,
    n_kv_heads=1, d_ff=512, vocab_size=512, sliding_window=64, global_every=2,
)
