"""Qwen2-0.5B [arXiv:2407.10671] — dense GQA with QKV bias.
24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

REDUCED = dataclasses.replace(
    CONFIG, name="qwen2-reduced", n_layers=2, d_model=256, n_heads=4,
    n_kv_heads=2, d_ff=512, vocab_size=512,
)
