"""Architecture registry: one module per assigned architecture (+ paper models).

``get_config(arch_id)`` returns the full production config;
``get_reduced(arch_id)`` returns the CPU-smoke-test variant of the same family
(≤2 layers, d_model ≤ 512, ≤4 experts) per the assignment rules.
"""
from __future__ import annotations

import importlib
from typing import List

from repro.models.config import ModelConfig

_ARCHS = [
    "xlstm_1p3b",
    "hymba_1p5b",
    "phi35_moe_42b",
    "yi_34b",
    "gemma3_12b",
    "internvl2_1b",
    "musicgen_large",
    "gemma2_27b",
    "mixtral_8x7b",
    "qwen2_0p5b",
    # paper's own evaluation models
    "mistral_7b",
    "llama2_7b",
    "llama2_70b",
]

_ALIASES = {
    "xlstm-1.3b": "xlstm_1p3b",
    "hymba-1.5b": "hymba_1p5b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "yi-34b": "yi_34b",
    "gemma3-12b": "gemma3_12b",
    "internvl2-1b": "internvl2_1b",
    "musicgen-large": "musicgen_large",
    "gemma2-27b": "gemma2_27b",
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen2-0.5b": "qwen2_0p5b",
    "mistral-7b": "mistral_7b",
    "llama2-7b": "llama2_7b",
    "llama2-70b": "llama2_70b",
}

ASSIGNED = [
    "xlstm-1.3b", "hymba-1.5b", "phi3.5-moe-42b-a6.6b", "yi-34b",
    "gemma3-12b", "internvl2-1b", "musicgen-large", "gemma2-27b",
    "mixtral-8x7b", "qwen2-0.5b",
]


def _module(name: str):
    mod = _ALIASES.get(name, name).replace("-", "_").replace(".", "p")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_reduced(name: str) -> ModelConfig:
    return _module(name).REDUCED


def list_configs() -> List[str]:
    return list(_ARCHS)
