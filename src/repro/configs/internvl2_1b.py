"""InternVL2-1B [arXiv:2404.16821] — InternViT (stub frontend) + InternLM2
backbone (llama-like GQA). 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655. ``input_specs`` supplies 256 precomputed patch embeddings per
the modality-frontend carve-out."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    vision_tokens=256,
)

REDUCED = dataclasses.replace(
    CONFIG, name="internvl2-reduced", n_layers=2, d_model=256, n_heads=4,
    n_kv_heads=2, d_ff=512, vocab_size=512, vision_tokens=16,
)
