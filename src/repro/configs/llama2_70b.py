"""LLaMA2-70B [arXiv:2307.09288] — RAGCache large-model case study
(paper §7.2, Table 1): 80L, 64 Q / 8 KV heads, KV 0.3125 MiB/token."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama2-70b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=32000,
    tie_embeddings=False,
)

REDUCED = dataclasses.replace(
    CONFIG, name="llama2-70b-reduced", n_layers=2, d_model=256, n_heads=8,
    n_kv_heads=1, d_ff=512, vocab_size=512,
)
