"""MusicGen-large [arXiv:2306.05284] — decoder-only LM over EnCodec tokens,
4 codebooks (delay interleaving handled by the data pipeline), MHA.
48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048 per codebook.
The EnCodec conv codec is a stub frontend per the carve-out; the model
consumes/produces codebook token ids."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    n_codebooks=4,
    tie_embeddings=False,
)

REDUCED = dataclasses.replace(
    CONFIG, name="musicgen-reduced", n_layers=2, d_model=256, n_heads=4,
    n_kv_heads=4, d_ff=512, vocab_size=128, n_codebooks=4,
)
