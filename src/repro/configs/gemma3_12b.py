"""Gemma-3-12B [hf:google/gemma-3-1b-pt family] — 5:1 local:global attention
pattern (every 6th layer global), 1024-token sliding window on local layers,
128k context. 48L d_model=3840 16H (GQA kv=8) head_dim=256 d_ff=15360
vocab=262144."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    sliding_window=1024,
    global_every=6,          # 5 local : 1 global
    rope_theta=1_000_000.0,
)

REDUCED = dataclasses.replace(
    CONFIG, name="gemma3-reduced", n_layers=2, d_model=256, n_heads=4,
    n_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512,
    sliding_window=64, global_every=2,
)
