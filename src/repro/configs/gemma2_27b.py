"""Gemma-2-27B [arXiv:2408.00118] — alternating local/global attention
(every 2nd layer global), 4096 sliding window, attention-logit softcap 50,
final-logit softcap 30. 46L d_model=4608 32H (GQA kv=16) head_dim=128
d_ff=36864 vocab=256000."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    sliding_window=4096,
    global_every=2,          # local, global, local, global, ...
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
)

REDUCED = dataclasses.replace(
    CONFIG, name="gemma2-reduced", n_layers=2, d_model=256, n_heads=4,
    n_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512,
    sliding_window=64, global_every=2,
)
