"""Phi-3.5-MoE-instruct (42B total / 6.6B active)
[hf:microsoft/Phi-3.5-MoE-instruct] — 16 experts, top-2 routing.
32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    moe_experts=16,
    moe_top_k=2,
    tie_embeddings=False,
)

REDUCED = dataclasses.replace(
    CONFIG, name="phi35-moe-reduced", n_layers=2, d_model=256, n_heads=4,
    n_kv_heads=2, d_ff=512, vocab_size=512, moe_experts=4,
)
