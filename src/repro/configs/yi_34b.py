"""Yi-34B [arXiv:2403.04652] — llama-architecture dense GQA.
60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    rope_theta=5_000_000.0,
    tie_embeddings=False,
)

REDUCED = dataclasses.replace(
    CONFIG, name="yi-reduced", n_layers=2, d_model=448, n_heads=7,
    n_kv_heads=1, d_ff=1024, vocab_size=512,
)
