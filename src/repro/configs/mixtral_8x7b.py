"""Mixtral-8x7B [arXiv:2401.04088] — 8 experts top-2 MoE with sliding-window
attention (4096). 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
Also one of RAGCache's own large-model evaluation targets (paper §7.2)."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    moe_experts=8,
    moe_top_k=2,
    sliding_window=4096,
    global_every=0,          # SWA on every layer
    tie_embeddings=False,
)

REDUCED = dataclasses.replace(
    CONFIG, name="mixtral-reduced", n_layers=2, d_model=256, n_heads=4,
    n_kv_heads=2, d_ff=512, vocab_size=512, moe_experts=4, sliding_window=64,
)
