"""LLaMA2-7B [arXiv:2307.09288] — RAGCache evaluation model (paper Table 1):
32L, MHA 32/32 heads, KV 0.5 MiB/token (4x Mistral's — drives the paper's
hit-rate gap between the two models)."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama2-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab_size=32000,
    tie_embeddings=False,
)

REDUCED = dataclasses.replace(
    CONFIG, name="llama2-reduced", n_layers=2, d_model=256, n_heads=4,
    n_kv_heads=4, d_ff=512, vocab_size=512,
)
