"""xLSTM-1.3B [arXiv:2405.04517] — sLSTM + mLSTM blocks (xLSTM[7:1] ratio:
every 8th block is sLSTM). 48L d_model=2048 4H vocab=50304, d_ff=0 (the
mLSTM up/down projection is the mixer)."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_every=8,          # xLSTM[7:1]: 7 mLSTM then 1 sLSTM per period
    proj_factor=2.0,
    conv_kernel=4,
)

REDUCED = dataclasses.replace(
    CONFIG, name="xlstm-reduced", n_layers=2, d_model=256, n_heads=4,
    n_kv_heads=4, vocab_size=512, slstm_every=2,
)
