"""Mistral-7B [arXiv:2310.06825] — RAGCache's primary evaluation model
(paper Table 1): 32L, 32 Q / 8 KV heads, SWA 4096, KV 0.125 MiB/token."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    sliding_window=4096,
    global_every=0,
    tie_embeddings=False,
)

REDUCED = dataclasses.replace(
    CONFIG, name="mistral-reduced", n_layers=2, d_model=256, n_heads=4,
    n_kv_heads=2, d_ff=512, vocab_size=512, sliding_window=64,
)
