"""Unified decoder-only model covering all assigned architecture families.

Entry points (all pure functions of (cfg, params, ...)):

  init_params(cfg, key)                 -> params pytree
  forward(cfg, params, inputs)          -> logits            (training path)
  prefill(cfg, params, inputs, prefix)  -> logits, cache     (serving prefill,
                                           optionally on top of a cached
                                           document-prefix — the RAGCache hook)
  decode_step(cfg, params, tokens, cache, pos) -> logits, cache

Layers are stacked and scanned (`lax.scan`) so 48–80-layer configs lower to a
small HLO even under 512-way SPMD partitioning.  Per-layer heterogeneity
(sliding-window vs global attention) rides along as a scanned int array.
The xLSTM family scans over *periods* (k−1 mLSTM blocks + 1 sLSTM block) so
heterogeneous block types need no dead parameters.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig
from repro.models import layers as L


# ==========================================================================
# parameter init
# ==========================================================================

def _norm_init(key, shape, scale):
    return (jax.random.normal(key, shape, jnp.float32) * scale)


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    D, F, V, nl = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.n_layers
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = cfg.jdtype
    keys = iter(jax.random.split(key, 64))
    scale = 0.02
    out_scale = scale / (2 * nl) ** 0.5

    def mk(shape, s=scale):
        return _norm_init(next(keys), shape, s).astype(dt)

    params: Dict[str, Any] = {}
    if cfg.n_codebooks:
        params["embed"] = mk((cfg.n_codebooks, V, D))
    else:
        params["embed"] = mk((V, D))
    if cfg.family == "vlm":
        params["vision_proj"] = mk((D, D))
    params["final_norm"] = jnp.zeros((D,), dt)
    if not cfg.tie_embeddings:
        params["lm_head"] = mk((D, V * max(1, cfg.n_codebooks)))

    if cfg.family == "ssm":
        params["blocks"] = _init_xlstm_blocks(cfg, next(keys))
        return params

    blk: Dict[str, Any] = {
        "ln1": jnp.zeros((nl, D), dt),
        "wq": mk((nl, D, H * hd)),
        "wk": mk((nl, D, KV * hd)),
        "wv": mk((nl, D, KV * hd)),
        "wo": mk((nl, H * hd, D), out_scale),
        "ln2": jnp.zeros((nl, D), dt),
    }
    if cfg.qkv_bias:
        blk["bq"] = jnp.zeros((nl, H * hd), dt)
        blk["bk"] = jnp.zeros((nl, KV * hd), dt)
        blk["bv"] = jnp.zeros((nl, KV * hd), dt)
    if cfg.moe_experts:
        E = cfg.moe_experts
        blk["router"] = mk((nl, D, E))
        blk["wg"] = mk((nl, E, D, F))
        blk["wu"] = mk((nl, E, D, F))
        blk["wd"] = mk((nl, E, F, D), out_scale)
    else:
        blk["wg"] = mk((nl, D, F))
        blk["wu"] = mk((nl, D, F))
        blk["wd"] = mk((nl, F, D), out_scale)
    if cfg.family == "hybrid":
        N = cfg.ssm_state
        blk["ssm_ln"] = jnp.zeros((nl, D), dt)
        blk["ssm_in"] = mk((nl, D, H * hd))
        blk["ssm_dt"] = mk((nl, D, H))
        blk["ssm_B"] = mk((nl, D, N))
        blk["ssm_C"] = mk((nl, D, N))
        blk["ssm_A"] = -jnp.exp(
            _norm_init(next(keys), (nl, H, hd, N), 1.0)
        ).astype(jnp.float32)
        blk["ssm_D"] = jnp.ones((nl, H, hd), jnp.float32)
        blk["ssm_out"] = mk((nl, H * hd, D), out_scale)
    params["blocks"] = blk
    return params


def _init_xlstm_blocks(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    D = cfg.d_model
    Dp = int(cfg.proj_factor * D)
    H = cfg.n_heads
    hd_s = D // H                      # sLSTM head dim (model space)
    F2 = max(128, (4 * D // 3) // 128 * 128)
    dt = cfg.jdtype
    keys = iter(jax.random.split(key, 32))
    scale = 0.02
    out_scale = scale / (2 * cfg.n_layers) ** 0.5

    def mk(lead, shape, s=scale):
        return _norm_init(next(keys), lead + shape, s).astype(dt)

    if cfg.slstm_every > 0:
        period = cfg.slstm_every
        n_periods = cfg.n_layers // period
        m_lead = (n_periods, period - 1)
        s_lead = (n_periods,)
    else:
        m_lead = (cfg.n_layers,)
        s_lead = (0,)

    mblk = {
        "ln": jnp.zeros(m_lead + (D,), dt),
        "w_up": mk(m_lead, (D, 2 * Dp)),
        "conv_w": mk(m_lead, (cfg.conv_kernel, Dp)),
        "wq": mk(m_lead, (Dp, Dp)),
        "wk": mk(m_lead, (Dp, Dp)),
        "wv": mk(m_lead, (Dp, Dp)),
        "w_if": mk(m_lead, (Dp, 2 * H)),
        "b_if": jnp.zeros(m_lead + (2 * H,), dt),
        "gn": jnp.zeros(m_lead + (Dp,), dt),
        "w_down": mk(m_lead, (Dp, D), out_scale),
    }
    out = {"mlstm": mblk}
    if cfg.slstm_every > 0:
        out["slstm"] = {
            "ln": jnp.zeros(s_lead + (D,), dt),
            "w_x": mk(s_lead, (D, 4 * D)),
            "b_x": jnp.zeros(s_lead + (4 * D,), dt),
            "r_w": mk(s_lead, (H, hd_s, 4 * hd_s)),
            "gn": jnp.zeros(s_lead + (D,), dt),
            "ln2": jnp.zeros(s_lead + (D,), dt),
            "wg": mk(s_lead, (D, F2)),
            "wu": mk(s_lead, (D, F2)),
            "wd": mk(s_lead, (F2, D), out_scale),
        }
    return out


# ==========================================================================
# embeddings / heads
# ==========================================================================

def embed_inputs(cfg: ModelConfig, params, inputs: Dict[str, jax.Array]):
    """Returns (x, positions_offset_is_zero). Handles text/vlm/audio."""
    emb = params["embed"]
    if cfg.n_codebooks:
        toks = inputs["tokens"]                       # (B, K, S)
        x = jnp.zeros(toks.shape[:1] + toks.shape[2:] + (cfg.d_model,), cfg.jdtype)
        for kk in range(cfg.n_codebooks):
            x = x + jnp.take(emb[kk], toks[:, kk], axis=0)
        return x
    toks = inputs["tokens"]                           # (B, S)
    x = jnp.take(emb, toks, axis=0)
    if cfg.family == "vlm" and "patch_embeds" in inputs:
        pe = inputs["patch_embeds"].astype(cfg.jdtype)          # (B, Simg, D)
        pe = L.dense(pe, params["vision_proj"])
        x = jnp.concatenate([pe, x], axis=1)
    return x


def lm_logits(cfg: ModelConfig, params, x: jax.Array) -> jax.Array:
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        emb = params["embed"]
        if cfg.n_codebooks:
            logits = jnp.einsum("bsd,kvd->bskv", x, emb)
        else:
            logits = jnp.einsum("bsd,vd->bsv", x, emb)
    else:
        logits = L.dense(x, params["lm_head"])
        if cfg.n_codebooks:
            B, S = logits.shape[:2]
            logits = logits.reshape(B, S, cfg.n_codebooks, cfg.vocab_size)
    if cfg.final_logit_softcap:
        logits = L.softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
    return logits.astype(jnp.float32)


# ==========================================================================
# attention-family block (dense / moe / hybrid / vlm / audio)
# ==========================================================================

def _ffn(cfg: ModelConfig, p, x):
    if cfg.moe_experts:
        if cfg.moe_impl == "capacity":
            return L.moe_capacity(x, p["router"], p["wg"], p["wu"], p["wd"],
                                  cfg.moe_top_k)
        return L.moe_dense(x, p["router"], p["wg"], p["wu"], p["wd"],
                           cfg.moe_top_k)
    return L.swiglu(x, p["wg"], p["wu"], p["wd"])


def _qkv(cfg: ModelConfig, p, h):
    B, S, _ = h.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = L.dense(h, p["wq"], p.get("bq")).reshape(B, S, H, hd)
    k = L.dense(h, p["wk"], p.get("bk")).reshape(B, S, KV, hd)
    v = L.dense(h, p["wv"], p.get("bv")).reshape(B, S, KV, hd)
    return q, k, v


def _attn_block_seq(cfg: ModelConfig, p, x, window, positions, q_offset,
                    prefix_kv=None, seq_par: bool = False):
    """Full-sequence attention block (train / prefill).

    prefix_kv: optional (k, v) each (B, P, KV, hd) — the RAGCache document
    prefix pulled from the knowledge tree (already roped at absolute pos).
    Returns (out, (k_full, v_full)).
    """
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _qkv(cfg, p, h)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    if prefix_kv is not None:
        pk, pv = prefix_kv
        k_full = jnp.concatenate([pk.astype(k.dtype), k], axis=1)
        v_full = jnp.concatenate([pv.astype(v.dtype), v], axis=1)
    else:
        k_full, v_full = k, v
    if seq_par and L.SEQ_PARALLEL_AXIS:
        o = L.flash_attention_seqpar(
            q, k_full, v_full, q_offset=q_offset, window=window,
            logit_cap=cfg.attn_logit_softcap, axis=L.SEQ_PARALLEL_AXIS)
        # store the per-layer cache hd-sharded: the stacked scan output is
        # otherwise batch-sharded only and dominates peak HBM at 32k
        from jax.sharding import PartitionSpec as _P
        if k_full.shape[-1] % 8 == 0:
            con = _P(None, None, None, L.SEQ_PARALLEL_AXIS)
            k_full = jax.lax.with_sharding_constraint(k_full, con)
            v_full = jax.lax.with_sharding_constraint(v_full, con)
    else:
        o = L.flash_attention(
            q, k_full, v_full,
            q_offset=q_offset, window=window,
            logit_cap=cfg.attn_logit_softcap,
        )
    B, S = x.shape[:2]
    o = L.dense_rowsum(o.reshape(B, S, -1), p["wo"])
    x = x + o
    if cfg.family == "hybrid":
        x = x + _ssm_branch_seq(cfg, p, x)[0]
    h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + _ffn(cfg, p, h2)
    return x, (k_full, v_full)


def _ssm_branch_seq(cfg: ModelConfig, p, x, state=None):
    B, S, D = x.shape
    H, hd, N = cfg.n_heads, cfg.hd, cfg.ssm_state
    h = L.rms_norm(x, p["ssm_ln"], cfg.norm_eps)
    xin = jax.nn.silu(L.dense(h, p["ssm_in"])).reshape(B, S, H, hd)
    delta = jax.nn.softplus(L.dense(h, p["ssm_dt"]).astype(jnp.float32))
    Bm = L.dense(h, p["ssm_B"])
    Cm = L.dense(h, p["ssm_C"])
    y, new_state = L.mamba_scan(xin, delta, p["ssm_A"], Bm, Cm, p["ssm_D"],
                                state=state)
    out = L.dense(y.reshape(B, S, H * hd), p["ssm_out"])
    return out, new_state


def _attn_block_decode(cfg: ModelConfig, p, x, window, pos, k_cache, v_cache,
                       ssm_state=None):
    """One-token decode block. pos: (B,) length *after* appending this token.
    k_cache/v_cache: (B, Smax, KV, hd). Returns out + updated caches."""
    B = x.shape[0]
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _qkv(cfg, p, h)                          # S == 1
    rope_pos = (pos - 1)[:, None]
    q = L.apply_rope(q, rope_pos, cfg.rope_theta)
    k = L.apply_rope(k, rope_pos, cfg.rope_theta)
    bidx = jnp.arange(B)
    k_cache = k_cache.at[bidx, pos - 1].set(k[:, 0].astype(k_cache.dtype))
    v_cache = v_cache.at[bidx, pos - 1].set(v[:, 0].astype(v_cache.dtype))
    o = L.decode_attention(q, k_cache, v_cache, pos=pos, window=window,
                           logit_cap=cfg.attn_logit_softcap)
    o = L.dense_rowsum(o.reshape(B, 1, -1), p["wo"])
    x = x + o
    new_ssm = None
    if cfg.family == "hybrid":
        y, new_ssm = _ssm_branch_seq(cfg, p, x, state=ssm_state)
        x = x + y
    h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + _ffn(cfg, p, h2)
    return x, k_cache, v_cache, new_ssm


# ==========================================================================
# xLSTM blocks
# ==========================================================================

def _mlstm_block(cfg: ModelConfig, p, x, state=None):
    """state: (C, n, m, conv_buf) or None. Returns (x_out, new_state)."""
    B, S, D = x.shape
    Dp = int(cfg.proj_factor * D)
    H = cfg.n_heads
    hd = Dp // H
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    up = L.dense(h, p["w_up"])
    xp, z = jnp.split(up, 2, axis=-1)                  # (B, S, Dp) each
    conv_buf = state[3] if state is not None else None
    xc, conv_buf = L.causal_conv1d(xp, p["conv_w"], conv_buf)
    xc = jax.nn.silu(xc)
    q = L.dense(xc, p["wq"]).reshape(B, S, H, hd)
    k = L.dense(xc, p["wk"]).reshape(B, S, H, hd)
    v = L.dense(xp, p["wv"]).reshape(B, S, H, hd)
    gif = L.dense(xc, p["w_if"], p["b_if"])            # (B, S, 2H)
    i_g, f_g = jnp.split(gif, 2, axis=-1)
    mstate = None if state is None else state[:3]
    if S == 1:
        hout, (C, n, m) = L.mlstm_scan(q, k, v, i_g, f_g, state=mstate)
    else:
        # chunkwise-parallel form: MXU matmuls intra-chunk, O(1) BPTT
        # residuals per chunk (docs/ARCHITECTURE.md §3)
        hout, (C, n, m) = L.mlstm_chunkwise(q, k, v, i_g, f_g, state=mstate)
    hout = hout.reshape(B, S, Dp)
    hout = L.rms_norm(hout, p["gn"], cfg.norm_eps)
    hout = hout * jax.nn.silu(z)
    return x + L.dense(hout, p["w_down"]), (C, n, m, conv_buf)


def _slstm_block(cfg: ModelConfig, p, x, state=None):
    B, S, D = x.shape
    H = cfg.n_heads
    hd = D // H
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    zifo = L.dense(h, p["w_x"], p["b_x"]).reshape(B, S, H, 4 * hd)
    out, new_state = L.slstm_scan(zifo, p["r_w"], state)
    out = out.reshape(B, S, D)
    out = L.rms_norm(out, p["gn"], cfg.norm_eps)
    x = x + out
    h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + L.swiglu(h2, p["wg"], p["wu"], p["wd"])
    return x, new_state


def _xlstm_init_state(cfg: ModelConfig, batch: int):
    D = cfg.d_model
    Dp = int(cfg.proj_factor * D)
    H = cfg.n_heads
    hd_m, hd_s = Dp // H, D // H
    K = cfg.conv_kernel

    def m_state(lead):
        return (
            jnp.zeros(lead + (batch, H, hd_m, hd_m), jnp.float32),
            jnp.zeros(lead + (batch, H, hd_m), jnp.float32),
            jnp.full(lead + (batch, H), L.NEG_INF, jnp.float32),
            jnp.zeros(lead + (batch, K - 1, Dp), cfg.jdtype),
        )

    def s_state(lead):
        return (
            jnp.zeros(lead + (batch, H, hd_s), jnp.float32),
            jnp.ones(lead + (batch, H, hd_s), jnp.float32),
            jnp.zeros(lead + (batch, H, hd_s), jnp.float32),
            jnp.zeros(lead + (batch, H, hd_s), jnp.float32),
        )

    if cfg.slstm_every > 0:
        period = cfg.slstm_every
        np_ = cfg.n_layers // period
        return {"mlstm": m_state((np_, period - 1)), "slstm": s_state((np_,))}
    return {"mlstm": m_state((cfg.n_layers,)), "slstm": None}


def _run_xlstm(cfg: ModelConfig, params, x, state):
    """Scan xLSTM blocks. state is the full stacked state pytree (required —
    use _xlstm_init_state for fresh). Returns (x, new_state)."""
    mblk = params["blocks"]["mlstm"]

    def m_layer(x, pst):
        p, st = pst
        x, st = _mlstm_block(cfg, p, x, st)
        return x, st

    if cfg.slstm_every > 0:
        sblk = params["blocks"]["slstm"]

        def period_body(x, xs):
            mp, mst, sp, sst = xs
            x, mst_new = lax.scan(m_layer, x, (mp, mst))
            x, sst_new = _slstm_block(cfg, sp, x, sst)
            return x, (mst_new, sst_new)

        x, (mst, sst) = lax.scan(
            period_body, x,
            (mblk, state["mlstm"], sblk, state["slstm"]),
        )
        return x, {"mlstm": mst, "slstm": sst}

    x, mst = lax.scan(m_layer, x, (mblk, state["mlstm"]))
    return x, {"mlstm": mst, "slstm": None}


# ==========================================================================
# public entry points
# ==========================================================================

def _layer_windows_arr(cfg: ModelConfig) -> jax.Array:
    return jnp.asarray(cfg.layer_windows(), jnp.int32)


def forward_hidden(cfg: ModelConfig, params, inputs: Dict[str, jax.Array],
                   *, remat: bool = False) -> jax.Array:
    """Training-path forward: full sequence, returns final hidden states."""
    x = embed_inputs(cfg, params, inputs)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)

    if cfg.family == "ssm":
        state = _xlstm_init_state(cfg, x.shape[0])
        x, _ = _run_xlstm(cfg, params, x, state)
        return x

    windows = _layer_windows_arr(cfg)

    def body(x, pw):
        p, w = pw
        out, _ = _attn_block_seq(cfg, p, x, w, positions, 0)
        return out, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, (params["blocks"], windows))
    return x


def forward(cfg: ModelConfig, params, inputs: Dict[str, jax.Array],
            *, remat: bool = False) -> jax.Array:
    return lm_logits(cfg, params,
                     forward_hidden(cfg, params, inputs, remat=remat))


def prefill(cfg: ModelConfig, params, inputs: Dict[str, jax.Array],
            prefix_cache=None, prefix_len: int = 0):
    """Serving prefill.  Returns (logits_last, cache).

    prefix_cache (RAGCache hook):
      attention families: {"k","v"} each (Lc, B, P, KV, hd)  (Lc = n_layers)
      ssm family:         stacked xLSTM state pytree (document state)
      hybrid:             {"k","v","ssm"}

    The returned cache holds the *full* sequence (prefix + new) so the
    controller can insert the new document nodes into the knowledge tree.
    """
    x = embed_inputs(cfg, params, inputs)
    B, S = x.shape[:2]

    if cfg.family == "ssm":
        state = prefix_cache if prefix_cache is not None else _xlstm_init_state(cfg, B)
        x, new_state = _run_xlstm(cfg, params, x, state)
        return lm_logits(cfg, params, x[:, -1:]), new_state

    positions = prefix_len + jnp.arange(S, dtype=jnp.int32)
    windows = _layer_windows_arr(cfg)

    if cfg.family == "hybrid":
        ssm0 = (prefix_cache["ssm"] if prefix_cache is not None
                else _hybrid_ssm_init(cfg, B))

        def body(x, xs):
            p, w, pk, pv, sst = xs
            h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
            q, k, v = _qkv(cfg, p, h)
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
            if prefix_cache is not None:
                k_full = jnp.concatenate([pk.astype(k.dtype), k], axis=1)
                v_full = jnp.concatenate([pv.astype(v.dtype), v], axis=1)
            else:
                k_full, v_full = k, v
            o = L.flash_attention(q, k_full, v_full, q_offset=prefix_len,
                                  window=w, logit_cap=cfg.attn_logit_softcap)
            x = x + L.dense_rowsum(o.reshape(B, S, -1), p["wo"])
            y, sst_new = _ssm_branch_seq(cfg, p, x, state=sst)
            x = x + y
            h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
            x = x + _ffn(cfg, p, h2)
            return x, (k_full, v_full, sst_new)

        if prefix_cache is not None:
            xs = (params["blocks"], windows, prefix_cache["k"],
                  prefix_cache["v"], ssm0)
        else:
            zk = jnp.zeros((cfg.n_layers, B, 0, cfg.n_kv_heads, cfg.hd), cfg.jdtype)
            xs = (params["blocks"], windows, zk, zk, ssm0)
        x, (ks, vs, ssm) = lax.scan(body, x, xs)
        return lm_logits(cfg, params, x[:, -1:]), {"k": ks, "v": vs, "ssm": ssm}

    def body(x, xs):
        p, w, pk, pv = xs
        out, (kf, vf) = _attn_block_seq(cfg, p, x, w, positions, prefix_len,
                                        prefix_kv=(pk, pv), seq_par=True)
        return out, (kf, vf)

    if prefix_cache is not None:
        xs = (params["blocks"], windows, prefix_cache["k"], prefix_cache["v"])
    else:
        zk = jnp.zeros((cfg.n_layers, B, 0, cfg.n_kv_heads, cfg.hd), cfg.jdtype)
        xs = (params["blocks"], windows, zk, zk)
    x, (ks, vs) = lax.scan(body, x, xs)
    return lm_logits(cfg, params, x[:, -1:]), {"k": ks, "v": vs}


def _hybrid_ssm_init(cfg: ModelConfig, batch: int):
    return jnp.zeros(
        (cfg.n_layers, batch, cfg.n_heads, cfg.hd, cfg.ssm_state), jnp.float32
    )


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Preallocated decode cache for serve_step (dense layout for dry-run)."""
    if cfg.family == "ssm":
        return _xlstm_init_state(cfg, batch)
    cache = {
        "k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd),
                       cfg.jdtype),
        "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd),
                       cfg.jdtype),
    }
    if cfg.family == "hybrid":
        cache["ssm"] = _hybrid_ssm_init(cfg, batch)
    return cache


def paged_decode_step(cfg: ModelConfig, params, tokens, k_pages, v_pages,
                      tables, counts, starts, write_blk, write_slot, pos,
                      *, attn_impl: str | None = None, mesh=None):
    """One decode iteration straight against the paged pool (RAGCache's
    steady-state hot path: no dense (L, B, S, KV, hd) re-materialization).

    k_pages/v_pages: the ``PagedKVStore`` buffers, (L, n_blocks, block, KV,
    hd).  tables/counts/starts: (B, n_slots) per-request run descriptors
    (token-level slot mapping compressed to runs — see
    kernels/paged_attention.py for the contract).  write_blk/write_slot:
    (B,) page coordinates of the token being decoded — its KV is appended
    in place per layer BEFORE attention, and ``counts`` must already
    include it.  pos: (B,) sequence length *including* that token (same
    semantics as ``decode_step``).

    Returns (logits, k_pages, v_pages).  Attention families only —
    recurrent state cannot be paged per-block.

    mesh: tensor-parallel serving — forwarded to the attention dispatch
    (per-shard Pallas via shard_map; the jnp path ignores it and lets GSPMD
    partition the sharded-KV einsums itself).
    """
    if cfg.family in ("ssm", "hybrid"):
        raise ValueError("paged decode requires per-token KV; "
                         "ssm/hybrid families use decode_step")
    from repro.kernels import ops

    x = embed_inputs(cfg, params, {"tokens": tokens})
    B = x.shape[0]
    windows = _layer_windows_arr(cfg)
    rope_pos = (pos - 1)[:, None]

    def body(carry, xs):
        x, kp, vp = carry
        p, w, li = xs
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        q, k, v = _qkv(cfg, p, h)                          # S == 1
        q = L.apply_rope(q, rope_pos, cfg.rope_theta)
        k = L.apply_rope(k, rope_pos, cfg.rope_theta)
        kp = kp.at[li, write_blk, write_slot].set(k[:, 0].astype(kp.dtype))
        vp = vp.at[li, write_blk, write_slot].set(v[:, 0].astype(vp.dtype))
        o = ops.paged_decode_attention(
            q[:, 0], kp, vp, tables, counts, starts, pos - 1, li, w,
            logit_cap=cfg.attn_logit_softcap, impl=attn_impl, mesh=mesh)
        x = x + L.dense_rowsum(o.reshape(B, 1, -1), p["wo"])
        h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + _ffn(cfg, p, h2)
        return (x, kp, vp), None

    (x, k_pages, v_pages), _ = lax.scan(
        body, (x, k_pages, v_pages),
        (params["blocks"], windows, jnp.arange(cfg.n_layers)))
    return lm_logits(cfg, params, x), k_pages, v_pages


def paged_prefill_step(cfg: ModelConfig, params, tokens, k_pages, v_pages,
                       tables, counts, starts, q_start, q_len, write_blk,
                       write_slot, *, attn_impl: str | None = None,
                       mesh=None):
    """One ragged prefill chunk computed straight against the paged pool —
    the prefill twin of ``paged_decode_step`` (no dense (L, B, S, KV, hd)
    gather, no per-chunk dense KV to re-page afterwards).

    tokens: (B, Sq) right-padded chunk token rows; row ``b`` holds
    ``q_len[b]`` valid tokens whose first sits at absolute position
    ``q_start[b]``.  k_pages/v_pages: the ``PagedKVStore`` buffers,
    (L, n_blocks, block, KV, hd).  tables/counts/starts: (B, n_slots) run
    descriptors covering the cached prefix PLUS this chunk's freshly
    allocated pages (counts include the chunk's own tokens — causal masking
    over absolute positions keeps later rows from seeing earlier garbage).
    write_blk/write_slot: (B, Sq) page coordinates for every chunk token —
    KV is scattered in place per layer BEFORE attention; padding rows point
    at the store's scratch block, which no live run ever reads.

    Returns (logits, k_pages, v_pages) with logits (B, 1, V) taken at each
    row's LAST VALID token, so the final chunk's call yields the first-token
    logits directly.  Attention families only — recurrent state cannot be
    paged per-block.

    mesh: tensor-parallel serving — forwarded to the attention dispatch
    (per-shard Pallas via shard_map; the jnp path ignores it and lets GSPMD
    partition the sharded-KV einsums itself).
    """
    if cfg.family in ("ssm", "hybrid"):
        raise ValueError("paged prefill requires per-token KV; "
                         "ssm/hybrid families use prefill")
    from repro.kernels import ops

    x = embed_inputs(cfg, params, {"tokens": tokens})
    B, Sq = tokens.shape
    windows = _layer_windows_arr(cfg)
    positions = q_start[:, None] + jnp.arange(Sq, dtype=jnp.int32)[None]

    def body(carry, xs):
        x, kp, vp = carry
        p, w, li = xs
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        q, k, v = _qkv(cfg, p, h)                          # (B, Sq, ., hd)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        kp = kp.at[li, write_blk, write_slot].set(k.astype(kp.dtype))
        vp = vp.at[li, write_blk, write_slot].set(v.astype(vp.dtype))
        o = ops.paged_prefill_attention(
            q.transpose(0, 2, 1, 3), kp, vp, tables, counts, starts,
            q_start, q_len, li, w, logit_cap=cfg.attn_logit_softcap,
            impl=attn_impl, mesh=mesh)
        x = x + L.dense_rowsum(o.transpose(0, 2, 1, 3).reshape(B, Sq, -1),
                               p["wo"])
        h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + _ffn(cfg, p, h2)
        return (x, kp, vp), None

    (x, k_pages, v_pages), _ = lax.scan(
        body, (x, k_pages, v_pages),
        (params["blocks"], windows, jnp.arange(cfg.n_layers)))
    last = jnp.clip(q_len - 1, 0, Sq - 1)
    x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)
    return lm_logits(cfg, params, x_last), k_pages, v_pages


def decode_step(cfg: ModelConfig, params, tokens, cache, pos):
    """One decode iteration.

    tokens: (B, 1) or (B, K, 1) for audio.  pos: (B,) sequence length
    *including* the token being decoded.  Returns (logits, new_cache).
    """
    inputs = {"tokens": tokens}
    x = embed_inputs(cfg, params, inputs)

    if cfg.family == "ssm":
        x, new_state = _run_xlstm(cfg, params, x, cache)
        return lm_logits(cfg, params, x), new_state

    windows = _layer_windows_arr(cfg)

    if cfg.family == "hybrid":
        def body(x, xs):
            p, w, kc, vc, sst = xs
            x, kc, vc, sst = _attn_block_decode(cfg, p, x, w, pos, kc, vc, sst)
            return x, (kc, vc, sst)

        x, (ks, vs, ssm) = lax.scan(
            body, x, (params["blocks"], windows, cache["k"], cache["v"],
                      cache["ssm"])
        )
        return lm_logits(cfg, params, x), {"k": ks, "v": vs, "ssm": ssm}

    def body(x, xs):
        p, w, kc, vc = xs
        x, kc, vc, _ = _attn_block_decode(cfg, p, x, w, pos, kc, vc)
        return x, (kc, vc)

    x, (ks, vs) = lax.scan(body, x, (params["blocks"], windows, cache["k"],
                                     cache["v"]))
    return lm_logits(cfg, params, x), {"k": ks, "v": vs}
