"""Model configuration for the repro model zoo.

One unified dataclass covers all six architecture families assigned to this
paper (dense / moe / ssm / hybrid / vlm / audio).  Family-specific fields are
zero/empty when unused.  Every config in ``repro.configs`` instantiates this.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio

    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    # attention details
    head_dim: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    sliding_window: int = 0        # 0 = full attention everywhere
    global_every: int = 1          # every k-th layer (1-indexed, i.e. layers
                                   # with (i+1) % global_every == 0) is global;
                                   # 1 = all layers global. Only meaningful if
                                   # sliding_window > 0.
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0

    # mixture of experts
    moe_experts: int = 0
    moe_top_k: int = 2
    moe_impl: str = "dense"        # dense (scan over experts) | capacity

    # xLSTM (family == "ssm")
    slstm_every: int = 0           # every k-th block is sLSTM; 0 = all mLSTM
    proj_factor: float = 2.0       # mLSTM up-projection factor
    conv_kernel: int = 4           # causal depthwise conv width in mLSTM block

    # Hymba-style hybrid (family == "hybrid")
    ssm_state: int = 0             # mamba state size per head-channel

    # MusicGen-style audio LM (family == "audio")
    n_codebooks: int = 0

    # VLM backbone (family == "vlm")
    vision_tokens: int = 0         # stub patch embeddings prepended

    tie_embeddings: bool = True
    dtype: str = "bfloat16"

    # ---- derived ------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def q_rep(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def layer_windows(self) -> Tuple[int, ...]:
        """Per-layer attention window (0 = full/global attention)."""
        if self.sliding_window <= 0:
            return tuple(0 for _ in range(self.n_layers))
        out = []
        for i in range(self.n_layers):
            is_global = self.global_every <= 1 or ((i + 1) % self.global_every == 0)
            out.append(0 if is_global else self.sliding_window)
        # if global_every==0 -> all local
        if self.global_every == 0:
            out = [self.sliding_window] * self.n_layers
        return tuple(out)

    def layer_is_slstm(self) -> Tuple[bool, ...]:
        if self.family != "ssm" or self.slstm_every <= 0:
            return tuple(False for _ in range(self.n_layers))
        return tuple(((i + 1) % self.slstm_every == 0) for i in range(self.n_layers))

    def n_params(self) -> int:
        """Approximate parameter count (used for roofline MODEL_FLOPS)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        hd, H, KV = self.hd, self.n_heads, self.n_kv_heads
        emb = V * D
        if self.n_codebooks:
            emb = self.n_codebooks * V * D
        per_layer = 0
        if self.family == "ssm":
            # mLSTM block: up-proj 2*pf*D, qkv from pf*D, down-proj
            Dp = int(self.proj_factor * D)
            per_layer = D * 2 * Dp + 3 * Dp * Dp // max(1, self.q_rep) + Dp * D + 4 * Dp
        else:
            per_layer += D * H * hd + 2 * D * KV * hd + H * hd * D  # attn
            if self.family == "hybrid":
                per_layer += D * H * hd * 2 + H * hd * D  # ssm in/out
            if self.moe_experts:
                per_layer += D * self.moe_experts + self.moe_experts * 3 * D * F
            elif F:
                per_layer += 3 * D * F
        head = 0 if self.tie_embeddings else V * D * max(1, self.n_codebooks)
        return emb + L * per_layer + head

    def n_active_params(self) -> int:
        """Active params per token (MoE counts top_k experts only)."""
        if not self.moe_experts:
            return self.n_params()
        D, F, L = self.d_model, self.d_ff, self.n_layers
        inactive = L * (self.moe_experts - self.moe_top_k) * 3 * D * F
        return self.n_params() - inactive
