"""Pure-JAX building blocks shared by every architecture in the zoo.

Memory discipline (these run at seq 4k-500k under 512-way SPMD):

* ``flash_attention`` is a custom-VJP chunked online-softmax attention —
  neither forward nor backward ever materializes (Sq, Skv) for more than one
  (q_chunk, kv_chunk) tile, exactly the schedule of the Pallas TPU kernel.
* ``mlstm_chunkwise`` is the chunkwise-parallel mLSTM form: intra-chunk
  (C x C) MXU matmuls + inter-chunk state passing, so BPTT stores only
  chunk-boundary states instead of per-step matrix memories.
* ``chunked_scan`` wraps sequential recurrences (sLSTM, mamba) in
  remat-per-chunk scans: backward recomputes inside one chunk at a time.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


# --------------------------------------------------------------------------
# norms / rope / misc
# --------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)
    angles = positions[..., None].astype(jnp.float32) * freqs
    angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return cap * jnp.tanh(x / cap)


def dense(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None) -> jax.Array:
    y = jnp.einsum("...d,df->...f", x, w)
    if b is not None:
        y = y + b
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# deterministic tensor-parallel serving
# --------------------------------------------------------------------------
# Trace-time toggle for the serving runtime's TP path.  Cross-device float
# summation (the partial-sum all-reduce GSPMD lowers row-parallel
# contractions to) is the ONE source of mesh-size-dependent numerics: its
# accumulation order differs from the single-device matmul, so logits drift
# a few ulps and near-tie argmaxes flip greedy tokens between tp sizes.
# Inside tp_deterministic(mesh), dense_rowsum() reshards its activations to
# replicated (an all-gather — pure data movement, no arithmetic) BEFORE the
# contraction; with the serving spec also replicating the row matrices
# (launch/sharding.py::serving_param_shardings), every device then computes
# the full contraction locally, bit-identical to tp=1
# (tests/test_tp_serving.py asserts token parity at mesh 1/2/4).
# Read at TRACE time only; False (the default, and everywhere outside the
# TP serving runtime) makes dense_rowsum exactly dense.  The constraint is
# a bare PartitionSpec resolved against the mesh CONTEXT tp_deterministic
# enters (never a NamedSharding closure): the ambient mesh is part of
# jit's tracing-cache key, so traces for different meshes — or for no mesh
# at all — can never be reused across each other.
_TP_REPLICATE = False


@contextlib.contextmanager
def tp_deterministic(mesh):
    """Trace model code with row-parallel contractions forced local."""
    global _TP_REPLICATE
    prev, _TP_REPLICATE = _TP_REPLICATE, True
    try:
        with mesh:
            yield
    finally:
        _TP_REPLICATE = prev


def dense_rowsum(x: jax.Array, w: jax.Array,
                 b: Optional[jax.Array] = None) -> jax.Array:
    """``dense`` for row-parallel sites (wo, wd): the contraction dim of
    ``x`` may be sharded over the model axis.  Under tp_deterministic the
    activations are gathered first so the sum never crosses devices."""
    if _TP_REPLICATE:
        from jax.sharding import PartitionSpec
        x = jax.lax.with_sharding_constraint(x, PartitionSpec())
    return dense(x, w, b)


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


# --------------------------------------------------------------------------
# flash attention (grouped GQA, custom VJP)
# --------------------------------------------------------------------------

def _chunk_mask(q_pos, k_pos, valid_kv, window):
    """(Cq, Ckv) bool mask: causal + padding + optional sliding window."""
    m = k_pos[None, :] <= q_pos[:, None]
    m &= k_pos[None, :] < valid_kv
    m &= jnp.where(window > 0, k_pos[None, :] > q_pos[:, None] - window, True)
    return m


def _flash_fwd(q, k, v, q_offset, window, kv_len, logit_cap, q_chunk, kv_chunk):
    """Returns (o, L) with o: (B, Sq, KV, R, hd), L = m + log(l): (B, Sq, KV, R).

    q: (B, Sq, KV, R, hd) grouped query; k, v: (B, Skv, KV, hd).
    """
    B, Sq, KV, R, hd = q.shape
    Skv = k.shape[1]
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    scale = hd ** -0.5

    qp = q.reshape(B, nq, q_chunk, KV, R, hd).transpose(1, 0, 2, 3, 4, 5)
    kp = k.reshape(B, nk, kv_chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vp = v.reshape(B, nk, kv_chunk, KV, hd).transpose(1, 0, 2, 3, 4)

    def q_step(_, qi_idx):
        qi, iq = qi_idx
        q_pos = q_offset + iq * q_chunk + jnp.arange(q_chunk, dtype=jnp.int32)

        def kv_step(carry, ki_vi_idx):
            o, m, l = carry
            ki, vi, ik = ki_vi_idx
            k_pos = ik * kv_chunk + jnp.arange(kv_chunk, dtype=jnp.int32)
            s = jnp.einsum("bqgrd,bkgd->bgrqk", qi.astype(jnp.float32),
                           ki.astype(jnp.float32)) * scale
            if logit_cap:
                s = softcap(s, logit_cap)
            mask = _chunk_mask(q_pos, k_pos, kv_len, window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            o_new = o * alpha[..., None] + jnp.einsum(
                "bgrqk,bkgd->bgrqd", p, vi.astype(jnp.float32))
            return (o_new, m_new, l_new), None

        o0 = jnp.zeros((B, KV, R, q_chunk, hd), jnp.float32)
        m0 = jnp.full((B, KV, R, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, R, q_chunk), jnp.float32)
        (o, m, l), _ = lax.scan(kv_step, (o0, m0, l0),
                                (kp, vp, jnp.arange(nk, dtype=jnp.int32)))
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        o = o / jnp.maximum(l[..., None], 1e-30)
        return None, (o.transpose(0, 3, 1, 2, 4), lse.transpose(0, 3, 1, 2))

    _, (o, lse) = lax.scan(q_step, None, (qp, jnp.arange(nq, dtype=jnp.int32)))
    o = o.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, KV, R, hd)
    lse = lse.transpose(1, 0, 2, 3, 4).reshape(B, Sq, KV, R)
    return o.astype(q.dtype), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def _flash(q, k, v, q_offset, window, kv_len, logit_cap, q_chunk, kv_chunk):
    o, _ = _flash_fwd(q, k, v, q_offset, window, kv_len,
                      logit_cap, q_chunk, kv_chunk)
    return o


def _flash_vjp_fwd(q, k, v, q_offset, window, kv_len,
                   logit_cap, q_chunk, kv_chunk):
    o, lse = _flash_fwd(q, k, v, q_offset, window, kv_len,
                        logit_cap, q_chunk, kv_chunk)
    return o, (q, k, v, o, lse, q_offset, window, kv_len)


def _flash_vjp_bwd(logit_cap, q_chunk, kv_chunk, res, do):
    q, k, v, o, lse, q_offset, window, kv_len = res
    B, Sq, KV, R, hd = q.shape
    Skv = k.shape[1]
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    scale = hd ** -0.5

    D = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    qp = q.reshape(B, nq, q_chunk, KV, R, hd).transpose(1, 0, 2, 3, 4, 5)
    dop = do.reshape(B, nq, q_chunk, KV, R, hd).transpose(1, 0, 2, 3, 4, 5)
    Lp = lse.reshape(B, nq, q_chunk, KV, R).transpose(1, 0, 2, 3, 4)
    Dp = D.reshape(B, nq, q_chunk, KV, R).transpose(1, 0, 2, 3, 4)
    kp = k.reshape(B, nk, kv_chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vp = v.reshape(B, nk, kv_chunk, KV, hd).transpose(1, 0, 2, 3, 4)

    def kv_step(dq_acc, kvi):
        ki, vi, ik = kvi
        k_pos = ik * kv_chunk + jnp.arange(kv_chunk, dtype=jnp.int32)

        def q_step(carry, qs):
            dk_i, dv_i = carry
            qi, doi, Li, Di, iq = qs
            q_pos = q_offset + iq * q_chunk + jnp.arange(q_chunk, dtype=jnp.int32)
            u = jnp.einsum("bqgrd,bkgd->bgrqk", qi.astype(jnp.float32),
                           ki.astype(jnp.float32)) * scale
            if logit_cap:
                s = softcap(u, logit_cap)
                dcap = 1.0 - jnp.square(s / logit_cap)
            else:
                s, dcap = u, None
            mask = _chunk_mask(q_pos, k_pos, kv_len, window)
            p = jnp.where(mask[None, None, None],
                          jnp.exp(s - Li.transpose(0, 2, 3, 1)[..., None]), 0.0)
            dv_c = jnp.einsum("bgrqk,bqgrd->bkgd", p, doi.astype(jnp.float32))
            dp = jnp.einsum("bqgrd,bkgd->bgrqk", doi.astype(jnp.float32),
                            vi.astype(jnp.float32))
            ds = p * (dp - Dp_t(Di))
            if dcap is not None:
                ds = ds * dcap
            dq_c = jnp.einsum("bgrqk,bkgd->bqgrd", ds,
                              ki.astype(jnp.float32)) * scale
            dk_c = jnp.einsum("bgrqk,bqgrd->bkgd", ds,
                              qi.astype(jnp.float32)) * scale
            return (dk_i + dk_c, dv_i + dv_c), dq_c

        def Dp_t(Di):
            return Di.transpose(0, 2, 3, 1)[..., None]

        dk0 = jnp.zeros((B, kv_chunk, KV, hd), jnp.float32)
        dv0 = jnp.zeros((B, kv_chunk, KV, hd), jnp.float32)
        (dk_i, dv_i), dq_contrib = lax.scan(
            q_step, (dk0, dv0),
            (qp, dop, Lp, Dp, jnp.arange(nq, dtype=jnp.int32)))
        return dq_acc + dq_contrib, (dk_i, dv_i)

    dq0 = jnp.zeros((nq, B, q_chunk, KV, R, hd), jnp.float32)
    dq, (dk, dv) = lax.scan(kv_step, dq0,
                            (kp, vp, jnp.arange(nk, dtype=jnp.int32)))
    dq = dq.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, KV, R, hd)
    dk = dk.transpose(1, 0, 2, 3, 4).reshape(B, Skv, KV, hd)
    dv = dv.transpose(1, 0, 2, 3, 4).reshape(B, Skv, KV, hd)
    zi = lambda x: np.zeros(np.shape(x), jax.dtypes.float0)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            zi(q_offset), zi(window), zi(kv_len))


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


# §Perf: materialize repeated KV heads before the flash einsums. Under TP,
# grouped (KV, R, hd) layouts are inexpressible when R doesn't tile the model
# axis, costing per-layer q/k all-gathers (measured 30% of mixtral prefill
# collective bytes); flat H-head layout shards cleanly at R x the KV reads.
FLAT_GQA = False


def flash_attention(
    q: jax.Array,                  # (B, Sq, H, hd)
    k: jax.Array,                  # (B, Skv, KV, hd)
    v: jax.Array,
    *,
    q_offset: jax.Array | int = 0,
    window: jax.Array | int = 0,   # 0 = full causal
    logit_cap: float = 0.0,
    kv_len: Optional[jax.Array] = None,
    q_chunk: int = 512,
    kv_chunk: int = 512,
) -> jax.Array:
    """Causal flash attention with GQA, cached-prefix offset, sliding windows
    and logit soft-capping. O(chunk²) transient memory in fwd AND bwd."""
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    if FLAT_GQA and H != KV:
        k = _repeat_kv(k, H // KV)
        v = _repeat_kv(v, H // KV)
        KV = H
    R = H // KV
    q_chunk = min(q_chunk, max(Sq, 1))
    kv_chunk = min(kv_chunk, max(Skv, 1))
    valid_kv = jnp.asarray(Skv if kv_len is None else kv_len, jnp.int32)
    qg = _pad_to(q.reshape(B, Sq, KV, R, hd), 1, q_chunk)
    kg = _pad_to(k, 1, kv_chunk)
    vg = _pad_to(v, 1, kv_chunk)
    o = _flash(qg, kg, vg, jnp.asarray(q_offset, jnp.int32),
               jnp.asarray(window, jnp.int32), valid_kv,
               float(logit_cap), q_chunk, kv_chunk)
    return o[:, :Sq].reshape(B, Sq, H, hd)


def _repeat_kv(k: jax.Array, rep: int) -> jax.Array:
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


# §Perf: sequence-parallel prefill attention. When set (e.g. "model"), the
# inference prefill path distributes *query chunks* over this mesh axis so
# ragged-head archs (yi-34b: 56 heads vs 16-way TP) run attention without
# either score all-reduces or replicated compute. Set by launch/dryrun --opt
# seq-par; numerics identical to flash_attention.
SEQ_PARALLEL_AXIS = None


def flash_attention_seqpar(
    q: jax.Array,                  # (B, Sq, H, hd)
    k: jax.Array,                  # (B, Skv, KV, hd)
    v: jax.Array,
    *,
    q_offset: jax.Array | int = 0,
    window: jax.Array | int = 0,
    logit_cap: float = 0.0,
    q_chunk: int = 512,
    kv_chunk: int = 512,
    axis: str = "model",
) -> jax.Array:
    """Forward-only flash attention with the q-chunk dim sharded over
    ``axis``: every device owns nq/|axis| query tiles and streams the full
    (replicated-over-axis, batch-sharded) KV past them. No collectives in
    the score/PV matmuls; one output reshard at the end."""
    from jax.sharding import PartitionSpec as P

    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    R = H // KV
    scale = hd ** -0.5
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    qp = _pad_to(q.reshape(B, Sq, KV, R, hd), 1, q_chunk)
    kp = _pad_to(k, 1, kv_chunk)
    vp = _pad_to(v, 1, kv_chunk)
    nq = qp.shape[1] // q_chunk
    nk = kp.shape[1] // kv_chunk
    qg = qp.reshape(B, nq, q_chunk, KV, R, hd)
    qg = jax.lax.with_sharding_constraint(
        qg, P(None, axis, None, None, None, None))
    kg = kp.reshape(B, nk, kv_chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vg = vp.reshape(B, nk, kv_chunk, KV, hd).transpose(1, 0, 2, 3, 4)

    q_off = jnp.asarray(q_offset, jnp.int32)
    win = jnp.asarray(window, jnp.int32)
    # absolute positions of every (nq, q_chunk) query
    q_pos = (q_off + jax.lax.broadcasted_iota(jnp.int32, (nq, q_chunk), 0)
             * q_chunk
             + jax.lax.broadcasted_iota(jnp.int32, (nq, q_chunk), 1))

    def kv_step(carry, kv):
        o, m, l = carry
        ki, vi, ik = kv
        k_pos = ik * kv_chunk + jnp.arange(kv_chunk, dtype=jnp.int32)
        s = jnp.einsum("bnqgrd,bkgd->bgrnqk", qg.astype(jnp.float32),
                       ki.astype(jnp.float32)) * scale
        if logit_cap:
            s = softcap(s, logit_cap)
        mask = k_pos[None, None, :] <= q_pos[..., None]
        mask &= k_pos[None, None, :] < Skv
        mask = mask & jnp.where(
            win > 0, k_pos[None, None, :] > q_pos[..., None] - win, True)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        o_new = o * alpha[..., None] + jnp.einsum(
            "bgrnqk,bkgd->bgrnqd", p, vi.astype(jnp.float32))
        return (o_new, m_new, l_new), None

    o0 = jnp.zeros((B, KV, R, nq, q_chunk, hd), jnp.float32)
    m0 = jnp.full((B, KV, R, nq, q_chunk), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, R, nq, q_chunk), jnp.float32)
    (o, m, l), _ = lax.scan(kv_step, (o0, m0, l0),
                            (kg, vg, jnp.arange(nk, dtype=jnp.int32)))
    o = o / jnp.maximum(l[..., None], 1e-30)
    o = o.transpose(0, 3, 4, 1, 2, 5).reshape(B, nq * q_chunk, H, hd)
    return o[:, :Sq].astype(q.dtype)


def decode_attention(
    q: jax.Array,                  # (B, 1, H, hd)
    k_cache: jax.Array,            # (B, Smax, KV, hd)
    v_cache: jax.Array,
    *,
    pos: jax.Array,                # (B,) cache length incl. the new token
    window: jax.Array | int = 0,
    logit_cap: float = 0.0,
) -> jax.Array:
    """Single-token attention over a (possibly sequence-sharded) cache.
    Direct contraction: scores are (B, H, Smax) — linear in context; GSPMD
    reduces over a sharded Smax with small collectives instead of gathering
    the cache."""
    B, _, H, hd = q.shape
    Smax, KV = k_cache.shape[1], k_cache.shape[2]
    R = H // KV
    scale = hd ** -0.5
    qg = q.reshape(B, KV, R, hd).astype(jnp.float32)
    s = jnp.einsum("bgrd,bkgd->bgrk", qg, k_cache.astype(jnp.float32)) * scale
    if logit_cap:
        s = softcap(s, logit_cap)
    k_pos = jnp.arange(Smax, dtype=jnp.int32)[None]
    mask = k_pos < pos[:, None]
    win = jnp.asarray(window, jnp.int32)
    mask &= jnp.where(win > 0, k_pos > pos[:, None] - 1 - win, True)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrk,bkgd->bgrd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)


# --------------------------------------------------------------------------
# remat-per-chunk sequential scan helper
# --------------------------------------------------------------------------

def chunked_scan(f, carry, xs, chunk: int = 256, remat: bool = True):
    """lax.scan(f, carry, xs) with time chunking: outer scan over chunks of
    ``chunk`` steps, inner scan rematerialized — BPTT stores only
    chunk-boundary carries."""
    S = jax.tree.leaves(xs)[0].shape[0]
    if S <= chunk:
        return lax.scan(f, carry, xs)
    nc = -(-S // chunk)
    Sp = nc * chunk
    # pad on the *time* axis; padded steps must be no-ops for the carry, so we
    # mask them: f sees a validity flag appended by the caller when needed.
    xs_p = jax.tree.map(lambda x: _pad_to(x, 0, chunk), xs)
    xs_c = jax.tree.map(lambda x: x.reshape((nc, chunk) + x.shape[1:]), xs_p)
    valid = (jnp.arange(Sp) < S).reshape(nc, chunk)

    def chunk_body(c, xv):
        x, val = xv

        def step(c2, sv):
            s, ok = sv
            new_c, y = f(c2, s)
            new_c = jax.tree.map(lambda a, b: jnp.where(ok, a, b), new_c, c2)
            return new_c, y

        return lax.scan(step, c, (x, val))

    body = jax.checkpoint(chunk_body) if remat else chunk_body
    carry, ys = lax.scan(body, carry, (xs_c, valid))
    ys = jax.tree.map(
        lambda y: y.reshape((Sp,) + y.shape[2:])[:S], ys)
    return carry, ys


# --------------------------------------------------------------------------
# feed-forward: SwiGLU + MoE
# --------------------------------------------------------------------------

def swiglu(x: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array) -> jax.Array:
    h = jax.nn.silu(dense(x, wg)) * dense(x, wu)
    return dense_rowsum(h, wd)      # row-parallel site (see dense_rowsum)


def moe_dense(x, router_w, wg, wu, wd, top_k: int):
    """Dense-compute MoE: scan over experts, weight by top-k router probs.
    Paper-faithful baseline path (data-independent shapes, expert-shardable);
    HLO FLOPs are E/top_k x the active FLOPs — visible in the roofline
    useful-ratio and addressed by moe_capacity (§Perf)."""
    E = router_w.shape[-1]
    logits = dense(x, router_w).astype(jnp.float32)
    topv, topi = lax.top_k(logits, top_k)
    gates = jax.nn.softmax(topv, axis=-1)
    oh = jax.nn.one_hot(topi, E, dtype=jnp.float32)
    w_full = (oh * gates[..., None]).sum(axis=-2)        # (B, S, E)

    # accumulate in the input dtype: the per-expert row-parallel psums move
    # (B,S,D) per expert per layer over ICI — f32 would double that traffic
    # (§Perf mixtral iteration 2; top-2 weighted sums are bf16-safe)
    acc_dt = x.dtype
    def body(acc, ew):
        wg_e, wu_e, wd_e, w_e = ew
        y = swiglu(x, wg_e, wu_e, wd_e)
        return acc + (y * w_e[..., None].astype(y.dtype)).astype(acc_dt), None

    acc0 = jnp.zeros(x.shape, acc_dt)
    acc, _ = lax.scan(body, acc0, (wg, wu, wd, jnp.moveaxis(w_full, -1, 0)))
    return acc.astype(x.dtype)


def moe_capacity(x, router_w, wg, wu, wd, top_k: int, *,
                 capacity_factor: float = 1.25, token_chunk: int = 4096):
    """Capacity-based dispatch MoE (beyond-paper perf path): chunked one-hot
    dispatch/combine einsums; each expert computes only its buffer."""
    B, S, D = x.shape
    E = router_w.shape[-1]
    xt = x.reshape(B * S, D)
    T = B * S
    token_chunk = min(token_chunk, T)
    n_chunks = -(-T // token_chunk)
    Tp = n_chunks * token_chunk
    xt = jnp.pad(xt, ((0, Tp - T), (0, 0)))
    xt = xt.reshape(n_chunks, token_chunk, D)
    cap = max(int(capacity_factor * token_chunk * top_k / E), 1)

    def chunk_body(_, xc):
        logits = dense(xc, router_w).astype(jnp.float32)
        topv, topi = lax.top_k(logits, top_k)
        gates = jax.nn.softmax(topv, axis=-1)
        oh = jax.nn.one_hot(topi, E, dtype=jnp.float32)          # (C,k,E)
        pos = jnp.cumsum(oh.reshape(-1, E), axis=0).reshape(oh.shape) * oh - 1.0
        keep = (pos < cap) & (oh > 0)
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
        disp = oh[..., None] * keep[..., None] * pos_oh          # (C,k,E,cap)
        disp_ce = disp.sum(axis=1)                               # (C,E,cap)
        xbuf = jnp.einsum("ceC,cd->eCd", disp_ce,
                          xc.astype(jnp.float32)).astype(xc.dtype)
        h = jax.nn.silu(jnp.einsum("eCd,edf->eCf", xbuf, wg))
        h = h * jnp.einsum("eCd,edf->eCf", xbuf, wu)
        ybuf = jnp.einsum("eCf,efd->eCd", h, wd)
        comb = (disp * gates[:, :, None, None]).sum(axis=1)      # (C,E,cap)
        yc = jnp.einsum("ceC,eCd->cd", comb, ybuf.astype(jnp.float32))
        return None, yc.astype(xc.dtype)

    _, y = lax.scan(chunk_body, None, xt)
    return y.reshape(Tp, D)[:T].reshape(B, S, D)


# --------------------------------------------------------------------------
# causal depthwise conv (mLSTM input path)
# --------------------------------------------------------------------------

def causal_conv1d(x: jax.Array, w: jax.Array, state: Optional[jax.Array] = None):
    """x: (B, S, D), w: (K, D); state carries the last K-1 inputs."""
    K = w.shape[0]
    if K == 1:
        return x * w[0], state
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xc = jnp.concatenate([state, x], axis=1)
    y = sum(xc[:, i: i + x.shape[1]] * w[i] for i in range(K))
    new_state = xc[:, -(K - 1):]
    return y.astype(x.dtype), new_state


# --------------------------------------------------------------------------
# mLSTM: chunkwise-parallel (train/prefill) + recurrent (decode)
# --------------------------------------------------------------------------

def _mlstm_init(B, H, hd):
    return (jnp.zeros((B, H, hd, hd), jnp.float32),
            jnp.zeros((B, H, hd), jnp.float32),
            jnp.full((B, H), NEG_INF, jnp.float32))


def mlstm_scan(q, k, v, i_gate, f_gate, state=None):
    """Strictly-recurrent mLSTM (matrix memory, stabilized exp gating).
    Used for S==1 decode and as the oracle for the chunkwise form."""
    B, S, H, hd = q.shape
    C0, n0, m0 = state if state is not None else _mlstm_init(B, H, hd)
    qs = jnp.moveaxis(q.astype(jnp.float32), 1, 0)
    ks = jnp.moveaxis(k.astype(jnp.float32), 1, 0) * (hd ** -0.5)
    vs = jnp.moveaxis(v.astype(jnp.float32), 1, 0)
    igs = jnp.moveaxis(i_gate.astype(jnp.float32), 1, 0)
    fgs = jnp.moveaxis(f_gate.astype(jnp.float32), 1, 0)

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, ig, fg = xs
        log_f = -jax.nn.softplus(-fg)
        m_new = jnp.maximum(log_f + m, ig)
        i_sc = jnp.exp(ig - m_new)
        f_sc = jnp.exp(log_f + m - m_new)
        C = f_sc[..., None, None] * C + i_sc[..., None, None] * (
            vt[..., :, None] * kt[..., None, :])
        n = f_sc[..., None] * n + i_sc[..., None] * kt
        num = jnp.einsum("bhij,bhj->bhi", C, qt)
        den = jnp.abs(jnp.einsum("bhj,bhj->bh", n, qt))
        floor = jnp.exp(jnp.minimum(-m_new, 30.0))
        h = num / jnp.maximum(den, floor)[..., None]
        return (C, n, m_new), h

    (C, n, m), hs = lax.scan(step, (C0, n0, m0), (qs, ks, vs, igs, fgs))
    return jnp.moveaxis(hs, 0, 1).astype(q.dtype), (C, n, m)


def mlstm_chunkwise(q, k, v, i_gate, f_gate, state=None, chunk: int = 256):
    """Chunkwise-parallel mLSTM (TPU-native form, docs/ARCHITECTURE.md §3).

    Within a chunk everything is (C x C)/(C x hd) matmuls (MXU-friendly);
    across chunks only the (hd x hd) state passes, so BPTT residuals are
    chunk-boundary states instead of per-step matrix memories.
    Matches ``mlstm_scan`` bit-for-bit up to fp assoc error.
    """
    B, S, H, hd = q.shape
    state = state if state is not None else _mlstm_init(B, H, hd)
    chunk = min(chunk, S)
    nc = -(-S // chunk)
    Sp = nc * chunk
    padt = lambda x: _pad_to(x, 1, chunk)
    qf = padt(q.astype(jnp.float32))
    kf = padt(k.astype(jnp.float32)) * (hd ** -0.5)
    vf = padt(v.astype(jnp.float32))
    # padded steps: no input (i = -inf), no decay (log f = 0 via f = +inf)
    ig = jnp.pad(i_gate.astype(jnp.float32), ((0, 0), (0, Sp - S), (0, 0)),
                 constant_values=NEG_INF)
    fg = jnp.pad(f_gate.astype(jnp.float32), ((0, 0), (0, Sp - S), (0, 0)),
                 constant_values=80.0)

    resh = lambda x: jnp.moveaxis(
        x.reshape((B, nc, chunk) + x.shape[2:]), 1, 0)
    qc, kc, vc, igc, fgc = map(resh, (qf, kf, vf, ig, fg))

    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def chunk_body(carry, xs):
        C_in, n_in, m_in = carry               # (B,H,hd,hd), (B,H,hd), (B,H)
        qi, ki, vi, ii, fi = xs                # (B,C,H,...)
        lf = -jax.nn.softplus(-fi)             # (B,C,H)
        F = jnp.cumsum(lf, axis=1)
        g = ii - F                             # (B,C,H)
        Mt = jnp.maximum(m_in[:, None], lax.cummax(g, axis=1))
        m_t = F + Mt
        in_scale = jnp.exp(m_in[:, None] - Mt)             # (B,C,H)
        # intra-chunk scores: A[t,s] = (q_t . k_s) * exp(g_s - M_t), s <= t
        qk = jnp.einsum("bthd,bshd->bhts", qi, ki)
        wts = jnp.exp(g.transpose(0, 2, 1)[:, :, None, :]
                      - Mt.transpose(0, 2, 1)[:, :, :, None])   # (B,H,t,s)
        A = qk * wts * causal[None, None]
        # outputs
        Cq = jnp.einsum("bhij,bthj->bthi", C_in, qi)
        num = in_scale[..., None] * Cq + jnp.einsum("bhts,bshd->bthd", A, vi)
        nq = jnp.einsum("bhj,bthj->bth", n_in, qi)         # (B,C,H)
        den = in_scale * nq + jnp.einsum("bhts->bth", A)
        floor = jnp.exp(jnp.minimum(-m_t, 30.0))
        h = num / jnp.maximum(jnp.abs(den), floor)[..., None]
        # state update to chunk end
        MT = Mt[:, -1]                                       # (B,H)
        state_scale = jnp.exp(m_in - MT)                     # (B,H)
        wk = jnp.exp(g - MT[:, None])                        # (B,C,H)
        C_out = state_scale[..., None, None] * C_in + jnp.einsum(
            "bshd,bsh,bshe->bhde", vi, wk, ki)
        n_out = state_scale[..., None] * n_in + jnp.einsum(
            "bsh,bshd->bhd", wk, ki)
        m_out = F[:, -1] + MT
        return (C_out, n_out, m_out), h

    (C, n, m), hs = lax.scan(jax.checkpoint(chunk_body), state,
                             (qc, kc, vc, igc, fgc))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, Sp, H, hd)[:, :S]
    return h.astype(q.dtype), (C, n, m)


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------

def slstm_scan(zifo, r_w, state=None, chunk: int = 256):
    """sLSTM: scalar memory, block-diagonal recurrence, exp gating.
    zifo: (B, S, H, 4*hd) input pre-activations."""
    B, S, H, hd4 = zifo.shape
    hd = hd4 // 4
    if state is None:
        c0 = jnp.zeros((B, H, hd), jnp.float32)
        n0 = jnp.ones((B, H, hd), jnp.float32)
        h0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.zeros((B, H, hd), jnp.float32)
        state = (c0, n0, h0, m0)
    xs = jnp.moveaxis(zifo.astype(jnp.float32), 1, 0)
    rw = r_w.astype(jnp.float32)

    def step(carry, xt):
        c, n, h, m = carry
        rec = jnp.einsum("bhi,hio->bho", h, rw)
        z, i, f, o = jnp.split(xt + rec, 4, axis=-1)
        z = jnp.tanh(z)
        o = jax.nn.sigmoid(o)
        log_f = -jax.nn.softplus(-f)
        m_new = jnp.maximum(log_f + m, i)
        i_sc = jnp.exp(i - m_new)
        f_sc = jnp.exp(log_f + m - m_new)
        c = f_sc * c + i_sc * z
        n = f_sc * n + i_sc
        h = o * c / jnp.maximum(n, 1.0)
        return (c, n, h, m_new), h

    (c, n, h, m), hs = chunked_scan(step, state, xs, chunk=chunk)
    return jnp.moveaxis(hs, 0, 1).astype(zifo.dtype), (c, n, h, m)


# --------------------------------------------------------------------------
# Mamba-style selective SSM (Hymba hybrid heads)
# --------------------------------------------------------------------------

def mamba_scan(x, delta, A, Bm, Cm, D, state=None, chunk: int = 256):
    """h_t = exp(delta_t A) h_{t-1} + delta_t (B_t ⊗ x_t); y = C_t·h + D x.
    Remat-per-chunk scan; state (B, H, hd, N) is the SSM document-cache
    payload."""
    Bb, S, H, hd = x.shape
    N = A.shape[-1]
    if state is None:
        state = jnp.zeros((Bb, H, hd, N), jnp.float32)
    xs = jnp.moveaxis(x.astype(jnp.float32), 1, 0)
    ds = jnp.moveaxis(delta.astype(jnp.float32), 1, 0)
    Bs = jnp.moveaxis(Bm.astype(jnp.float32), 1, 0)
    Cs = jnp.moveaxis(Cm.astype(jnp.float32), 1, 0)
    Af = A.astype(jnp.float32)

    def step(h, xt):
        xv, dt, bt, ct = xt
        decay = jnp.exp(dt[..., None, None] * Af[None])
        inp = (dt[..., None] * xv)[..., None] * bt[:, None, None, :]
        h = decay * h + inp
        y = jnp.einsum("bhdn,bn->bhd", h, ct) + D[None] * xv
        return h, y

    state, ys = chunked_scan(step, state, (xs, ds, Bs, Cs), chunk=chunk)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state
