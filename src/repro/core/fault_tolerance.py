"""Fault tolerance (paper §6).

Two mechanisms:
  * hot-node replication — a GPU failure invalidates every device-tier node
    (prefix sensitivity makes children unusable without parents), so the most
    frequently accessed upper-level nodes keep a host-memory replica even
    while resident in GPU; recovery re-seeds the tree from those replicas.
  * request retry — a request that fails before its first iteration is
    recomputed from scratch; afterwards it resumes from the stored states.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Tuple

from repro.core.knowledge_tree import KnowledgeTree, Node


def replicate_hot_nodes(tree: KnowledgeTree, budget_bytes: int) -> int:
    """Copy the highest-frequency GPU-resident upper-level nodes into host
    memory (top-down, so every replica's parent is replicated first).
    Returns bytes replicated. Uses the swap-out path, so a later GPU
    eviction of these nodes is a zero-copy free."""
    done = 0
    frontier: List[Node] = [c for c in tree.root.children.values() if c.in_gpu]
    while frontier and done < budget_bytes:
        frontier.sort(key=lambda n: -n.frequency)
        node = frontier.pop(0)
        if not node.in_host:
            if tree.host_used + node.bytes_ > tree.host_capacity:
                tree.evict_host(node.bytes_)
            if tree.host_used + node.bytes_ > tree.host_capacity:
                break
            tree.backend.swap_out(node)
            node.in_host = True
            node.swapped_once = True
            tree.host_used += node.bytes_
            done += node.bytes_
        kids = [c for c in node.children.values() if c.in_gpu]
        kids.sort(key=lambda n: -n.frequency)
        frontier.extend(kids)
    return done


def _drop_cold_copies(tree: KnowledgeTree, n: Node) -> None:
    """Free a node's host and disk copies (with accounting) — the slower
    tiers are worthless once the node is unreachable from a cached parent."""
    if n.in_host:
        tree.backend.free_host(n)
        n.in_host = False
        n.swapped_once = False
        tree.host_used -= n.bytes_
    if n.in_disk:
        tree.backend.free_disk(n)
        n.in_disk = False
        n.spilled_once = False
        tree.disk_used -= n.bytes_


def recover_from_gpu_failure(tree: KnowledgeTree) -> Tuple[int, int]:
    """Simulated device loss: every GPU-tier payload is gone.  Nodes with a
    host or disk copy survive (demoted off the device); the rest are freed,
    and slower-tier state stranded under a lost parent is reclaimed too —
    match_prefix can never reach it again, so keeping it (or its mmap
    segment files) would be a permanent leak.  Returns (nodes_recovered,
    nodes_lost).  Tier invariants hold afterwards."""
    recovered = lost = 0
    # top-down so a node's fate can depend on its parent's outcome (a lost
    # parent dooms the whole subtree, however many replicas it holds)
    nodes = sorted(tree.nodes(), key=lambda n: len(n.path()))
    dropped: List[Node] = []
    for n in nodes:
        parent_ok = n.parent is tree.root or n.parent.cached
        if not n.in_gpu:
            if n.cached and not parent_ok:
                _drop_cold_copies(tree, n)   # orphaned by a lost ancestor
                lost += 1
                dropped.append(n)
            continue
        n.payload_gpu = None
        n.in_gpu = False
        tree.gpu_used -= n.bytes_
        if (n.in_host or n.in_disk) and parent_ok:
            recovered += 1
        else:
            _drop_cold_copies(tree, n)
            lost += 1
            dropped.append(n)
    for n in dropped:
        tree._maybe_prune(n)
    return recovered, lost


@dataclasses.dataclass
class RetryPolicy:
    max_attempts: int = 3
    timeout_s: float = 30.0


def serve_with_retry(serve_fn: Callable[[], object],
                     policy: RetryPolicy = RetryPolicy()):
    """Timeout/retry wrapper for request processing (paper §6: requests
    failing before their first iteration are recomputed)."""
    last: Optional[BaseException] = None
    for attempt in range(policy.max_attempts):
        t0 = time.time()
        try:
            return serve_fn()
        except Exception as e:  # noqa: BLE001
            last = e
            if time.time() - t0 > policy.timeout_s:
                break
    raise RuntimeError(
        f"request failed after {policy.max_attempts} attempts") from last
