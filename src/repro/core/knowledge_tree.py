"""The knowledge tree (paper §5.1): a prefix tree over *document ID
sequences* whose nodes hold the intermediate states (KV tensors / SSM states)
of one document conditioned on its path prefix, placed in a two-tier
GPU/host hierarchy with PGDSF replacement (Algorithm 1).

Tier invariant: if a node is in GPU, its parent is in GPU; if in host, its
parent is in GPU or host ("parents before children in the faster tier").
Eviction therefore only ever removes tier-leaves, and the tree hierarchy
mirrors the memory hierarchy (paper Fig. 8).

Payloads are opaque handles managed by a ``CacheBackend`` (real JAX arrays in
the serving engine, byte counters in the simulator) so the identical policy
code drives both execution modes.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.core.profiler import CostProfiler


# --------------------------------------------------------------------------
# replacement policies (PGDSF + ablation baselines, paper §7.3)
# --------------------------------------------------------------------------

class Policy:
    """Maps node stats -> eviction priority (lower evicts first)."""
    name = "base"

    def priority(self, node: "Node", clock: float) -> float:
        raise NotImplementedError


class PGDSF(Policy):
    """Priority = Clock + Frequency × AvgCost (per-non-cached-token cost from
    the bilinear profiler — Alg. 1 line 13). Prefix-aware via AvgCost."""
    name = "pgdsf"

    def priority(self, node: "Node", clock: float) -> float:
        return clock + node.frequency * node.avg_cost


class GDSF(Policy):
    """Classic GDSF with cost ∝ size (paper's ablation setting): Clock +
    Frequency × Cost/Size = Clock + Frequency × const."""
    name = "gdsf"

    def priority(self, node: "Node", clock: float) -> float:
        return clock + node.frequency * 1.0


class LRU(Policy):
    name = "lru"

    def priority(self, node: "Node", clock: float) -> float:
        return node.last_access


class LFU(Policy):
    name = "lfu"

    def priority(self, node: "Node", clock: float) -> float:
        return float(node.frequency)


POLICIES = {p.name: p for p in (PGDSF(), GDSF(), LRU(), LFU())}


# --------------------------------------------------------------------------
# backend protocol
# --------------------------------------------------------------------------

class CacheBackend:
    """Moves/free payloads between tiers; returns the seconds each move costs
    (simulated or measured). Default: pure accounting with zero cost."""

    def swap_out(self, node: "Node") -> float:   # GPU -> host copy
        node.payload_host = node.payload_gpu
        return 0.0

    def load(self, node: "Node") -> float:       # host -> GPU copy
        node.payload_gpu = node.payload_host
        return 0.0

    def free_gpu(self, node: "Node") -> None:
        node.payload_gpu = None

    def free_host(self, node: "Node") -> None:
        node.payload_host = None


@dataclasses.dataclass
class Node:
    doc_id: Optional[int]
    parent: Optional["Node"]
    n_tokens: int = 0
    bytes_: int = 0
    children: Dict[int, "Node"] = dataclasses.field(default_factory=dict)

    # PGDSF stats (Alg. 1)
    frequency: int = 0
    total_cost: float = 0.0
    num_computed: int = 0
    avg_cost: float = 0.0
    priority: float = 0.0
    last_access: float = 0.0

    in_gpu: bool = False
    in_host: bool = False
    swapped_once: bool = False
    pinned: bool = False            # in active use by a running request

    payload_gpu: object = None
    payload_host: object = None

    @property
    def cached(self) -> bool:
        return self.in_gpu or self.in_host

    def path(self) -> Tuple[int, ...]:
        ids: List[int] = []
        n: Optional[Node] = self
        while n is not None and n.doc_id is not None:
            ids.append(n.doc_id)
            n = n.parent
        return tuple(reversed(ids))

    def __hash__(self):
        return id(self)

    def __eq__(self, other):
        return self is other


class EvictionError(RuntimeError):
    pass


class KnowledgeTree:
    def __init__(
        self,
        gpu_capacity: int,
        host_capacity: int,
        *,
        policy: Policy | str = "pgdsf",
        profiler: Optional[CostProfiler] = None,
        backend: Optional[CacheBackend] = None,
        bytes_per_token: int = 1,
    ):
        self.root = Node(doc_id=None, parent=None, pinned=True)
        self.root.in_gpu = True     # shared system prompt lives in GPU
        self.gpu_capacity = gpu_capacity
        self.host_capacity = host_capacity
        self.gpu_used = 0
        self.host_used = 0
        self.gpu_clock = 0.0
        self.host_clock = 0.0
        self.policy = POLICIES[policy] if isinstance(policy, str) else policy
        self.profiler = profiler
        self.backend = backend or CacheBackend()
        self.bytes_per_token = bytes_per_token
        self._access_counter = itertools.count()
        # counters for benchmarks
        self.stats = {
            "hits": 0, "misses": 0, "gpu_evictions": 0, "host_evictions": 0,
            "swap_out_bytes": 0, "load_bytes": 0, "swap_out_skipped": 0,
        }

    # ---- lookup ----------------------------------------------------------

    def match_prefix(self, doc_ids: Sequence[int]) -> List[Node]:
        """Longest cached prefix of ``doc_ids`` (paper: O(h) traversal that
        stops at the first non-cached child). Returns matched nodes in order."""
        out: List[Node] = []
        cur = self.root
        for d in doc_ids:
            nxt = cur.children.get(d)
            if nxt is None or not nxt.cached:
                break
            out.append(nxt)
            cur = nxt
        return out

    # ---- Alg. 1: UPDATE_NODE --------------------------------------------

    def update_on_access(self, node: Node, is_cached: bool,
                         alpha: int, beta: int) -> None:
        node.frequency += 1
        node.last_access = float(next(self._access_counter))
        # cost is profiled from requests that computed the node (Eq. 3); a
        # node that has only ever been hit still needs *a* cost estimate so
        # its PGDSF priority reflects its recompute value.
        if (not is_cached or node.num_computed == 0) and beta > 0:
            if self.profiler is not None:
                t = self.profiler.estimate(alpha, beta)
            else:
                t = float(beta)  # unit cost fallback
            node.total_cost += t / beta
            node.num_computed += 1
            node.avg_cost = node.total_cost / node.num_computed
        clock = self.gpu_clock if node.in_gpu else self.host_clock
        node.priority = self.policy.priority(node, clock)

    # ---- eviction (Alg. 1 EVICT_IN_GPU + swap-out-only-once) -------------

    def _tier_leaves(self, tier: str, pinned: Set[Node]) -> List[Node]:
        """Nodes in `tier` with no child in the same-or-faster tier."""
        out = []
        stack = [self.root]
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if n is self.root or n in pinned or n.pinned:
                continue
            if tier == "gpu" and n.in_gpu:
                if not any(c.in_gpu for c in n.children.values()):
                    out.append(n)
            elif tier == "host" and n.in_host and not n.in_gpu:
                if not any(c.cached for c in n.children.values()):
                    out.append(n)
        return out

    def evict_gpu(self, required: int, pinned: Optional[Set[Node]] = None) -> float:
        """Free >= required bytes of GPU tier. Returns transfer seconds spent
        on swap-outs. Raises EvictionError if impossible (all pinned)."""
        return self.evict_gpu_until(
            lambda: self.gpu_used + required <= self.gpu_capacity, pinned)

    def evict_gpu_until(self, done: Callable[[], bool],
                        pinned: Optional[Set[Node]] = None) -> float:
        """Alg. 1 EVICT_IN_GPU driven by an arbitrary stop condition —
        shared by the byte-budget loop above and external resource reclaim
        (e.g. the runtime freeing paged-pool blocks). Raises EvictionError
        if ``done()`` is still false with no evictable leaf left."""
        pinned = pinned or set()
        cost = 0.0
        while not done():
            leaves = self._tier_leaves("gpu", pinned)
            if not leaves:
                raise EvictionError("GPU cache thrash: all nodes pinned")
            victim = min(leaves, key=lambda n: n.priority)
            self.gpu_clock = max(self.gpu_clock, victim.priority)
            cost += self._demote(victim)
            self.stats["gpu_evictions"] += 1
        return cost

    def _demote(self, node: Node) -> float:
        """GPU -> host (first time: copy; afterwards: free, zero copy)."""
        cost = 0.0
        if not node.swapped_once and self.host_capacity > 0:
            cost += self.evict_host(node.bytes_)
            if self.host_used + node.bytes_ <= self.host_capacity:
                cost += self.backend.swap_out(node)
                node.in_host = True
                node.swapped_once = True
                self.host_used += node.bytes_
                self.stats["swap_out_bytes"] += node.bytes_
        elif node.swapped_once:
            self.stats["swap_out_skipped"] += 1
        self.backend.free_gpu(node)
        node.in_gpu = False
        self.gpu_used -= node.bytes_
        # re-key priority against the host clock for its new tier
        if node.in_host:
            node.priority = self.policy.priority(node, self.host_clock)
        return cost

    def evict_host(self, required: int, pinned: Optional[Set[Node]] = None) -> float:
        pinned = pinned or set()
        while self.host_used + required > self.host_capacity:
            leaves = self._tier_leaves("host", pinned)
            if not leaves:
                return 0.0  # can't make room; caller will skip host copy
            victim = min(leaves, key=lambda n: n.priority)
            self.host_clock = max(self.host_clock, victim.priority)
            self.backend.free_host(victim)
            victim.in_host = False
            victim.swapped_once = False
            self.host_used -= victim.bytes_
            self.stats["host_evictions"] += 1
            self._maybe_prune(victim)
        return 0.0

    def _maybe_prune(self, node: Node) -> None:
        """Drop fully-uncached leaf subtrees to bound metadata growth (keeps
        frequency stats for cached/again-reachable nodes only)."""
        while (node is not None and node is not self.root and not node.cached
               and not node.children and node.parent is not None):
            parent = node.parent
            parent.children.pop(node.doc_id, None)
            node = parent

    # ---- insertion / promotion ------------------------------------------

    def insert(self, parent: Node, doc_id: int, n_tokens: int,
               payload=None, pinned: Optional[Set[Node]] = None) -> Tuple[Node, float]:
        """Create (or revive) child node in GPU tier. Returns (node, seconds)."""
        node = parent.children.get(doc_id)
        if node is None:
            node = Node(doc_id=doc_id, parent=parent, n_tokens=n_tokens,
                        bytes_=n_tokens * self.bytes_per_token)
            parent.children[doc_id] = node
        cost = 0.0
        if not node.in_gpu:
            cost += self.evict_gpu(node.bytes_, pinned)
            if self.gpu_used + node.bytes_ > self.gpu_capacity:
                raise EvictionError("node larger than GPU cache")
            node.payload_gpu = payload
            node.in_gpu = True
            self.gpu_used += node.bytes_
        else:
            # already resident: keep the existing payload — with chunked /
            # batched prefill, two in-flight requests can compute the same
            # doc segment (plan→commit windows interleave); the caller frees
            # any payload the tree did not take (it owns the storage)
            if node.payload_gpu is None and payload is not None:
                node.payload_gpu = payload
        return node, cost

    def ensure_in_gpu(self, nodes: Sequence[Node]) -> float:
        """Promote a matched prefix path into GPU (host hits pay the PCIe
        transfer — the paper's 'cache hit latency' component)."""
        cost = 0.0
        pinned = set(nodes)
        for n in nodes:
            if n.in_gpu:
                continue
            cost += self.evict_gpu(n.bytes_, pinned)
            if self.gpu_used + n.bytes_ > self.gpu_capacity:
                raise EvictionError("promotion does not fit GPU cache")
            cost += self.backend.load(n)
            n.in_gpu = True
            self.gpu_used += n.bytes_
            self.stats["load_bytes"] += n.bytes_
            n.priority = self.policy.priority(n, self.gpu_clock)
        return cost

    # ---- introspection ----------------------------------------------------

    def nodes(self) -> Iterable[Node]:
        stack = [self.root]
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if n is not self.root:
                yield n

    def check_invariants(self) -> None:
        gpu_b = host_b = 0
        for n in self.nodes():
            if n.in_gpu:
                gpu_b += n.bytes_
                p = n.parent
                assert p is self.root or p.in_gpu, "GPU node with non-GPU parent"
            if n.in_host:
                host_b += n.bytes_
                p = n.parent
                assert p is self.root or p.cached, "host node with free parent"
        assert gpu_b == self.gpu_used, (gpu_b, self.gpu_used)
        assert host_b == self.host_used, (host_b, self.host_used)
        assert self.gpu_used <= self.gpu_capacity
        assert self.host_used <= self.host_capacity
