"""The knowledge tree (paper §5.1): a prefix tree over *document ID
sequences* whose nodes hold the intermediate states (KV tensors / SSM states)
of one document conditioned on its path prefix, placed in a multi-tier
GPU/host/disk hierarchy with PGDSF replacement (Algorithm 1) run as a
generic clock cascade over the tier chain (docs/ARCHITECTURE.md §2).

Tier invariant ("parents before children in the faster tier"): if a node is
resident in tier i, its parent is resident in some tier <= i.  Eviction
therefore only ever removes tier-leaves, and the tree hierarchy mirrors the
memory hierarchy (paper Fig. 8).  Demotion cascades one tier at a time
(GPU -> host -> disk -> gone); promotion pulls the other way
(disk -> host -> GPU).  Each hop reuses the "swap-out-only-once" invariant:
a tier never recopies bytes a live slower-tier copy already holds
(``swapped_once`` for the host copy, ``spilled_once`` for the disk file).

Payloads are opaque handles managed by a ``CacheBackend`` (real JAX arrays /
mmap'd disk segments in the serving engines, byte counters in the simulator)
so the identical policy code drives both execution modes.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.core.profiler import CostProfiler

# Tier levels, fastest first.  The cascade logic is generic over this chain;
# a zero-capacity tail tier simply never receives copies.
GPU, HOST, DISK = 0, 1, 2
TIER_NAMES = ("gpu", "host", "disk")
N_TIERS = len(TIER_NAMES)


# --------------------------------------------------------------------------
# replacement policies (PGDSF + ablation baselines, paper §7.3)
# --------------------------------------------------------------------------

class Policy:
    """Maps node stats -> eviction priority (lower evicts first)."""
    name = "base"

    def priority(self, node: "Node", clock: float) -> float:
        raise NotImplementedError


class PGDSF(Policy):
    """Priority = Clock + Frequency × AvgCost (per-non-cached-token cost from
    the bilinear profiler — Alg. 1 line 13). Prefix-aware via AvgCost."""
    name = "pgdsf"

    def priority(self, node: "Node", clock: float) -> float:
        return clock + node.frequency * node.avg_cost


class GDSF(Policy):
    """Classic GDSF with cost ∝ size (paper's ablation setting): Clock +
    Frequency × Cost/Size = Clock + Frequency × const."""
    name = "gdsf"

    def priority(self, node: "Node", clock: float) -> float:
        return clock + node.frequency * 1.0


class LRU(Policy):
    name = "lru"

    def priority(self, node: "Node", clock: float) -> float:
        return node.last_access


class LFU(Policy):
    name = "lfu"

    def priority(self, node: "Node", clock: float) -> float:
        return float(node.frequency)


POLICIES = {p.name: p for p in (PGDSF(), GDSF(), LRU(), LFU())}


# --------------------------------------------------------------------------
# backend protocol
# --------------------------------------------------------------------------

class CacheBackend:
    """Moves/frees payloads between tiers; returns the seconds each move
    costs (simulated or measured). Default: pure accounting with zero cost.

    Subclasses override the named hop methods (``swap_out``/``load`` for
    GPU<->host, ``spill``/``fetch`` for host<->disk); the generic cascade in
    ``KnowledgeTree`` dispatches through ``demote_copy``/``promote_copy``/
    ``free_tier`` so the policy code never names a tier pair."""

    def swap_out(self, node: "Node") -> float:   # GPU -> host copy
        node.payload_host = node.payload_gpu
        return 0.0

    def load(self, node: "Node") -> float:       # host -> GPU copy
        node.payload_gpu = node.payload_host
        return 0.0

    def spill(self, node: "Node") -> float:      # host -> disk write
        node.payload_disk = node.payload_host
        return 0.0

    def fetch(self, node: "Node") -> float:      # disk -> host read
        node.payload_host = node.payload_disk
        return 0.0

    def free_gpu(self, node: "Node") -> None:
        node.payload_gpu = None

    def free_host(self, node: "Node") -> None:
        node.payload_host = None

    def free_disk(self, node: "Node") -> None:
        node.payload_disk = None

    # ---- generic dispatch (indexed by tier level) ------------------------

    def demote_copy(self, node: "Node", level: int) -> float:
        """Copy ``node``'s payload from tier ``level`` to tier ``level+1``."""
        return (self.swap_out, self.spill)[level](node)

    def promote_copy(self, node: "Node", level: int) -> float:
        """Copy ``node``'s payload from tier ``level`` to tier ``level-1``."""
        return (self.load, self.fetch)[level - 1](node)

    def free_tier(self, node: "Node", level: int) -> None:
        (self.free_gpu, self.free_host, self.free_disk)[level](node)


@dataclasses.dataclass
class Node:
    doc_id: Optional[int]
    parent: Optional["Node"]
    n_tokens: int = 0
    bytes_: int = 0
    children: Dict[int, "Node"] = dataclasses.field(default_factory=dict)

    # PGDSF stats (Alg. 1)
    frequency: int = 0
    total_cost: float = 0.0
    num_computed: int = 0
    avg_cost: float = 0.0
    priority: float = 0.0
    last_access: float = 0.0

    in_gpu: bool = False
    in_host: bool = False
    in_disk: bool = False
    swapped_once: bool = False      # a live host copy exists (GPU demotes free)
    spilled_once: bool = False      # a live disk file exists (host demotes free)
    pinned: bool = False            # in active use by a running request

    payload_gpu: object = None
    payload_host: object = None
    payload_disk: object = None

    # chunk-cache mode (--reuse chunk; docs/ARCHITECTURE.md §11): the doc
    # context this node's KV was actually computed after.  ``src_prefix``
    # is the preceding doc-ID tuple at compute time; ``exact_ctx`` says
    # that context was itself exact (not patched from relocated chunks).
    # A chunk hit whose requesting context equals (src_prefix, exact) is
    # bit-identical; any other placement is RELOCATED — reusable, but only
    # with boundary-token recompute, and approximate by construction.
    # Prefix mode ignores both fields (the path IS the context).
    src_prefix: Optional[Tuple[int, ...]] = None
    exact_ctx: bool = False

    @property
    def cached(self) -> bool:
        return self.in_gpu or self.in_host or self.in_disk

    def resident(self, level: int) -> bool:
        return (self.in_gpu, self.in_host, self.in_disk)[level]

    def set_resident(self, level: int, value: bool) -> None:
        if level == GPU:
            self.in_gpu = value
        elif level == HOST:
            self.in_host = value
        else:
            self.in_disk = value

    def fastest_tier(self) -> Optional[int]:
        """Fastest tier holding a copy (None = fully uncached)."""
        for level in range(N_TIERS):
            if self.resident(level):
                return level
        return None

    def path(self) -> Tuple[int, ...]:
        ids: List[int] = []
        n: Optional[Node] = self
        while n is not None and n.doc_id is not None:
            ids.append(n.doc_id)
            n = n.parent
        return tuple(reversed(ids))

    def __hash__(self):
        return id(self)

    def __eq__(self, other):
        return self is other


class EvictionError(RuntimeError):
    pass


class KnowledgeTree:
    def __init__(
        self,
        gpu_capacity: int,
        host_capacity: int,
        disk_capacity: int = 0,
        *,
        policy: Policy | str = "pgdsf",
        profiler: Optional[CostProfiler] = None,
        backend: Optional[CacheBackend] = None,
        bytes_per_token: int = 1,
    ):
        if disk_capacity > 0 and host_capacity <= 0:
            raise ValueError(
                "disk tier requires a host tier (the cascade demotes and "
                "promotes strictly one tier at a time; host stages disk I/O)")
        self.root = Node(doc_id=None, parent=None, pinned=True)
        self.root.in_gpu = True     # shared system prompt lives in GPU
        self._capacity = [int(gpu_capacity), int(host_capacity),
                          int(disk_capacity)]
        self._used = [0] * N_TIERS
        self._clocks = [0.0] * N_TIERS
        self.policy = POLICIES[policy] if isinstance(policy, str) else policy
        self.profiler = profiler
        self.backend = backend or CacheBackend()
        self.bytes_per_token = bytes_per_token
        self._access_counter = itertools.count()
        # counters for benchmarks; *_seconds are the measured/simulated
        # transfer costs per tier hop (eviction cascades bill each hop)
        self.stats = {
            "hits": 0, "misses": 0,
            "gpu_evictions": 0, "host_evictions": 0, "disk_evictions": 0,
            "swap_out_bytes": 0, "load_bytes": 0, "swap_out_skipped": 0,
            "spill_bytes": 0, "fetch_bytes": 0, "spill_skipped": 0,
            "swap_out_seconds": 0.0, "load_seconds": 0.0,
            "spill_seconds": 0.0, "fetch_seconds": 0.0,
            "orphaned_bytes": 0,
            "hit_tokens_gpu": 0, "hit_tokens_host": 0, "hit_tokens_disk": 0,
        }

    # ---- tier accessor back-compat (fault_tolerance writes these) --------

    @property
    def gpu_capacity(self) -> int:
        return self._capacity[GPU]

    @property
    def host_capacity(self) -> int:
        return self._capacity[HOST]

    @property
    def disk_capacity(self) -> int:
        return self._capacity[DISK]

    @property
    def gpu_used(self) -> int:
        return self._used[GPU]

    @gpu_used.setter
    def gpu_used(self, v: int) -> None:
        self._used[GPU] = v

    @property
    def host_used(self) -> int:
        return self._used[HOST]

    @host_used.setter
    def host_used(self, v: int) -> None:
        self._used[HOST] = v

    @property
    def disk_used(self) -> int:
        return self._used[DISK]

    @disk_used.setter
    def disk_used(self, v: int) -> None:
        self._used[DISK] = v

    @property
    def gpu_clock(self) -> float:
        return self._clocks[GPU]

    @gpu_clock.setter
    def gpu_clock(self, v: float) -> None:
        self._clocks[GPU] = v

    @property
    def host_clock(self) -> float:
        return self._clocks[HOST]

    @host_clock.setter
    def host_clock(self, v: float) -> None:
        self._clocks[HOST] = v

    @property
    def disk_clock(self) -> float:
        return self._clocks[DISK]

    # ---- lookup ----------------------------------------------------------

    def match_prefix(self, doc_ids: Sequence[int]) -> List[Node]:
        """Longest cached prefix of ``doc_ids`` (paper: O(h) traversal that
        stops at the first non-cached child). Returns matched nodes in order.
        A node counts as cached in ANY tier — a disk-resident prefix is a hit
        that pays the fetch, not a miss that pays the recompute."""
        out: List[Node] = []
        cur = self.root
        for d in doc_ids:
            nxt = cur.children.get(d)
            if nxt is None or not nxt.cached:
                break
            out.append(nxt)
            cur = nxt
        return out

    def match_chunks(self, doc_ids: Sequence[int]) -> List[Optional[Node]]:
        """Chunk-cache lookup (--reuse chunk): every doc is keyed directly
        under root — the tree is flat — so each position probes
        independently and a cached doc hits at ANY position, not just on
        the longest cached prefix.  Returns one entry per position: the
        cached root child, or None for a miss.  Like ``match_prefix``, a
        copy in any tier counts as a hit."""
        out: List[Optional[Node]] = []
        for d in doc_ids:
            n = self.root.children.get(d)
            out.append(n if n is not None and n.cached else None)
        return out

    # ---- Alg. 1: UPDATE_NODE --------------------------------------------

    def update_on_access(self, node: Node, is_cached: bool,
                         alpha: int, beta: int) -> None:
        node.frequency += 1
        node.last_access = float(next(self._access_counter))
        # cost is profiled from requests that computed the node (Eq. 3); a
        # node that has only ever been hit still needs *a* cost estimate so
        # its PGDSF priority reflects its recompute value.
        if (not is_cached or node.num_computed == 0) and beta > 0:
            if self.profiler is not None:
                t = self.profiler.estimate(alpha, beta)
            else:
                t = float(beta)  # unit cost fallback
            node.total_cost += t / beta
            node.num_computed += 1
            node.avg_cost = node.total_cost / node.num_computed
        level = node.fastest_tier()
        clock = self._clocks[level] if level is not None else self._clocks[GPU]
        node.priority = self.policy.priority(node, clock)

    # ---- eviction: Alg. 1 EVICT_IN_GPU generalised to a clock cascade ----

    def _tier_leaves(self, level: int, pinned: Set[Node]) -> List[Node]:
        """Evictable nodes of tier ``level``: resident there, not resident in
        any faster tier, and with no child resident at tier <= ``level`` —
        demoting the node one tier down then keeps it at least as fast as
        every cached child (children on slower tiers are fine: the demoted
        parent stays cached).  If the demotion's copy fails outright, the
        orphaned subtree is reclaimed (see ``_orphan_subtree``) — so a node
        with a pinned cached descendant is NOT evictable: a failed copy
        would have to orphan state a running request (or in-flight fetch)
        still references."""
        # one post-order pass: does any pinned cached node live below n?
        order: List[Node] = []
        stack = [self.root]
        while stack:
            n = stack.pop()
            order.append(n)
            stack.extend(n.children.values())
        pinned_below: Dict[Node, bool] = {}
        for n in reversed(order):       # children before parents
            pinned_below[n] = any(
                ((c.pinned or c in pinned) and c.cached) or pinned_below[c]
                for c in n.children.values())
        out = []
        for n in order:
            if n is self.root or n in pinned or n.pinned or pinned_below[n]:
                continue
            if n.resident(level) and \
                    not any(n.resident(j) for j in range(level)) and \
                    not any(c.resident(j) for c in n.children.values()
                            for j in range(level + 1)):
                out.append(n)
        return out

    def evict_gpu(self, required: int, pinned: Optional[Set[Node]] = None) -> float:
        """Free >= required bytes of GPU tier. Returns transfer seconds spent
        on the demotion cascade. Raises EvictionError if impossible (all
        pinned)."""
        return self.evict_gpu_until(
            lambda: self.gpu_used + required <= self.gpu_capacity, pinned)

    def evict_gpu_until(self, done: Callable[[], bool],
                        pinned: Optional[Set[Node]] = None) -> float:
        """Alg. 1 EVICT_IN_GPU driven by an arbitrary stop condition —
        shared by the byte-budget loop above and external resource reclaim
        (e.g. the runtime freeing paged-pool blocks). Raises EvictionError
        if ``done()`` is still false with no evictable leaf left."""
        return self._evict_tier_until(GPU, done, pinned, strict=True)

    def evict_host(self, required: int, pinned: Optional[Set[Node]] = None) -> float:
        """Best-effort: free host bytes by spilling to disk (or dropping).
        Returns cascade transfer seconds; gives up silently when every host
        leaf is pinned (the caller skips the host copy)."""
        return self._evict_tier_until(
            HOST, lambda: self.host_used + required <= self.host_capacity,
            pinned, strict=False)

    def evict_disk(self, required: int, pinned: Optional[Set[Node]] = None) -> float:
        """Best-effort: reclaim disk files of the lowest-priority disk-only
        leaves (end of the hierarchy — the bytes are simply dropped)."""
        return self._evict_tier_until(
            DISK, lambda: self.disk_used + required <= self.disk_capacity,
            pinned, strict=False)

    def _evict_tier_until(self, level: int, done: Callable[[], bool],
                          pinned: Optional[Set[Node]] = None,
                          *, strict: bool) -> float:
        """The shared per-tier eviction loop: pop the minimum-priority tier
        leaf, advance the tier clock to its priority (the GDSF aging step,
        one clock per tier), and demote it one tier down."""
        pinned = pinned or set()
        cost = 0.0
        while not done():
            leaves = self._tier_leaves(level, pinned)
            if not leaves:
                if strict:
                    raise EvictionError(
                        f"{TIER_NAMES[level]} cache thrash: all nodes pinned")
                return cost
            victim = min(leaves, key=lambda n: n.priority)
            self._clocks[level] = max(self._clocks[level], victim.priority)
            cost += self._demote(victim, level, pinned)
            self.stats[f"{TIER_NAMES[level]}_evictions"] += 1
        return cost

    def _written_below(self, node: Node, level: int) -> bool:
        """Does a live copy already exist one tier below ``level``?"""
        return (node.swapped_once, node.spilled_once)[level]

    def _mark_written_below(self, node: Node, level: int, value: bool) -> None:
        if level == GPU:
            node.swapped_once = value
        else:
            node.spilled_once = value

    def _demote(self, node: Node, level: int,
                pinned: Optional[Set[Node]] = None) -> float:
        """Demote ``node`` one tier down from ``level`` (first time: copy;
        while a copy below is live: free, zero bytes moved).  The last tier
        demotes to nowhere — the payload is dropped and the metadata pruned.
        The caller's ``pinned`` set rides the whole cascade: a promotion's
        room-making must never evict another node of the path being
        promoted, at ANY tier."""
        cost = 0.0
        nxt = level + 1
        if nxt < N_TIERS and self._capacity[nxt] > 0:
            if not self._written_below(node, level):
                # make room below first — this is the cascade: a host
                # eviction triggered here may itself spill to disk
                cost += self._evict_tier_until(
                    nxt,
                    lambda: self._used[nxt] + node.bytes_
                    <= self._capacity[nxt],
                    pinned, strict=False)
                if self._used[nxt] + node.bytes_ <= self._capacity[nxt]:
                    t = self.backend.demote_copy(node, level)
                    cost += t
                    node.set_resident(nxt, True)
                    self._mark_written_below(node, level, True)
                    self._used[nxt] += node.bytes_
                    hop = ("swap_out", "spill")[level]
                    self.stats[f"{hop}_bytes"] += node.bytes_
                    self.stats[f"{hop}_seconds"] += t
            else:
                self.stats[("swap_out_skipped", "spill_skipped")[level]] += 1
        self.backend.free_tier(node, level)
        node.set_resident(level, False)
        if level > GPU:
            # the copy AT this level is gone: the tier above must recopy on
            # its next demotion (swap-out/spill-once tracks live copies)
            self._mark_written_below(node, level - 1, False)
        self._used[level] -= node.bytes_
        dest = node.fastest_tier()
        if dest is not None:
            # re-key priority against the clock of its new (slower) home tier
            node.priority = self.policy.priority(node, self._clocks[dest])
        else:
            # fell fully uncached (end of hierarchy, or the copy down was
            # skipped because the next tier is saturated with pinned work):
            # descendants still holding copies are unreachable now —
            # match_prefix stops at the first uncached hop — so keeping
            # their bytes is a pure leak; reclaim the whole subtree.
            self._orphan_subtree(node)
            if level > GPU:
                # GPU demotions that failed to copy keep the node's own
                # metadata — it may be recomputed and revived with stats.
                self._maybe_prune(node)
        return cost

    def _orphan_subtree(self, node: Node) -> None:
        """Free every cached copy below a node that fell fully uncached and
        prune the dead metadata.  Cannot touch pinned state: ``_tier_leaves``
        refuses to evict any node with a pinned cached descendant, so a
        request path (or an in-flight fetch's temp-pinned node) never loses
        its bytes to a failed demotion above it."""
        doomed = []
        stack = list(node.children.values())
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            doomed.append(n)
        for n in doomed:
            for level in range(N_TIERS):
                if n.resident(level):
                    self.backend.free_tier(n, level)
                    n.set_resident(level, False)
                    self._used[level] -= n.bytes_
                    self.stats["orphaned_bytes"] += n.bytes_
            n.swapped_once = n.spilled_once = False
        for n in doomed:
            self._maybe_prune(n)

    def _maybe_prune(self, node: Node) -> None:
        """Drop fully-uncached leaf subtrees to bound metadata growth (keeps
        frequency stats for cached/again-reachable nodes only).  Pinned
        nodes are never pruned: a running request (or an in-flight insert /
        fetch, which temp-pins its node) still references them, and marking
        a detached object resident would leak its bytes forever."""
        while (node is not None and node is not self.root and not node.cached
               and not node.pinned
               and not node.children and node.parent is not None):
            parent = node.parent
            parent.children.pop(node.doc_id, None)
            node = parent

    # ---- insertion / promotion ------------------------------------------

    def insert(self, parent: Node, doc_id: int, n_tokens: int,
               payload=None, pinned: Optional[Set[Node]] = None) -> Tuple[Node, float]:
        """Create (or revive) child node in GPU tier. Returns (node, seconds)."""
        node = parent.children.get(doc_id)
        if node is None:
            node = Node(doc_id=doc_id, parent=parent, n_tokens=n_tokens,
                        bytes_=n_tokens * self.bytes_per_token)
            parent.children[doc_id] = node
        cost = 0.0
        if not node.in_gpu:
            # temp-pin: the room-making cascade below must not victimize or
            # prune the very node being inserted (it can be a cold-tier
            # resident — e.g. a disk hit whose promotion failed and degraded
            # to recompute — and would otherwise be the lowest-priority leaf)
            was_pinned = node.pinned
            node.pinned = True
            try:
                cost += self.evict_gpu(node.bytes_, pinned)
            finally:
                node.pinned = was_pinned
            if self.gpu_used + node.bytes_ > self.gpu_capacity:
                raise EvictionError("node larger than GPU cache")
            node.payload_gpu = payload
            node.in_gpu = True
            self.gpu_used += node.bytes_
        else:
            # already resident: keep the existing payload — with chunked /
            # batched prefill, two in-flight requests can compute the same
            # doc segment (plan→commit windows interleave); the caller frees
            # any payload the tree did not take (it owns the storage)
            if node.payload_gpu is None and payload is not None:
                node.payload_gpu = payload
        return node, cost

    def preload_disk(self, doc_id: int, n_tokens: int,
                     payload_host=None) -> Tuple[Node, float]:
        """Bulk-insert path for corpus preloading (--mode cag): create a
        root child DIRECTLY in the disk tier.  O(1) per doc — no eviction
        scan, no clock churn, no transient GPU/host residency — where
        ``insert`` + demotion cascades would run a full-tree ``_tier_leaves``
        post-order walk per node (the bulk-insert pathology: O(corpus^2) to
        preload a corpus).  Preloading never evicts: inserting beyond
        ``disk_capacity`` raises EvictionError loudly instead of thrashing
        the cascade.  ``payload_host`` is the host-layout KV payload the
        backend's ``spill`` hop writes to disk (the host copy is freed after
        the write — the node lands disk-only, promoted on demand later).
        Returns (node, spill_seconds)."""
        if self._capacity[DISK] <= 0:
            raise ValueError(
                "preload_disk requires a disk tier (disk_capacity > 0)")
        node = self.root.children.get(doc_id)
        if node is not None and node.cached:
            return node, 0.0            # already resident somewhere: no-op
        if node is None:
            node = Node(doc_id=doc_id, parent=self.root, n_tokens=n_tokens,
                        bytes_=n_tokens * self.bytes_per_token)
        if self._used[DISK] + node.bytes_ > self._capacity[DISK]:
            raise EvictionError(
                f"corpus preload overflows the disk tier: doc {doc_id} "
                f"({node.bytes_} B) does not fit "
                f"({self._used[DISK]}/{self._capacity[DISK]} B used); "
                f"raise --disk-cache-bytes to hold the whole corpus")
        node.payload_host = payload_host
        t = self.backend.spill(node)
        self.backend.free_host(node)
        node.in_disk = True
        node.spilled_once = True        # the disk file is the live copy
        self._used[DISK] += node.bytes_
        self.stats["spill_bytes"] += node.bytes_
        self.stats["spill_seconds"] += t
        # chunk-cache metadata: preloaded KV is computed at position 0 with
        # no preceding docs — exactly what commit_chunks records for a doc
        # computed first — so --reuse chunk composes with CAG preloads
        node.src_prefix = ()
        node.exact_ctx = True
        node.priority = self.policy.priority(node, self._clocks[DISK])
        self.root.children[doc_id] = node
        return node, t

    def fetch_to_host(self, node: Node, *, strict: bool = False,
                      pinned: Optional[Set[Node]] = None) -> float:
        """Stage a disk-resident node into the host tier (the first hop of a
        promotion, and the overlap hook: the runtime prefetches disk reads
        during retrieval stages so the engine-critical promote is a pure
        host->GPU copy).  Best-effort unless ``strict``; returns seconds."""
        if node.in_host or not node.in_disk:
            return 0.0
        was_pinned = node.pinned
        node.pinned = True     # room-making must not evict the fetchee
        try:
            cost = self._evict_tier_until(
                HOST,
                lambda: self.host_used + node.bytes_ <= self.host_capacity,
                pinned, strict=False)
        finally:
            node.pinned = was_pinned
        if not node.in_disk:
            # defense in depth: the room-making cascade should never be able
            # to reclaim the pinned fetchee's disk copy, but promoting a
            # freed handle would corrupt the tier state — bail instead
            if strict:
                raise EvictionError("disk copy vanished during fetch")
            return cost
        if self.host_used + node.bytes_ > self.host_capacity:
            if strict:
                raise EvictionError("disk fetch does not fit host tier")
            return cost
        t = self.backend.promote_copy(node, DISK)
        cost += t
        node.in_host = True
        node.swapped_once = True        # a live host copy exists again
        self.host_used += node.bytes_
        self.stats["fetch_bytes"] += node.bytes_
        self.stats["fetch_seconds"] += t
        # re-key against the destination tier's clock, like every other tier
        # move — a stale disk-clock priority would make the fresh fetch the
        # first host eviction victim, undoing the prefetch immediately
        node.priority = self.policy.priority(node, self._clocks[HOST])
        return cost

    def ensure_in_gpu(self, nodes: Sequence[Node]) -> float:
        """Promote a matched prefix path into GPU, cascading disk->host->GPU
        (host hits pay the PCIe transfer, disk hits additionally pay the
        mmap read — the paper's 'cache hit latency' components)."""
        cost = 0.0
        pinned = set(nodes)
        for n in nodes:
            if n.in_gpu:
                continue
            if not n.in_host:
                # disk-only: stage through host (prefetch may have done this
                # already during retrieval, making this a no-op)
                cost += self.fetch_to_host(n, strict=True, pinned=pinned)
            cost += self.evict_gpu(n.bytes_, pinned)
            if self.gpu_used + n.bytes_ > self.gpu_capacity:
                raise EvictionError("promotion does not fit GPU cache")
            t = self.backend.promote_copy(n, HOST)
            cost += t
            n.in_gpu = True
            self.gpu_used += n.bytes_
            self.stats["load_bytes"] += n.bytes_
            self.stats["load_seconds"] += t
            n.priority = self.policy.priority(n, self.gpu_clock)
        return cost

    # ---- introspection ----------------------------------------------------

    def nodes(self) -> Iterable[Node]:
        stack = [self.root]
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if n is not self.root:
                yield n

    def check_invariants(self) -> None:
        used = [0] * N_TIERS
        for n in self.nodes():
            for level in range(N_TIERS):
                if n.resident(level):
                    used[level] += n.bytes_
            if n.in_gpu:
                p = n.parent
                assert p is self.root or p.in_gpu, "GPU node with non-GPU parent"
            elif n.cached:
                p = n.parent
                assert p is self.root or p.cached, \
                    f"{TIER_NAMES[n.fastest_tier()]} node with free parent"
            assert n.swapped_once == n.in_host, "host-copy flag out of sync"
            assert n.spilled_once == n.in_disk, "disk-copy flag out of sync"
        for level in range(N_TIERS):
            assert used[level] == self._used[level], \
                (TIER_NAMES[level], used[level], self._used[level])
            assert self._used[level] <= self._capacity[level], TIER_NAMES[level]
