"""Dynamic speculative pipelining (paper §5.3, Algorithm 2 + Theorem 5.1).

The vector search is split into stages; after each stage the provisional
top-k document list is pushed to the LLM engine as a *speculative* prefill.
A stale speculation (documents changed) is terminated after its current
iteration; a new one is admitted only while the pending-prefill pool has
room (``max_prefill_bs``), which keeps speculation off the critical path
under load (Theorem 5.1 cases 2/4).

This module holds the pure decision logic; the serving engine and the
discrete-event simulator both drive it.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple


@dataclasses.dataclass
class SpecState:
    request_id: int
    current_docs: Optional[Tuple[int, ...]] = None   # docs of live speculation
    launched: List[Tuple[int, ...]] = dataclasses.field(default_factory=list)
    wasted_launches: int = 0
    useful: bool = False


class SpeculativeController:
    """Algorithm 2: decide, per retrieval stage, whether to (re)launch a
    speculative generation for ``request_id`` with docs ``d_temp``."""

    def __init__(self, max_prefill_bs: int, enabled: bool = True):
        self.max_prefill_bs = max_prefill_bs
        self.enabled = enabled

    def on_stage(
        self,
        state: SpecState,
        d_temp: Tuple[int, ...],
        pool_size: int,
        *,
        is_final: bool = False,
    ) -> Tuple[str, Optional[Tuple[int, ...]]]:
        """Returns (action, docs):
          action ∈ {"keep", "terminate_and_launch", "launch", "terminate",
                    "none"} — what the engine should do with this request's
          speculation after this retrieval stage.
        """
        if not self.enabled:
            # No-DSP baseline: only act when the search is final.
            if is_final:
                return ("launch", d_temp)
            return ("none", None)

        if d_temp == state.current_docs:
            if state.current_docs is not None and is_final:
                state.useful = True
            return ("keep", None)

        # docs changed: terminate stale speculation after current iteration
        terminate = state.current_docs is not None
        # admit new speculation only if the prefill pool has room (Alg. 2 l.9)
        # — the *final* result is always admitted (it is real work, case 3).
        if is_final or pool_size < self.max_prefill_bs:
            if terminate:
                state.wasted_launches += 1
            state.current_docs = d_temp
            state.launched.append(d_temp)
            if is_final:
                state.useful = True
            return ("terminate_and_launch" if terminate else "launch", d_temp)
        if terminate:
            state.wasted_launches += 1
            state.current_docs = None
            return ("terminate", None)
        return ("none", None)


def staged_topk(
    scores_per_stage: Sequence[Sequence[Tuple[float, int]]],
    k: int,
) -> List[Tuple[int, ...]]:
    """Utility: given per-stage (score, doc_id) pools, produce the running
    top-k after each stage (lower score = closer, L2)."""
    pool: List[Tuple[float, int]] = []
    out: List[Tuple[int, ...]] = []
    for stage in scores_per_stage:
        pool.extend(stage)
        pool.sort()
        out.append(tuple(d for _, d in pool[:k]))
    return out
