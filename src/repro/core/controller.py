"""RAG controller (paper Fig. 7): the orchestration logic shared verbatim by
the real JAX serving engine and the discrete-event simulator.

Given a request's retrieved document sequence it plans the prefix hit
(promotions + alpha/beta split), and after prefill it commits the newly
computed document states into the knowledge tree and refreshes PGDSF stats.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core.knowledge_tree import EvictionError, KnowledgeTree, Node


@dataclasses.dataclass
class RequestPlan:
    doc_ids: Tuple[int, ...]
    doc_tokens: Tuple[int, ...]      # token count per retrieved doc
    question_tokens: int
    hit_nodes: List[Node]            # longest cached prefix (in order)
    alpha: int                       # cached tokens (prefix docs)
    beta: int                        # tokens to compute (rest docs + question)
    promote_bytes: int               # host/disk->GPU bytes for the hit
    hit_docs: int                    # for the paper's per-doc hit-rate metric
    # per-tier hit attribution at plan time: alpha tokens split by the tier
    # each hit node was resident in (gpu, host, disk)
    hit_tier_tokens: Tuple[int, int, int] = (0, 0, 0)

    @property
    def full_len(self) -> int:
        return self.alpha + self.beta


class RAGController:
    def __init__(self, tree: KnowledgeTree):
        self.tree = tree
        self.total_docs = 0
        self.total_hit_docs = 0

    # ---- planning ---------------------------------------------------------

    def plan(self, doc_ids: Sequence[int], doc_tokens: Sequence[int],
             question_tokens: int) -> RequestPlan:
        hit = self.tree.match_prefix(doc_ids)
        alpha = sum(n.n_tokens for n in hit)
        beta = sum(doc_tokens[len(hit):]) + question_tokens
        promote = sum(n.bytes_ for n in hit if not n.in_gpu)
        tier_tokens = [0, 0, 0]
        for n in hit:
            tier_tokens[n.fastest_tier()] += n.n_tokens
        for name, toks in zip(("gpu", "host", "disk"), tier_tokens):
            self.tree.stats[f"hit_tokens_{name}"] += toks
        self.total_docs += len(doc_ids)
        self.total_hit_docs += len(hit)
        self.tree.stats["hits" if hit else "misses"] += 1
        return RequestPlan(
            doc_ids=tuple(doc_ids),
            doc_tokens=tuple(doc_tokens),
            question_tokens=question_tokens,
            hit_nodes=list(hit),
            alpha=alpha,
            beta=beta,
            promote_bytes=promote,
            hit_docs=len(hit),
            hit_tier_tokens=tuple(tier_tokens),
        )

    # ---- execution hooks ----------------------------------------------------

    def promote(self, plan: RequestPlan) -> float:
        """Pull the hit prefix into GPU; returns transfer seconds."""
        for n in plan.hit_nodes:
            n.pinned = True
        try:
            return self.tree.ensure_in_gpu(plan.hit_nodes)
        except EvictionError:
            # degenerate: cache thrash — drop the hit, full recompute.
            # Roll back BOTH tier-attribution channels (the plan's own split
            # and the tree's running counters): nothing was actually served
            for n in plan.hit_nodes:
                n.pinned = False
            for name, toks in zip(("gpu", "host", "disk"),
                                  plan.hit_tier_tokens):
                self.tree.stats[f"hit_tokens_{name}"] -= toks
            plan.hit_nodes, plan.alpha = [], 0
            plan.beta = sum(plan.doc_tokens) + plan.question_tokens
            plan.promote_bytes = 0
            plan.hit_tier_tokens = (0, 0, 0)
            return 0.0

    def commit(self, plan: RequestPlan,
               payloads: Optional[Sequence[object]] = None,
               max_docs: Optional[int] = None) -> List[Node]:
        """After prefill: insert newly computed doc nodes (GPU tier), run
        Alg. 1 UPDATE_NODE for every accessed doc, unpin. Returns the list
        of newly inserted nodes (in path order) so callers managing real
        payload storage can reclaim payloads the tree did not take.

        max_docs (paper §8 "Large top-k"): cache only the first ``max_docs``
        documents of the sequence — permutation explosion makes deep tails
        unlikely to be reused, so trading tail coverage for cache space
        raises overall hit rate at large top-k."""
        tree = self.tree
        parent = plan.hit_nodes[-1] if plan.hit_nodes else tree.root
        pinned = set(plan.hit_nodes)
        new_nodes: List[Node] = []
        limit = len(plan.doc_ids) if max_docs is None else min(
            max_docs, len(plan.doc_ids))
        for i in range(len(plan.hit_nodes), limit):
            payload = payloads[i - len(plan.hit_nodes)] if payloads else None
            try:
                node, _ = tree.insert(parent, plan.doc_ids[i],
                                      plan.doc_tokens[i], payload,
                                      pinned=pinned | set(new_nodes))
            except EvictionError:
                break  # cache too small for this path — skip the tail
            new_nodes.append(node)
            parent = node
        # Alg. 1 stat updates: every accessed doc node
        for n in plan.hit_nodes:
            tree.update_on_access(n, True, plan.alpha, plan.beta)
        for n in new_nodes:
            tree.update_on_access(n, False, plan.alpha, plan.beta)
        for n in plan.hit_nodes:
            n.pinned = False
        return new_nodes

    # ---- metrics ------------------------------------------------------------

    @property
    def doc_hit_rate(self) -> float:
        """Paper §7.3: hit documents / retrieved documents."""
        return self.total_hit_docs / max(self.total_docs, 1)
