"""RAG controller (paper Fig. 7): the orchestration logic shared verbatim by
the real JAX serving engine and the discrete-event simulator.

Given a request's retrieved document sequence it plans the prefix hit
(promotions + alpha/beta split), and after prefill it commits the newly
computed document states into the knowledge tree and refreshes PGDSF stats.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core.knowledge_tree import EvictionError, KnowledgeTree, Node


@dataclasses.dataclass
class ChunkItem:
    """Per-position placement decision in chunk-cache mode (--reuse chunk).

    kind:
      * ``miss``  — no cached KV: compute all ``n_tokens`` fresh;
      * ``exact`` — cached KV was computed after this exact doc prefix
        (``node.src_prefix == ctx`` with ``exact_ctx``): reuse all of it,
        bit-identical;
      * ``reloc`` — cached KV exists but for a different context/position:
        reuse the tail, recompute the first ``recompute`` boundary tokens
        against the true preceding context (approximate by construction —
        the reused tail keeps its original RoPE rotations)."""
    kind: str                        # "miss" | "exact" | "reloc"
    doc_id: int
    n_tokens: int                    # full doc token count
    node: Optional[Node]             # cached node (exact/reloc), else None
    recompute: int = 0               # boundary tokens recomputed (reloc)

    @property
    def reused(self) -> int:
        return 0 if self.kind == "miss" else self.n_tokens - self.recompute


@dataclasses.dataclass
class RequestPlan:
    doc_ids: Tuple[int, ...]
    doc_tokens: Tuple[int, ...]      # token count per retrieved doc
    question_tokens: int
    hit_nodes: List[Node]            # longest cached prefix (in order)
    alpha: int                       # cached tokens (prefix docs)
    beta: int                        # tokens to compute (rest docs + question)
    promote_bytes: int               # host/disk->GPU bytes for the hit
    hit_docs: int                    # for the paper's per-doc hit-rate metric
    # per-tier hit attribution at plan time: alpha tokens split by the tier
    # each hit node was resident in (gpu, host, disk)
    hit_tier_tokens: Tuple[int, int, int] = (0, 0, 0)
    # chunk-cache mode only: one ChunkItem per doc position (None = prefix
    # mode).  hit_nodes then holds the exact/reloc nodes in position order.
    chunks: Optional[List[ChunkItem]] = None
    # was the materialized context exact end-to-end at plan time?  (False
    # as soon as one chunk is relocated — the request's outputs are then
    # approximate and only tolerance verification applies.)
    exact: bool = True

    @property
    def full_len(self) -> int:
        return self.alpha + self.beta


def effective_recompute(recompute_tokens: int, n_tokens: int,
                        block_size: int) -> int:
    """Boundary-recompute width for one relocated chunk, page-aligned UP:
    the reused tail must start at a page boundary (run tables address whole
    pages from slot 0 — kernels/paged_attention.py contract), so the
    recomputed head rounds up to the block size.  Clamps to the chunk
    length: at or past it the chunk degenerates to an exact full
    recompute (the tolerance-mode hypothesis property)."""
    if recompute_tokens >= n_tokens:
        return n_tokens
    bs = max(1, int(block_size))
    r = ((max(0, int(recompute_tokens)) + bs - 1) // bs) * bs
    return min(r, n_tokens)


class RAGController:
    def __init__(self, tree: KnowledgeTree):
        self.tree = tree
        self.total_docs = 0
        self.total_hit_docs = 0

    # ---- planning ---------------------------------------------------------

    def plan(self, doc_ids: Sequence[int], doc_tokens: Sequence[int],
             question_tokens: int) -> RequestPlan:
        hit = self.tree.match_prefix(doc_ids)
        alpha = sum(n.n_tokens for n in hit)
        beta = sum(doc_tokens[len(hit):]) + question_tokens
        promote = sum(n.bytes_ for n in hit if not n.in_gpu)
        tier_tokens = [0, 0, 0]
        for n in hit:
            tier_tokens[n.fastest_tier()] += n.n_tokens
        for name, toks in zip(("gpu", "host", "disk"), tier_tokens):
            self.tree.stats[f"hit_tokens_{name}"] += toks
        self.total_docs += len(doc_ids)
        self.total_hit_docs += len(hit)
        self.tree.stats["hits" if hit else "misses"] += 1
        return RequestPlan(
            doc_ids=tuple(doc_ids),
            doc_tokens=tuple(doc_tokens),
            question_tokens=question_tokens,
            hit_nodes=list(hit),
            alpha=alpha,
            beta=beta,
            promote_bytes=promote,
            hit_docs=len(hit),
            hit_tier_tokens=tuple(tier_tokens),
        )

    def plan_chunks(self, doc_ids: Sequence[int], doc_tokens: Sequence[int],
                    question_tokens: int, *, recompute_tokens: int,
                    block_size: int = 1) -> RequestPlan:
        """Chunk-cache planning (--reuse chunk): probe every doc position
        independently via ``match_chunks`` and classify each as
        miss / exact / reloc (see ``ChunkItem``).  alpha counts the REUSED
        tokens (exact chunks whole, relocated chunks minus their boundary
        recompute); beta is everything computed (misses + boundaries +
        question), so alpha + beta == full_len exactly as in prefix mode
        and every downstream accounting path keeps working."""
        tree = self.tree
        match = tree.match_chunks(doc_ids)
        chunks: List[ChunkItem] = []
        hit_nodes: List[Node] = []
        tier_tokens = [0, 0, 0]
        exact_so_far = True
        for i, (d, node) in enumerate(zip(doc_ids, match)):
            n_tok = int(doc_tokens[i])
            if node is not None and node.exact_ctx \
                    and node.src_prefix == tuple(doc_ids[:i]):
                # the cached KV was computed after exactly this doc prefix
                # with an exact context: reusing it IS the full-recompute
                # value — zero boundary recompute, exactness preserved
                item = ChunkItem("exact", int(d), n_tok, node)
            elif node is not None:
                r = effective_recompute(recompute_tokens, n_tok, block_size)
                if r >= n_tok:
                    # boundary covers the whole chunk: plain full recompute
                    item = ChunkItem("miss", int(d), n_tok, None)
                else:
                    item = ChunkItem("reloc", int(d), n_tok, node,
                                     recompute=r)
                    exact_so_far = False
            else:
                item = ChunkItem("miss", int(d), n_tok, None)
            chunks.append(item)
            if item.node is not None:
                hit_nodes.append(item.node)
                tier_tokens[item.node.fastest_tier()] += item.reused
        alpha = sum(it.reused for it in chunks)
        beta = sum(it.n_tokens if it.kind == "miss" else it.recompute
                   for it in chunks) + question_tokens
        promote = sum(n.bytes_ for n in hit_nodes if not n.in_gpu)
        for name, toks in zip(("gpu", "host", "disk"), tier_tokens):
            tree.stats[f"hit_tokens_{name}"] += toks
        self.total_docs += len(doc_ids)
        self.total_hit_docs += len(hit_nodes)
        tree.stats["hits" if hit_nodes else "misses"] += 1
        return RequestPlan(
            doc_ids=tuple(int(d) for d in doc_ids),
            doc_tokens=tuple(int(t) for t in doc_tokens),
            question_tokens=question_tokens,
            hit_nodes=hit_nodes,
            alpha=alpha,
            beta=beta,
            promote_bytes=promote,
            hit_docs=len(hit_nodes),
            hit_tier_tokens=tuple(tier_tokens),
            chunks=chunks,
            exact=exact_so_far,
        )

    # ---- corpus preloading (--mode cag; docs/ARCHITECTURE.md §12) ----------

    def preload_corpus(self, doc_ids: Sequence[int],
                       doc_tokens: Sequence[int], payload_of=None, *,
                       log=None, log_every: int = 64) -> dict:
        """Pre-insert the FULL corpus KV into the tree's disk tier (CAG
        startup).  Every doc becomes a root child via the O(1)
        ``preload_disk`` path — no eviction scans, no transient GPU/host
        residency — so preloading a corpus is linear in corpus size and
        raises EvictionError loudly if the disk budget cannot hold it.

        ``payload_of(doc_id, n_tokens)`` produces the host-layout KV payload
        to spill (None = accounting-only, the simulator's mode).  ``log`` is
        an optional progress callback called every ``log_every`` docs and at
        the end with (docs_done, total_docs, bytes_so_far).  Returns
        ``{"docs", "tokens", "bytes", "files", "seconds"}`` — ``files`` is
        the number of disk segments actually written (spill hops taken;
        already-resident docs are skipped and don't write)."""
        tree = self.tree
        stats = {"docs": 0, "tokens": 0, "bytes": 0, "files": 0,
                 "seconds": 0.0}
        total = len(doc_ids)
        for i, (d, n_tok) in enumerate(zip(doc_ids, doc_tokens)):
            d, n_tok = int(d), int(n_tok)
            existing = tree.root.children.get(d)
            if existing is not None and existing.cached:
                continue
            payload = payload_of(d, n_tok) if payload_of is not None else None
            node, t = tree.preload_disk(d, n_tok, payload)
            stats["docs"] += 1
            stats["tokens"] += n_tok
            stats["bytes"] += node.bytes_
            stats["files"] += 1
            stats["seconds"] += t
            if log is not None and (i + 1) % log_every == 0:
                log(i + 1, total, stats["bytes"])
        if log is not None:
            log(total, total, stats["bytes"])
        return stats

    # ---- execution hooks ----------------------------------------------------

    def promote(self, plan: RequestPlan) -> float:
        """Pull the hit prefix into GPU; returns transfer seconds."""
        for n in plan.hit_nodes:
            n.pinned = True
        try:
            return self.tree.ensure_in_gpu(plan.hit_nodes)
        except EvictionError:
            # degenerate: cache thrash — drop the hit, full recompute.
            # Roll back BOTH tier-attribution channels (the plan's own split
            # and the tree's running counters): nothing was actually served
            for n in plan.hit_nodes:
                n.pinned = False
            for name, toks in zip(("gpu", "host", "disk"),
                                  plan.hit_tier_tokens):
                self.tree.stats[f"hit_tokens_{name}"] -= toks
            plan.hit_nodes, plan.alpha = [], 0
            plan.beta = sum(plan.doc_tokens) + plan.question_tokens
            plan.promote_bytes = 0
            plan.hit_tier_tokens = (0, 0, 0)
            if plan.chunks is not None:
                # chunk mode: every position falls back to a fresh compute
                # — which is exact again (nothing relocated anymore)
                plan.chunks = [ChunkItem("miss", it.doc_id, it.n_tokens,
                                         None) for it in plan.chunks]
                plan.exact = True
            return 0.0

    def commit(self, plan: RequestPlan,
               payloads: Optional[Sequence[object]] = None,
               max_docs: Optional[int] = None) -> List[Node]:
        """After prefill: insert newly computed doc nodes (GPU tier), run
        Alg. 1 UPDATE_NODE for every accessed doc, unpin. Returns the list
        of newly inserted nodes (in path order) so callers managing real
        payload storage can reclaim payloads the tree did not take.

        max_docs (paper §8 "Large top-k"): cache only the first ``max_docs``
        documents of the sequence — permutation explosion makes deep tails
        unlikely to be reused, so trading tail coverage for cache space
        raises overall hit rate at large top-k."""
        tree = self.tree
        parent = plan.hit_nodes[-1] if plan.hit_nodes else tree.root
        pinned = set(plan.hit_nodes)
        new_nodes: List[Node] = []
        limit = len(plan.doc_ids) if max_docs is None else min(
            max_docs, len(plan.doc_ids))
        for i in range(len(plan.hit_nodes), limit):
            payload = payloads[i - len(plan.hit_nodes)] if payloads else None
            try:
                node, _ = tree.insert(parent, plan.doc_ids[i],
                                      plan.doc_tokens[i], payload,
                                      pinned=pinned | set(new_nodes))
            except EvictionError:
                break  # cache too small for this path — skip the tail
            new_nodes.append(node)
            parent = node
        # Alg. 1 stat updates: every accessed doc node
        for n in plan.hit_nodes:
            tree.update_on_access(n, True, plan.alpha, plan.beta)
        for n in new_nodes:
            tree.update_on_access(n, False, plan.alpha, plan.beta)
        for n in plan.hit_nodes:
            n.pinned = False
        return new_nodes

    def commit_chunks(self, plan: RequestPlan,
                      payloads: Optional[Sequence[object]] = None,
                      max_docs: Optional[int] = None) -> List[Node]:
        """Chunk-mode commit: every MISS doc inserts as a root child (the
        flat chunk cache) recording the doc context it was computed after
        (``src_prefix``/``exact_ctx``).  Relocated boundary segments are
        request-private and never commit — the canonical cache entry for a
        reloc hit is the node already resident.  ``payloads`` aligns with
        the MISS positions in order.  Returns newly inserted nodes so
        callers managing real storage can reclaim declined payloads."""
        tree = self.tree
        assert plan.chunks is not None, "commit_chunks needs a chunk plan"
        pinned = set(plan.hit_nodes)
        new_nodes: List[Node] = []
        limit = len(plan.chunks) if max_docs is None else min(
            max_docs, len(plan.chunks))
        pi = 0
        exact_so_far = True
        for i, it in enumerate(plan.chunks):
            if it.kind == "reloc":
                # everything materialized after a relocated chunk was
                # computed over approximate context
                exact_so_far = False
                continue
            if it.kind != "miss":
                continue
            payload = None
            if payloads is not None and pi < len(payloads):
                payload = payloads[pi]
            pi += 1
            if i >= limit:
                continue
            existing = tree.root.children.get(it.doc_id)
            if existing is not None and existing.cached:
                # a concurrent prefill committed this doc between plan and
                # commit: the incumbent (with ITS src_prefix) is canonical —
                # taking our payload would attach KV computed after a
                # different context to its metadata.  Caller reclaims ours.
                continue
            try:
                node, _ = tree.insert(tree.root, it.doc_id, it.n_tokens,
                                      payload,
                                      pinned=pinned | set(new_nodes))
            except EvictionError:
                continue     # chunk cache too small for this doc: skip it
            node.src_prefix = tuple(plan.doc_ids[:i])
            node.exact_ctx = exact_so_far
            new_nodes.append(node)
        for n in plan.hit_nodes:
            tree.update_on_access(n, True, plan.alpha, plan.beta)
        for n in new_nodes:
            tree.update_on_access(n, False, plan.alpha, plan.beta)
        for n in plan.hit_nodes:
            n.pinned = False
        return new_nodes

    # ---- metrics ------------------------------------------------------------

    @property
    def doc_hit_rate(self) -> float:
        """Paper §7.3: hit documents / retrieved documents."""
        return self.total_hit_docs / max(self.total_docs, 1)
