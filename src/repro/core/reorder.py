"""Cache-aware request reordering (paper §5.2).

OrderPriority = CachedLength / ComputationLength — serve requests whose hit
prefix is large relative to the compute they still need; a starvation window
guarantees any request is scheduled after at most ``window`` pops.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Generic, List, Optional, TypeVar

T = TypeVar("T")


@dataclasses.dataclass
class _Entry(Generic[T]):
    item: T
    cached_len: int
    compute_len: int
    seq: int
    skipped: int = 0

    @property
    def order_priority(self) -> float:
        return self.cached_len / max(self.compute_len, 1)


class ReorderQueue(Generic[T]):
    def __init__(self, window: int = 32, enabled: bool = True):
        self.window = window
        self.enabled = enabled
        self._entries: List[_Entry[T]] = []
        self._seq = itertools.count()

    def __len__(self) -> int:
        return len(self._entries)

    def push(self, item: T, cached_len: int, compute_len: int) -> None:
        self._entries.append(
            _Entry(item, cached_len, compute_len, next(self._seq))
        )

    def refresh(self, fn: Callable[[T], tuple]) -> None:
        """Re-evaluate (cached_len, compute_len) — hit lengths change as the
        tree evolves between arrival and scheduling."""
        for e in self._entries:
            e.cached_len, e.compute_len = fn(e.item)

    def peek_entry(self, viable: Optional[Callable[[T], bool]] = None
                   ) -> Optional["_Entry[T]"]:
        """The entry ``pop`` would select, WITHOUT removing it — callers that
        must check a resource fit (e.g. a prefill-token budget) peek first
        and only ``remove`` when the entry actually fits, so a non-fitting
        round does not disturb queue positions."""
        cands = (self._entries if viable is None
                 else [e for e in self._entries if viable(e.item)])
        if not cands:
            return None
        if not self.enabled:
            return min(cands, key=lambda e: e.seq)
        # starvation guard: anything skipped >= window times goes first
        starved = [e for e in cands if e.skipped >= self.window]
        if starved:
            return min(starved, key=lambda e: e.seq)
        return max(cands, key=lambda e: (e.order_priority, -e.seq))

    def remove(self, entry: "_Entry[T]", age: bool = True) -> None:
        """Remove a peeked entry; by default every remaining entry ages one
        skip (the same bookkeeping ``pop`` performs).  Callers popping
        several entries in ONE scheduling round pass ``age=False`` after the
        first so entries age exactly once per round, not once per pop."""
        self._entries.remove(entry)
        if age:
            for e in self._entries:
                e.skipped += 1

    def pop(self, viable: Optional[Callable[[T], bool]] = None) -> Optional[T]:
        """Remove and return the best entry. ``viable`` restricts the
        candidate set (e.g. admission control) without disturbing the
        queue position of non-viable entries."""
        best = self.peek_entry(viable)
        if best is None:
            return None
        self.remove(best)
        return best.item

    def bump_skipped(self, pred: Optional[Callable[[T], bool]] = None) -> None:
        """Count a scheduling round that passed (pred-matching) entries over
        without popping anything — admission-blocked rounds must still age
        entries toward the starvation window."""
        for e in self._entries:
            if pred is None or pred(e.item):
                e.skipped += 1

    def prune(self, drop: Callable[[T], bool]) -> int:
        """Remove entries for which ``drop(item)`` is true (cancelled
        speculations, finished requests). Returns how many were removed."""
        before = len(self._entries)
        self._entries = [e for e in self._entries if not drop(e.item)]
        return before - len(self._entries)

    def max_skipped(self, viable: Optional[Callable[[T], bool]] = None) -> int:
        """Largest skip count among (viable) entries — the scheduler's
        preemption trigger reads this to detect starving admissions."""
        cands = (self._entries if viable is None
                 else [e for e in self._entries if viable(e.item)])
        return max((e.skipped for e in cands), default=-1)

    def peek_all(self) -> List[T]:
        return [e.item for e in self._entries]
