"""Prefill-cost profiler: T(alpha cached, beta non-cached) with bilinear
interpolation — Algorithm 1 lines 6–9 of the paper.

PGDSF needs the *per-non-cached-token* compute cost of a document given how
much of its prefix was cached.  RAGCache profiles the LLM offline over a grid
of (alpha, beta) and interpolates.  Two sources feed the same table format:

  * measured: timing the real JAX model on this host (tiny models), and
  * analytic: a hardware profile (A10G / H800 / TPU v5e) for the simulator.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Callable, Dict, List, Sequence, Tuple


@dataclasses.dataclass
class HardwareProfile:
    """Analytic serving-cost model for one accelerator setup."""
    name: str
    flops_per_s: float           # effective prefill FLOP/s (already derated)
    hbm_bytes_per_s: float       # device memory bandwidth
    pcie_bytes_per_s: float      # host<->device link (the paper's PCIe 4.0x16)
    model_params: float          # active parameters
    kv_bytes_per_token: float    # paper Table 1 column
    model_bytes: float           # weight bytes (decode is weight-bound)

    # per-forward fixed overhead (framework/launch, ~1 ms per layer on the
    # paper's vLLM testbed) — this is what bounds the paper's cached-prefix
    # speedup at 11.5x rather than the raw FLOP ratio
    fixed_overhead_s: float = 30e-3

    # disk tier (expansion storage below host DRAM): sequential-read
    # bandwidth of the local NVMe the mmap'd KV segments live on
    disk_bytes_per_s: float = 6e9

    # per-forward collective time (tensor-parallel all-reduce of the
    # activations after attention + MLP); 0 on single-device profiles,
    # set by with_tp() — this term does NOT shrink with tp, which is why
    # TP speedup saturates below linear
    collective_s: float = 0.0

    def with_tp(self, tp: int, ici_allreduce_s: float = 1.5e-3
                ) -> "HardwareProfile":
        """Derived profile for a tp-way tensor-parallel replica.

        Compute, HBM bandwidth, and the host link all scale by ``tp``
        (params, pool KV-head planes, and decode kernels are sharded over
        the mesh's model axis; promote/demote copies move per-shard slices
        in parallel), while every forward gains a ring all-reduce term
        ``2 (tp-1)/tp * ici_allreduce_s`` that grows with tp.  The
        simulator applies this via ``SimConfig.tp``.
        """
        if tp < 1:
            raise ValueError(f"tp must be >= 1, got {tp}")
        if tp == 1:
            return self
        return dataclasses.replace(
            self, name=f"{self.name}-tp{tp}",
            flops_per_s=self.flops_per_s * tp,
            hbm_bytes_per_s=self.hbm_bytes_per_s * tp,
            pcie_bytes_per_s=self.pcie_bytes_per_s * tp,
            collective_s=self.collective_s
            + 2.0 * (tp - 1) / tp * ici_allreduce_s)

    def prefill_time(self, alpha: int, beta: int) -> float:
        """Time to prefill beta new tokens on top of alpha cached tokens."""
        if beta <= 0:
            return 0.0
        # dense FLOPs for the new tokens + attention against cached prefix
        flops = 2.0 * self.model_params * beta
        flops += 2.0 * 2.0 * beta * (alpha + beta / 2.0) * _attn_dim(self)
        # weights stream through SRAM at least once regardless of beta
        weight_floor = self.model_bytes / self.hbm_bytes_per_s
        return (flops / self.flops_per_s + weight_floor
                + self.fixed_overhead_s + self.collective_s)

    def transfer_time(self, n_bytes: float) -> float:
        return n_bytes / self.pcie_bytes_per_s + 1e-4

    def disk_transfer_time(self, n_bytes: float) -> float:
        """One host<->disk hop (mmap read or write of a KV segment)."""
        return n_bytes / self.disk_bytes_per_s + 1e-4

    def decode_time(self, batch: int, context: int) -> float:
        """One decode iteration for a batch (weight + KV reads, mem-bound)."""
        weight = self.model_bytes
        kv = batch * context * self.kv_bytes_per_token
        return (weight + kv) / self.hbm_bytes_per_s + 1e-3 + self.collective_s


def _attn_dim(p: HardwareProfile) -> float:
    # effective attention width: kv_bytes/token = 2 (k,v) * 2 bytes * L * d_kv
    return p.kv_bytes_per_token / 4.0


# Paper testbed: AWS g5.16xlarge, one A10G (24 GiB), PCIe 4.0x16.
# flops calibrated to paper Fig.2 (~1 s prefill at 4k tokens for a 7B model).
A10G_MISTRAL_7B = HardwareProfile(
    name="a10g-mistral-7b",
    flops_per_s=5.6e13,
    hbm_bytes_per_s=600e9,
    pcie_bytes_per_s=16e9,
    model_params=7.2e9,
    kv_bytes_per_token=0.125 * 2**20,
    model_bytes=14 * 2**30,
)
A10G_LLAMA2_7B = dataclasses.replace(
    A10G_MISTRAL_7B, name="a10g-llama2-7b", kv_bytes_per_token=0.5 * 2**20
)
H800_MIXTRAL = HardwareProfile(
    name="h800x2-mixtral-8x7b",
    flops_per_s=8e14,
    hbm_bytes_per_s=2 * 3.35e12,
    pcie_bytes_per_s=64e9,
    model_params=12.9e9,          # active (top-2 of 8 experts)
    kv_bytes_per_token=0.125 * 2**20,
    model_bytes=96.8 * 2**30,
)
H800_LLAMA2_70B = HardwareProfile(
    name="h800x2-llama2-70b",
    flops_per_s=8e14,
    hbm_bytes_per_s=2 * 3.35e12,
    pcie_bytes_per_s=64e9,
    model_params=70e9,
    kv_bytes_per_token=0.3125 * 2**20,
    model_bytes=140 * 2**30,
)
# TPU v5e target (per chip): the deployment profile for the TPU-native port.
TPU_V5E = HardwareProfile(
    name="tpu-v5e-chip",
    flops_per_s=0.5 * 197e12,     # ~50% MFU prefill
    hbm_bytes_per_s=819e9,
    pcie_bytes_per_s=16e9,        # host DRAM tier link
    model_params=7.2e9,
    kv_bytes_per_token=0.125 * 2**20,
    model_bytes=14 * 2**30,
)


class CostProfiler:
    """The T(alpha, beta) grid + bilinear interpolation of Algorithm 1."""

    def __init__(self, alphas: Sequence[int], betas: Sequence[int],
                 table: Dict[Tuple[int, int], float]):
        self.alphas = sorted(set(alphas))
        self.betas = sorted(set(betas))
        self.table = dict(table)

    @classmethod
    def from_fn(cls, fn: Callable[[int, int], float],
                alphas: Sequence[int], betas: Sequence[int]) -> "CostProfiler":
        tbl = {(a, b): fn(a, b) for a in alphas for b in betas}
        return cls(alphas, betas, tbl)

    @classmethod
    def from_profile(cls, prof: HardwareProfile,
                     alphas: Sequence[int] = (0, 128, 512, 1024, 2048, 4096, 8192),
                     betas: Sequence[int] = (1, 32, 128, 512, 1024, 2048, 4096),
                     ) -> "CostProfiler":
        return cls.from_fn(prof.prefill_time, alphas, betas)

    def _bracket(self, grid: List[int], x: int) -> Tuple[int, int, float]:
        if x <= grid[0]:
            return grid[0], grid[0], 0.0
        if x >= grid[-1]:
            # extrapolate linearly from the last interval
            lo, hi = grid[-2], grid[-1]
            return lo, hi, (x - lo) / (hi - lo)
        i = bisect.bisect_right(grid, x)
        lo, hi = grid[i - 1], grid[i]
        t = 0.0 if hi == lo else (x - lo) / (hi - lo)
        return lo, hi, t

    def estimate(self, alpha: int, beta: int) -> float:
        """Bilinear interpolation T(alpha, beta) — Alg. 1 lines 6–9."""
        al, ah, ta = self._bracket(self.alphas, int(alpha))
        bl, bh, tb = self._bracket(self.betas, int(beta))
        T = self.table
        t_l = T[(al, bl)] + ta * (T[(ah, bl)] - T[(al, bl)])
        t_h = T[(al, bh)] + ta * (T[(ah, bh)] - T[(al, bh)])
        return max(t_l + tb * (t_h - t_l), 0.0)


def measure_profiler(prefill_fn: Callable[[int, int], float],
                     alphas: Sequence[int], betas: Sequence[int],
                     repeats: int = 2) -> CostProfiler:
    """Build a profiler by timing a real prefill function (wall clock)."""
    import time
    tbl = {}
    for a in alphas:
        for b in betas:
            prefill_fn(a, b)  # warm-up / compile
            t0 = time.perf_counter()
            for _ in range(repeats):
                prefill_fn(a, b)
            tbl[(a, b)] = (time.perf_counter() - t0) / repeats
    return CostProfiler(alphas, betas, tbl)
