"""Iterative retrieval (paper §9 / Related-work RAG): multi-hop RAG issues a
new retrieval per reasoning step.  "RAGCache supports iterative retrieval by
treating the intermediate iterations as separate requests and caching the
corresponding KV cache of the documents."

This module plans a multi-hop request as a chain of single-hop plans whose
document prefixes extend each other, so hop i+1's tree lookup hits the
entire [docs_1 .. docs_i] path that hop i just inserted.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Sequence

from repro.core.controller import RAGController, RequestPlan


@dataclasses.dataclass
class HopResult:
    plan: RequestPlan
    alpha: int
    beta: int


def run_iterative(
    controller: RAGController,
    retrieve_fn: Callable[[int], Sequence[int]],   # hop index -> doc ids
    doc_tokens_fn: Callable[[int], int],           # doc id -> token count
    n_hops: int,
    question_tokens: int,
) -> List[HopResult]:
    """Plan+commit each hop; hop i's docs are appended to the running
    document path so the knowledge tree accumulates one branch per chain."""
    path: List[int] = []
    out: List[HopResult] = []
    for hop in range(n_hops):
        new_docs = [d for d in retrieve_fn(hop) if d not in path]
        docs = path + list(new_docs)
        toks = [doc_tokens_fn(d) for d in docs]
        plan = controller.plan(docs, toks, question_tokens)
        controller.promote(plan)
        controller.commit(plan)
        out.append(HopResult(plan=plan, alpha=plan.alpha, beta=plan.beta))
        path = docs
    return out
