"""Replacement-policy ablation at paper scale via the discrete-event
simulator (paper Fig. 17 / Table 2 shape): PGDSF vs GDSF vs LRU vs LFU on an
A10G + Mistral-7B profile with a drifting Zipf workload.

    PYTHONPATH=src python examples/policy_ablation.py
"""
from repro.core.profiler import A10G_MISTRAL_7B
from repro.retrieval.corpus import make_corpus, make_workload
from repro.retrieval.vectordb import IVFIndex
from repro.serving.simulator import RAGSimulator, SimConfig

corpus = make_corpus(2000, mean_doc_tokens=1000, seed=0)
index = IVFIndex(corpus.doc_vectors, n_clusters=64, nprobe=8)
wl = make_workload(corpus, n_requests=300, rate=0.8, zipf_s=1.0,
                   drift=0.15, seed=2)

print(f"{'policy':>8} {'hit rate':>9} {'avg TTFT':>9} {'p99':>7}")
for policy in ("pgdsf", "gdsf", "lru", "lfu"):
    cfg = SimConfig(profile=A10G_MISTRAL_7B, policy=policy,
                    gpu_cache_bytes=int(0.25 * 2**30),
                    host_cache_bytes=2 * 2**30,
                    reorder=False, speculative=False)
    m = RAGSimulator(cfg, corpus, index, wl).run()
    print(f"{policy:>8} {m.doc_hit_rate:>9.3f} {m.avg_ttft:>8.3f}s "
          f"{m.p99_ttft:>6.2f}s")
print("\n(paper Fig.17: PGDSF highest hit rate, lowest TTFT)")
