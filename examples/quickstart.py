"""Quickstart: the RAGCache knowledge tree + PGDSF in 60 lines.

Builds a tiny model, caches two documents' KV in the tree, and shows that a
cache-hit prefill (a) skips the document computation and (b) produces the
exact same logits as the cold path.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.core.controller import RAGController
from repro.core.knowledge_tree import KnowledgeTree
from repro.core.profiler import A10G_MISTRAL_7B, CostProfiler
from repro.models import model as M

cfg = get_reduced("qwen2-0.5b")
params = M.init_params(cfg, jax.random.PRNGKey(0))

# two "retrieved documents" and a user question (token ids)
doc1 = jax.random.randint(jax.random.PRNGKey(1), (1, 24), 0, cfg.vocab_size)
doc2 = jax.random.randint(jax.random.PRNGKey(2), (1, 24), 0, cfg.vocab_size)
question = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0, cfg.vocab_size)

# ---- cold request: compute everything, insert doc states into the tree ----
tree = KnowledgeTree(gpu_capacity=1 << 20, host_capacity=1 << 22,
                     profiler=CostProfiler.from_profile(A10G_MISTRAL_7B),
                     bytes_per_token=2 * cfg.n_layers * cfg.n_kv_heads * cfg.hd * 2)
ctl = RAGController(tree)

plan = ctl.plan([101, 202], [24, 24], question_tokens=8)
print(f"cold plan: cached={plan.alpha} tokens, to compute={plan.beta}")

_, c1 = M.prefill(cfg, params, {"tokens": doc1})
_, c12 = M.prefill(cfg, params, {"tokens": doc2}, prefix_cache=c1, prefix_len=24)
logits_cold, _ = M.prefill(cfg, params, {"tokens": question},
                           prefix_cache=c12, prefix_len=48)
payload1 = {"k": c12["k"][:, :, :24], "v": c12["v"][:, :, :24]}
payload2 = {"k": c12["k"][:, :, 24:48], "v": c12["v"][:, :, 24:48]}
ctl.commit(plan, [payload1, payload2])

# ---- warm request: same docs -> prefix hit, question-only prefill ----------
plan2 = ctl.plan([101, 202], [24, 24], question_tokens=8)
print(f"warm plan: cached={plan2.alpha} tokens, to compute={plan2.beta}")
assert plan2.alpha == 48 and plan2.beta == 8

prefix = {
    "k": jnp.concatenate([n.payload_gpu["k"] for n in plan2.hit_nodes], axis=2),
    "v": jnp.concatenate([n.payload_gpu["v"] for n in plan2.hit_nodes], axis=2),
}
logits_warm, _ = M.prefill(cfg, params, {"tokens": question},
                           prefix_cache=prefix, prefix_len=48)
ctl.commit(plan2)

err = float(jnp.abs(logits_cold - logits_warm).max())
print(f"cold-vs-warm logit error: {err:.2e} (exact reuse, no approximation)")
print(f"doc hit rate so far: {ctl.doc_hit_rate:.0%}")
assert err < 1e-5
print("OK")
