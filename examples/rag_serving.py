"""End-to-end RAG serving with the real engine: staged IVF retrieval,
speculative-pipelining decisions, knowledge-tree caching, cache-aware
reordering, prefix prefill and greedy decode — then an ablation pass that
re-serves the same workload without the cache to show the TTFT gap.

    PYTHONPATH=src python examples/rag_serving.py
"""

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models import model as M
from repro.retrieval.corpus import make_corpus, make_workload
from repro.retrieval.vectordb import IVFIndex
from repro.serving.config import EngineConfig
from repro.serving.engine import RAGServer

cfg = get_reduced("qwen2-0.5b")
params = M.init_params(cfg, jax.random.PRNGKey(0))
corpus = make_corpus(40, mean_doc_tokens=32, vocab=cfg.vocab_size, seed=0)
index = IVFIndex(corpus.doc_vectors, n_clusters=8, nprobe=4)
wl = make_workload(corpus, n_requests=10, rate=100.0, zipf_s=1.3,
                   question_tokens=8, vocab=cfg.vocab_size, seed=1)

print("== RAGCache serving (PGDSF, reorder, speculative pipelining) ==")
srv = RAGServer(cfg, params, corpus, index, config=EngineConfig(top_k=2))
res = srv.serve(wl, max_new_tokens=3)
hits = [r for r in res if r.alpha > 0]
print(f"hit rate: {srv.controller.doc_hit_rate:.0%} "
      f"({len(hits)}/{len(res)} requests had a prefix hit)")
cold = np.mean([r.prefill_time for r in res if r.alpha == 0])
warm = np.mean([r.prefill_time for r in hits]) if hits else float("nan")
print(f"mean prefill: cold={cold * 1000:.0f}ms warm={warm * 1000:.0f}ms "
      f"({cold / warm:.1f}x)" if hits else "")

print("\n== same workload, cache disabled (vLLM-like baseline) ==")
base = RAGServer(cfg, params, corpus, index,
                 config=EngineConfig(top_k=2, gpu_cache_bytes=0,
                                     host_cache_bytes=0, reorder=False,
                                     speculative=False))
res_b = base.serve(wl, max_new_tokens=3)
print(f"hit rate: {base.controller.doc_hit_rate:.0%}")

# answers must be identical with and without caching
same = sum(a.tokens == b.tokens for a, b in
           zip(sorted(res, key=lambda r: r.req_id),
               sorted(res_b, key=lambda r: r.req_id)))
print(f"\nidentical answers with/without cache: {same}/{len(res)}")
assert same == len(res)
print("OK")
