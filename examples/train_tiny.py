"""Train a ~25M-param model for a few hundred steps on the synthetic LM
stream (deliverable (b) training driver, library API usage).

    PYTHONPATH=src python examples/train_tiny.py [--steps 200]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.models import model as M
from repro.training.data import DataConfig, make_batches
from repro.training.optimizer import AdamWConfig, init_state
from repro.training.train_lib import make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--arch", default="qwen2-0.5b")
args = ap.parse_args()

cfg = get_reduced(args.arch)
params = M.init_params(cfg, jax.random.PRNGKey(0))
n = sum(x.size for x in jax.tree.leaves(params))
print(f"{cfg.name}: {n / 1e6:.1f}M params")

opt = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
state = init_state(params)
step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))
data = make_batches(DataConfig(batch_size=8, seq_len=64,
                               vocab_size=cfg.vocab_size), cfg)

first = None
t0 = time.time()
for step in range(1, args.steps + 1):
    batch = {k: jnp.asarray(v) for k, v in next(data).items()}
    params, state, m = step_fn(params, state, batch)
    loss = float(m["loss"])
    first = first or loss
    if step % 25 == 0 or step == 1:
        print(f"step {step:>4} loss {loss:.4f} "
              f"({8 * 64 * step / (time.time() - t0):,.0f} tok/s)")
print(f"loss {first:.3f} -> {loss:.3f}")
assert loss < first
print("OK")
