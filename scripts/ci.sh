#!/usr/bin/env bash
# Tier-1 CI: install dev deps (best effort — the image may be offline and
# tests degrade gracefully without hypothesis) and run the test suite with
# a hard timeout.
set -uo pipefail
cd "$(dirname "$0")/.."

TIMEOUT="${CI_TIMEOUT:-1800}"

pip install -q -r requirements-dev.txt 2>/dev/null \
    || echo "ci: dev-dep install skipped (offline?); continuing"

timeout "$TIMEOUT" python -m pytest -q
rc=$?
if [ "$rc" -eq 124 ]; then
    echo "ci: test suite exceeded ${TIMEOUT}s timeout" >&2
fi
exit "$rc"
