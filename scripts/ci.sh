#!/usr/bin/env bash
# Tier-1 CI: install dev deps (best effort — the image may be offline and
# tests degrade gracefully without hypothesis) and run the test suite with
# a hard timeout.
#
# Usage: scripts/ci.sh [--fast]
#   --fast   skip slow-marked tests (the hosted-CI fast lane)
#
# Exit codes: pytest's own code on test failure; 124 on suite timeout
# (reported distinctly on stderr).
set -uo pipefail
cd "$(dirname "$0")/.."

# export PYTHONPATH ourselves instead of relying on pyproject discovery —
# callers may invoke this script from any CWD or without pytest's rootdir
# detection (e.g. a bare `bash scripts/ci.sh` in a hosted runner).
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# 45 min: the full suite (incl. the paged-decode parity sweep added in
# PR 5) runs ~22 min on a 2-core runner; leave 2x headroom before the
# job-level 60-min kill so the distinct 124 exit still fires first.
TIMEOUT="${CI_TIMEOUT:-2700}"
PYTEST_ARGS=(-q)
for arg in "$@"; do
    case "$arg" in
        --fast) PYTEST_ARGS+=(-m "not slow") ;;
        *) echo "ci: unknown argument '$arg'" >&2; exit 2 ;;
    esac
done

pip install -q -r requirements-dev.txt 2>/dev/null \
    || echo "ci: dev-dep install skipped (offline?); continuing"

timeout "$TIMEOUT" python -m pytest "${PYTEST_ARGS[@]}"
rc=$?
if [ "$rc" -eq 124 ]; then
    echo "ci: test suite exceeded ${TIMEOUT}s timeout" >&2
elif [ "$rc" -ne 0 ]; then
    echo "ci: pytest failed (exit code $rc)" >&2
fi
exit "$rc"
